"""Long-context decoding: prefill a long prompt, then decode with the
quantized cache, comparing int4/int2 fidelity against an fp16-equivalent
(int8) baseline per decoded position — the paper's single-batch long-context
scenario (Fig. 11) at CPU-friendly scale.

Run:  PYTHONPATH=src python examples/longcontext_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_config
from repro.models.zoo import build_model


def decode_n(model, params, state, tok, n):
    step = jax.jit(model.decode_step)
    ids, logps = [], []
    for _ in range(n):
        logits, state = step(params, state, tok)
        lp = jax.nn.log_softmax(logits[:, -1])
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        ids.append(int(tok[0, 0]))
        logps.append(np.asarray(lp)[0])
    return ids, np.stack(logps)


def main():
    base = smoke_config("llama3-8b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 384), 0, base.vocab)
    results = {}
    for bits in (8, 4, 2):
        cfg = base.with_(kv_bits=bits)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))  # same weights every run
        logits, state = jax.jit(lambda p, b: model.prefill(p, b, 640))(
            params, {"tokens": prompt})
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        ids, logps = decode_n(model, params, state, tok, 24)
        results[bits] = (ids, logps)
        cache = state["caches"][0]
        kv_bytes = cache.kw.size * 4 * 2 + cache.k_res.size * 2 * 2
        print(f"int{bits}: cache≈{kv_bytes/1e6:.2f}MB  first tokens {ids[:8]}")

    ref_ids, ref_lp = results[8]
    # context: KL(ref || uniform) — how far the model is from noise; the
    # untrained smoke model has near-flat logits, so greedy-token agreement
    # is an unstable metric and KL is the meaningful one
    uni = -np.log(1.0 / ref_lp.shape[-1])
    kl_uniform = float(np.mean(np.sum(np.exp(ref_lp) * (ref_lp + uni), axis=-1)))
    print(f"reference sharpness: KL(int8||uniform) = {kl_uniform:.4f}")
    for bits in (4, 2):
        ids, lp = results[bits]
        agree = np.mean([a == b for a, b in zip(ids, ref_ids)])
        kl = float(np.mean(np.sum(np.exp(ref_lp) * (ref_lp - lp), axis=-1)))
        print(f"int{bits} vs int8 baseline: greedy-token agreement "
              f"{agree*100:.0f}% (untrained model — see above), "
              f"mean KL {kl:.4f} ({kl/max(kl_uniform,1e-9):.2f}x of uniform KL)")


if __name__ == "__main__":
    main()
