"""Quickstart: build a small llama-family model, prefill a prompt into the
quantized KV cache, and greedily decode a few tokens — the minimal
BitDecoding pipeline (query transform -> residual append -> fused low-bit
attention).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.models.zoo import build_model


def main():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_gran="channel")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}-smoke  kv_bits={cfg.kv_bits} "
          f"({cfg.kv_gran}-wise K scaling, residual N_r={cfg.kv_block})")

    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0, cfg.vocab)
    logits, state = jax.jit(lambda p, b: model.prefill(p, b, 256))(
        params, {"tokens": prompt})
    cache0 = state["caches"][0]  # stacked over layers: leaves are [L, B, ...]
    print(f"prefilled {prompt.shape[1]} tokens; cache length = "
          f"{int(cache0.length[0, 0])} "
          f"(packed blocks={int(cache0.pack_blocks[0, 0])}, "
          f"residual={int(cache0.res_len[0, 0])})")

    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for _ in range(16):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation token ids:", out)
    print("final cache length:", int(jnp.max(state["caches"][0].length[0])))


if __name__ == "__main__":
    main()
