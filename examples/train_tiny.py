"""Train a small model for a few hundred steps on the synthetic pipeline
with checkpoint/restart — exercises the full training substrate (optimizer,
microbatching, prefetch, checkpoint manager).

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    sys.argv = [
        "train", "--arch", "llama3-8b", "--smoke",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_tiny", "--ckpt-every", "50",
    ]
    train_mod.main()


if __name__ == "__main__":
    main()
