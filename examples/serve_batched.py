"""End-to-end driver: serve a small model with batched requests through the
paged continuous-batching engine (page-pool KV allocation, length-bucketed
prefill, paged decode kernel) with an int4 KV cache — the paper's "Batches"
serving setting.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=4, max_seq=256)

    rng = np.random.default_rng(0)
    n_requests = 12
    for uid in range(n_requests):
        prompt_len = int(rng.integers(8, 48))
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 24)),
        ))
    print(f"submitted {n_requests} requests into 4 slots "
          f"({'paged' if engine.paged else 'exact-length shim'} engine, "
          "int4 KV cache)")
    stats = engine.run()
    print(f"served: {stats['decoded_tokens']} tokens in {stats['steps']} "
          f"batched steps, {stats['tokens_per_s']:.1f} tok/s (CPU), "
          f"budget_retired={stats['budget_retired']}")
    if engine.paged:
        print(f"paged: {stats['prefill_calls']} bucketed prefill calls, "
              f"p50 per-token latency {stats['latency_p50_ms']:.0f} ms, "
              f"peak pool occupancy {stats['occupancy_max']:.0%}")


if __name__ == "__main__":
    main()
