"""Oracle for paged low-bit decode attention: gather pages, then reuse the
dense bitdecode reference (which also owns the shared_kv latent semantics)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.bitdecode import ref as bd_ref


def _gather(pool, table):
    """pool [P, H, ...] + table [B, nb] -> [B, H, nb, ...]."""
    if pool is None:  # shared_kv: no V-side pools
        return None
    g = jnp.take(pool, table, axis=0)  # [B, nb, H, ...]
    return jnp.moveaxis(g, 2, 1)


def paged_bitdecode_attention_ref(
    q,
    kw_pool, k_scale_pool, k_zero_pool,   # [P,H,npr,dk], [P,H,dk|block]
    vw_pool, v_scale_pool, v_zero_pool,   # None when shared_kv
    k_res, v_res,                          # dense residual per sequence
    page_table,                            # int32 [B, nb_max]
    pack_blocks, res_len,
    *,
    bits, block_n=128, sm_scale=None, k_gran="channel",
    shared_kv=False, d_v=None, num_splits=1, draft_bits=None,
):
    kw = _gather(kw_pool, page_table)
    ks = _gather(k_scale_pool, page_table)
    kz = _gather(k_zero_pool, page_table)
    vw = _gather(vw_pool, page_table)
    vs = _gather(v_scale_pool, page_table)
    vz = _gather(v_zero_pool, page_table)
    return bd_ref.bitdecode_attention_ref(
        q, kw, ks, kz, vw, vs, vz, k_res, v_res, pack_blocks, res_len,
        bits=bits, block_n=block_n, sm_scale=sm_scale, k_gran=k_gran,
        shared_kv=shared_kv, d_v=d_v, num_splits=num_splits,
        draft_bits=draft_bits,
    )
