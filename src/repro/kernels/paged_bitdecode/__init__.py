from repro.kernels.paged_bitdecode.ops import paged_bitdecode_attention  # noqa: F401
