"""Pallas TPU kernel: paged low-bit flash-decode attention (paper's Page
setting, §VI-A).

TPU-idiomatic paging: instead of a scalar-core page-table walk (vLLM/GPU),
the page table is a *scalar-prefetch* operand — BlockSpec index_maps read
``page_table[b, j]`` to pick which page of the global pool the next grid
step's DMA fetches, so page indirection rides the same double-buffered
HBM→VMEM pipeline as the dense kernel (zero extra kernels, zero gathers).

Pools are [n_pages, H, ...]; everything else matches kernels/bitdecode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.bitdecode.kernel import (_CompilerParams, _unpack,
                                            dequant_tile, finalize,
                                            init_carries, make_flash_update)


def _kernel(pt_ref, pb_ref, rl_ref, q_ref, kw_ref, ks_ref, kz_ref,
            vw_ref, vs_ref, vz_ref, kres_ref, vres_ref,
            o_ref, lse_ref, m_scr, l_scr, acc_scr,
            *, bits, block_n, nb, res_n, sm_scale, k_gran):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_steps = nb + 1

    @pl.when(j == 0)
    def _init():
        init_carries(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.bfloat16)
    update = make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale)

    @pl.when(jnp.logical_and(j < n_steps - 1, j < pb_ref[b]))
    def _packed_page():
        kq = _unpack(kw_ref[0, 0], bits)  # pool block (1,1,npr,dk) -> [0,0]
        k_hat = dequant_tile(kq, ks_ref[0, 0], kz_ref[0, 0], k_gran)
        vq = _unpack(vw_ref[0, 0], bits)
        v_hat = dequant_tile(vq, vs_ref[0, 0], vz_ref[0, 0], "tensor")
        update(k_hat, v_hat)

    @pl.when(j == n_steps - 1)
    def _residual_and_finalize():
        kr = kres_ref[0, 0].astype(jnp.bfloat16)
        vr = vres_ref[0, 0].astype(jnp.bfloat16)
        mask = lax.broadcasted_iota(jnp.int32, (1, res_n), 1) < rl_ref[b]
        update(kr, vr, row_mask=mask)
        finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_n", "sm_scale", "k_gran", "interpret"),
)
def paged_bitdecode_attention_pallas(
    q,             # [B, H, g, d_k]  (pre-padded)
    kw_pool,       # int32 [P, H, npr, d_k]
    k_scale_pool,  # [P, H, d_k] (channel) or [P, H, block_n]
    k_zero_pool,
    vw_pool,       # int32 [P, H, npr, d_v]
    v_scale_pool,  # [P, H, block_n]
    v_zero_pool,
    k_res, v_res,  # [B, H, res_n, d]
    page_table,    # int32 [B, nb_max]
    pack_blocks, res_len,
    *,
    bits: int, block_n: int, sm_scale: float, k_gran: str, interpret: bool,
):
    b, h, g, d_k = q.shape
    _, _, npr, _ = kw_pool.shape
    d_v = vw_pool.shape[-1]
    nb = page_table.shape[1]
    res_n = k_res.shape[2]
    n_steps = nb + 1

    def page(j, pt_ref, b_):
        # page id for grid step j of sequence b (clamped for residual step)
        return pt_ref[b_, jnp.minimum(j, nb - 1)]

    q_spec = pl.BlockSpec((1, 1, g, d_k), lambda i, hh, j, pt, pb, rl: (i, hh, 0, 0))
    kw_spec = pl.BlockSpec(
        (1, 1, npr, d_k), lambda i, hh, j, pt, pb, rl: (page(j, pt, i), hh, 0, 0)
    )
    kp_last = d_k if k_gran == "channel" else block_n
    kp_spec = pl.BlockSpec(
        (1, 1, kp_last), lambda i, hh, j, pt, pb, rl: (page(j, pt, i), hh, 0)
    )
    vw_spec = pl.BlockSpec(
        (1, 1, npr, d_v), lambda i, hh, j, pt, pb, rl: (page(j, pt, i), hh, 0, 0)
    )
    vp_spec = pl.BlockSpec(
        (1, 1, block_n), lambda i, hh, j, pt, pb, rl: (page(j, pt, i), hh, 0)
    )
    res_spec_k = pl.BlockSpec(
        (1, 1, res_n, d_k), lambda i, hh, j, pt, pb, rl: (i, hh, 0, 0))
    res_spec_v = pl.BlockSpec(
        (1, 1, res_n, d_v), lambda i, hh, j, pt, pb, rl: (i, hh, 0, 0))

    out_specs = [
        pl.BlockSpec((1, 1, g, d_v), lambda i, hh, j, pt, pb, rl: (i, hh, 0, 0)),
        pl.BlockSpec((1, 1, g), lambda i, hh, j, pt, pb, rl: (i, hh, 0)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, n_steps),
        in_specs=[q_spec, kw_spec, kp_spec, kp_spec, vw_spec, vp_spec, vp_spec,
                  res_spec_k, res_spec_v],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d_v), jnp.float32),
        ],
    )
    body = functools.partial(
        _kernel, bits=bits, block_n=block_n, nb=nb, res_n=res_n,
        sm_scale=sm_scale, k_gran=k_gran,
    )
    out, lse = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, g, d_v), jnp.float32),
            jax.ShapeDtypeStruct((b, h, g), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(page_table.astype(jnp.int32), pack_blocks.astype(jnp.int32),
      res_len.astype(jnp.int32), q,
      kw_pool, k_scale_pool, k_zero_pool, vw_pool, v_scale_pool, v_zero_pool,
      k_res, v_res)
    return out, lse
