"""Pallas TPU kernel: paged low-bit flash-decode attention (paper's Page
setting, §VI-A), with the same split-KV grid as kernels/bitdecode.

TPU-idiomatic paging: instead of a scalar-core page-table walk (vLLM/GPU),
the page table is a *scalar-prefetch* operand — BlockSpec index_maps read
``page_table[b, jj]`` to pick which page of the global pool the next grid
step's DMA fetches, so page indirection rides the same double-buffered
HBM→VMEM pipeline as the dense kernel (zero extra kernels, zero gathers).

Split-KV: grid = (B, H, num_splits, bps + 1); split ``s`` walks page-table
entries [s*bps, (s+1)*bps), writes its own slot of the per-split partials
(o [S,B,H,g,d_v], lse [S,B,H,g]); the residual tail rides with the last
split and the partials are combined by the shared logsumexp merge epilogue
(bitdecode.kernel.merge_partials).

Pools are [n_pages, H, ...]; everything else matches kernels/bitdecode.

``shared_kv=True`` is the MLA latent-cache mode, mirrored from the dense
kernel: the pools hold a single quantized latent stream, there are no V-side
pools at all, and the V tile is a channel slice (``[:, :d_v]``) of the
dequantized K tile — one pool page read per grid step feeds both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from jax import lax

from repro.kernels.bitdecode.kernel import (_CompilerParams, _unpack,
                                            dequant_tile, finalize,
                                            init_carries, make_flash_update)


def _paged_body(pt_ref, pb_ref, rl_ref, q_ref, kw_ref, ks_ref, kz_ref,
                vw_ref, vs_ref, vz_ref, kres_ref, vres_ref,
                o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, bits, block_n, bps, num_splits, res_n, sm_scale, k_gran,
                shared_kv, d_v):
    b = pl.program_id(0)
    s = pl.program_id(2)
    j = pl.program_id(3)
    jj = s * bps + j  # global page-table slot owned by this grid step

    @pl.when(j == 0)
    def _init():
        init_carries(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.bfloat16)
    update = make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale)

    @pl.when(jnp.logical_and(j < bps, jj < pb_ref[b]))
    def _packed_page():
        kq = _unpack(kw_ref[0, 0], bits)  # pool block (1,1,npr,dk) -> [0,0]
        k_hat = dequant_tile(kq, ks_ref[0, 0], kz_ref[0, 0], k_gran)
        if shared_kv:
            v_hat = k_hat[:, :d_v]
        else:
            vq = _unpack(vw_ref[0, 0], bits)
            v_hat = dequant_tile(vq, vs_ref[0, 0], vz_ref[0, 0], "tensor")
        update(k_hat, v_hat)

    @pl.when(jnp.logical_and(j == bps, s == num_splits - 1))
    def _residual():
        kr = kres_ref[0, 0].astype(jnp.bfloat16)
        if shared_kv:
            vr = kres_ref[0, 0, :, :d_v].astype(jnp.bfloat16)
        else:
            vr = vres_ref[0, 0].astype(jnp.bfloat16)
        mask = lax.broadcasted_iota(jnp.int32, (1, res_n), 1) < rl_ref[b]
        update(kr, vr, row_mask=mask)

    @pl.when(j == bps)
    def _finalize():
        finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr)


def _kernel_standard(pt, pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres,
                     o, lse, m, l, acc, **kw_args):
    _paged_body(pt, pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres,
                o, lse, m, l, acc, **kw_args)


def _kernel_shared(pt, pb, rl, q, kw, ks, kz, kres, o, lse, m, l, acc,
                   **kw_args):
    _paged_body(pt, pb, rl, q, kw, ks, kz, None, None, None, kres, None,
                o, lse, m, l, acc, **kw_args)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_n", "sm_scale", "k_gran", "shared_kv",
                     "d_v", "num_splits", "interpret"),
)
def paged_bitdecode_attention_pallas(
    q,             # [B, H, g, d_k]  (pre-padded)
    kw_pool,       # int32 [P, H, npr, d_k]
    k_scale_pool,  # [P, H, d_k] (channel) or [P, H, block_n]
    k_zero_pool,
    vw_pool,       # int32 [P, H, npr, d_v]; None when shared_kv
    v_scale_pool,  # [P, H, block_n]; None when shared_kv
    v_zero_pool,
    k_res, v_res,  # [B, H, res_n, d]; v_res None when shared_kv
    page_table,    # int32 [B, nb_max]
    pack_blocks, res_len,
    *,
    bits: int, block_n: int, sm_scale: float, k_gran: str,
    shared_kv: bool = False, d_v: int | None = None,
    num_splits: int = 1, interpret: bool,
):
    """Returns per-split partials (o [S,B,H,g,d_v], lse [S,B,H,g])."""
    b, h, g, d_k = q.shape
    _, _, npr, _ = kw_pool.shape
    if not shared_kv:
        d_v = vw_pool.shape[-1]
    nb = page_table.shape[1]
    res_n = k_res.shape[2]
    num_splits = max(1, min(num_splits, nb))
    bps = -(-nb // num_splits)
    n_steps = bps + 1

    def page(s, j, pt_ref, b_):
        # page id for grid step (s, j) of sequence b (clamped for the
        # residual/tail steps so the prefetch DMA stays in range)
        return pt_ref[b_, jnp.minimum(s * bps + j, nb - 1)]

    q_spec = pl.BlockSpec((1, 1, g, d_k), lambda i, hh, s, j, *_: (i, hh, 0, 0))
    kw_spec = pl.BlockSpec(
        (1, 1, npr, d_k), lambda i, hh, s, j, pt, pb, rl: (page(s, j, pt, i), hh, 0, 0)
    )
    kp_last = d_k if k_gran == "channel" else block_n
    kp_spec = pl.BlockSpec(
        (1, 1, kp_last), lambda i, hh, s, j, pt, pb, rl: (page(s, j, pt, i), hh, 0)
    )
    res_spec_k = pl.BlockSpec(
        (1, 1, res_n, d_k), lambda i, hh, s, j, *_: (i, hh, 0, 0))

    in_specs = [q_spec, kw_spec, kp_spec, kp_spec]
    operands = [q, kw_pool, k_scale_pool, k_zero_pool]
    if not shared_kv:
        vw_spec = pl.BlockSpec(
            (1, 1, npr, d_v), lambda i, hh, s, j, pt, pb, rl: (page(s, j, pt, i), hh, 0, 0)
        )
        vp_spec = pl.BlockSpec(
            (1, 1, block_n), lambda i, hh, s, j, pt, pb, rl: (page(s, j, pt, i), hh, 0)
        )
        res_spec_v = pl.BlockSpec(
            (1, 1, res_n, d_v), lambda i, hh, s, j, *_: (i, hh, 0, 0))
        in_specs += [vw_spec, vp_spec, vp_spec, res_spec_k, res_spec_v]
        operands += [vw_pool, v_scale_pool, v_zero_pool, k_res, v_res]
        kernel = _kernel_standard
    else:
        in_specs += [res_spec_k]
        operands += [k_res]
        kernel = _kernel_shared

    out_specs = [
        pl.BlockSpec((1, 1, 1, g, d_v), lambda i, hh, s, j, *_: (s, i, hh, 0, 0)),
        pl.BlockSpec((1, 1, 1, g), lambda i, hh, s, j, *_: (s, i, hh, 0)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, num_splits, n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d_v), jnp.float32),
        ],
    )
    body = functools.partial(
        kernel, bits=bits, block_n=block_n, bps=bps,
        num_splits=num_splits, res_n=res_n, sm_scale=sm_scale, k_gran=k_gran,
        shared_kv=shared_kv, d_v=d_v,
    )
    out, lse = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_splits, b, h, g, d_v), jnp.float32),
            jax.ShapeDtypeStruct((num_splits, b, h, g), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
    )(page_table.astype(jnp.int32), pack_blocks.astype(jnp.int32),
      res_len.astype(jnp.int32), *operands)
    return out, lse
