"""Public entry point for paged low-bit decode attention (Page setting).

``shared_kv=True`` is the MLA latent-cache mode: the pools hold a single
quantized latent stream (V-side pools and residual are ``None``), the kernel
reads each page once and slices the V tile out of the dequantized K tile —
the paged twin of ``kernels/bitdecode``'s shared mode, same split-KV grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitdecode import kernel as bd_kernel
from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.paged_bitdecode import kernel as _kernel
from repro.kernels.paged_bitdecode import ref as _ref


def _round_up(x, m):
    return -(-x // m) * m


def paged_bitdecode_attention(
    q,
    kw_pool, k_scale_pool, k_zero_pool,
    vw_pool, v_scale_pool, v_zero_pool,
    k_res, v_res,
    page_table, pack_blocks, res_len,
    *,
    bits: int, block_n: int = 128, sm_scale: float | None = None,
    k_gran: str = "channel", shared_kv: bool = False, d_v: int | None = None,
    impl: str = "auto",
    num_splits: int | str | None = "auto", return_lse: bool = False,
    draft_bits: int | None = None,
):
    b, h, g, d_k = q.shape
    if shared_kv:
        if d_v is None:
            raise ValueError("shared_kv requires d_v")
    else:
        d_v = vw_pool.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    if draft_bits is not None and draft_bits >= bits:
        draft_bits = None  # full-fidelity read: identical to the normal path
    if draft_bits is not None:
        if impl == "pallas":
            raise ValueError(
                "draft_bits (speculative draft read) has no Pallas kernel; "
                "use impl='xla' or 'auto'"
            )
        impl = "xla"
    elif impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if num_splits in (None, "auto") and impl == "xla":
        num_splits = 1  # splitting only pays on the Pallas grid (see bd_ops)
    else:
        num_splits = bd_ops.resolve_num_splits(num_splits, b, h, page_table.shape[1])
    if impl == "xla":
        out, lse = _ref.paged_bitdecode_attention_ref(
            q, kw_pool, k_scale_pool, k_zero_pool, vw_pool, v_scale_pool,
            v_zero_pool, k_res, v_res, page_table, pack_blocks, res_len,
            bits=bits, block_n=block_n, sm_scale=sm_scale, k_gran=k_gran,
            shared_kv=shared_kv, d_v=d_v, num_splits=num_splits,
            draft_bits=draft_bits,
        )
        return (out, lse) if return_lse else out
    if impl != "pallas":
        raise ValueError(impl)

    g_p, dk_p = max(8, _round_up(g, 8)), _round_up(d_k, 128)

    def pad(x, axis_pads):
        cfg = [(0, 0)] * x.ndim
        for ax, p in axis_pads:
            cfg[ax] = (0, p)
        return jnp.pad(x, cfg) if any(p for _, p in axis_pads) else x

    q_p = pad(q, [(2, g_p - g), (3, dk_p - d_k)])
    kw_p = pad(kw_pool, [(3, dk_p - d_k)])
    if k_gran == "channel" and dk_p != d_k:
        ones = jnp.ones(k_scale_pool.shape[:-1] + (dk_p - d_k,), k_scale_pool.dtype)
        ks_p = jnp.concatenate([k_scale_pool, ones], axis=-1)
        kz_p = pad(k_zero_pool, [(2, dk_p - d_k)])
    else:
        ks_p, kz_p = k_scale_pool, k_zero_pool
    kres_p = pad(k_res, [(3, dk_p - d_k)])
    if shared_kv:
        # the V tile is a channel slice of the dequantized K tile; it must
        # stay a lane-aligned slice of the (padded) latent width
        if d_v % 128:
            raise ValueError(f"shared_kv requires d_v % 128 == 0, got {d_v}")
        vw_p = vs_p = vz_p = vres_p = None
        dv_eff = d_v
    else:
        dv_p = _round_up(d_v, 128)
        vw_p = pad(vw_pool, [(3, dv_p - d_v)])
        vs_p, vz_p = v_scale_pool, v_zero_pool
        vres_p = pad(v_res, [(3, dv_p - d_v)])
        dv_eff = dv_p

    o_parts, lse_parts = _kernel.paged_bitdecode_attention_pallas(
        q_p, kw_p, ks_p, kz_p, vw_p, vs_p, vz_p,
        kres_p, vres_p, page_table, pack_blocks, res_len,
        bits=bits, block_n=block_n, sm_scale=float(sm_scale), k_gran=k_gran,
        shared_kv=shared_kv, d_v=dv_eff if shared_kv else None,
        num_splits=num_splits, interpret=jax.default_backend() != "tpu",
    )
    if o_parts.shape[0] == 1:  # unsplit: partials are already the answer
        out, lse = o_parts[0], lse_parts[0]
    else:
        out, lse = bd_kernel.merge_partials(o_parts, lse_parts)
    out = out[:, :, :g, :d_v]
    lse = lse[:, :, :g]
    return (out, lse) if return_lse else out
