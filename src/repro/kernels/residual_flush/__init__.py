from repro.kernels.residual_flush.ops import residual_flush  # noqa: F401
