"""Pure-jnp oracle for the fused residual flush: the select-based
block-granularity commit that ``qcache.append_decode`` used before the
kernel existed (quantize the residual, read-modify-write exactly one packed
block per sequence, select against ``full``).  Kept verbatim as the ``xla``
impl and the parity reference for the Pallas path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import layout, quantizer


def residual_flush_ref(
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    full,
    dest_block,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool,
):
    """Same contract as :func:`..kernel.residual_flush_pallas`.

    kw: int32 [B, H, nb, npr, d_k]; k_res: [B, H, block_n, d_k];
    full/dest_block: int32 [B].  Returns the six packed arrays (V side None
    when ``shared_kv``); sequences with ``full[b] == 0`` are unchanged.
    """
    param_dtype = k_scale.dtype
    if block_n != layout.words_per_block(block_n, bits) * layout.packing_ratio(bits):
        raise ValueError(f"block_n={block_n} violates the layout invariant")

    def one(kw, ks, kz, vw, vs, vz, kres, vres, fl, pb):
        # commit at BLOCK granularity: dynamic_slice one block, select, write
        # back — never a whole-array jnp.where (that would copy the full
        # per-layer cache on every invocation)
        def commit(dst, upd, idx):
            cur = lax.dynamic_slice(dst, idx, upd.shape)
            sel = jnp.where(fl != 0, upd, cur)
            return lax.dynamic_update_slice(dst, sel, idx)

        # kres [H, block_n, d] -> words [H, npr, d]; insert the block dim
        w, s, z = quantizer.quantize_and_pack(
            kres, bits, k_gran, param_dtype=param_dtype
        )
        kw = commit(kw, w[:, None], (0, pb, 0, 0))
        ks = commit(ks, s[:, None], (0, pb, 0))
        kz = commit(kz, z[:, None], (0, pb, 0))
        if not shared_kv:
            wv, sv, zv = quantizer.quantize_and_pack(
                vres, bits, "tensor", param_dtype=param_dtype
            )
            vw = commit(vw, wv[:, None], (0, pb, 0, 0))
            vs = commit(vs, sv[:, None], (0, pb, 0))
            vz = commit(vz, zv[:, None], (0, pb, 0))
        return kw, ks, kz, vw, vs, vz

    if shared_kv:
        dummy = jnp.zeros((kw.shape[0],), jnp.int32)
        kw, ks, kz, _, _, _ = jax.vmap(
            lambda kw, ks, kz, kres, fl, pb, _d: one(
                kw, ks, kz, None, None, None, kres, None, fl, pb
            )
        )(kw, k_scale, k_zero, k_res, full, dest_block, dummy)
        return kw, ks, kz, None, None, None
    return jax.vmap(one)(
        kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res, full, dest_block
    )


def paged_residual_flush_ref(
    kw_pool,
    k_scale_pool,
    k_zero_pool,
    vw_pool,
    v_scale_pool,
    v_zero_pool,
    k_res,
    v_res,
    full,
    dest_page,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool = False,
):
    """Oracle for :func:`..kernel.paged_residual_flush_pallas`: quantize every
    residual, gather the current destination pages, select against ``full``,
    scatter back.  Same injectivity contract as the kernel: ``dest_page``
    entries must be pairwise distinct (non-flushing sequences point at their
    reserved per-slot scratch page), so the scatter has no duplicate indices.

    kw_pool: int32 [P, H, npr, d_k]; k_res: [B, H, block_n, d_k];
    full/dest_page: int32 [B].  Returns the six updated pool arrays (V side
    ``None`` when ``shared_kv`` — the MLA latent pools have no V stream).
    """
    param_dtype = k_scale_pool.dtype
    if block_n != layout.words_per_block(block_n, bits) * layout.packing_ratio(bits):
        raise ValueError(f"block_n={block_n} violates the layout invariant")
    dest = jnp.minimum(dest_page.astype(jnp.int32), kw_pool.shape[0] - 1)
    fl = full != 0

    w, s, z = jax.vmap(
        lambda r: quantizer.quantize_and_pack(r, bits, k_gran, param_dtype=param_dtype)
    )(k_res)

    def commit(pool, new):
        cur = jnp.take(pool, dest, axis=0)
        keep = fl.reshape((-1,) + (1,) * (new.ndim - 1))
        return pool.at[dest].set(jnp.where(keep, new.astype(pool.dtype), cur))

    if shared_kv:
        return (
            commit(kw_pool, w),
            commit(k_scale_pool, s),
            commit(k_zero_pool, z),
            None, None, None,
        )

    wv, sv, zv = jax.vmap(
        lambda r: quantizer.quantize_and_pack(r, bits, "tensor", param_dtype=param_dtype)
    )(v_res)
    return (
        commit(kw_pool, w),
        commit(k_scale_pool, s),
        commit(k_zero_pool, z),
        commit(vw_pool, wv),
        commit(v_scale_pool, sv),
        commit(v_zero_pool, zv),
    )
