"""Pallas TPU kernel: fused residual flush — the paper's Residual Kernel
proper (§V-B), decode-time face.

``qcache.append_decode`` keeps the newest tokens in a bf16 residual buffer
and must, exactly once every ``block_n`` tokens, quantize that block and
commit it into the packed low-bit cache.  This kernel does the whole flush
in one pass per ``(batch, head)``:

  1. the residual tile is DMA'd HBM→VMEM once;
  2. min/max stats, scale/zero, round/clip and the strided bit-pack all run
     in registers (``kv_quant.kernel.quant_block_tile`` — the same code the
     prefill-time kernel uses, so flushed blocks are bitwise identical to
     prefill-quantized ones);
  3. the packed words + params are written *directly into the cache* via
     ``input_output_aliases``: the packed arrays are donated, the output
     BlockSpec index map reads the per-sequence destination block
     ``dest_block[b]`` from scalar prefetch, and only that one block is
     touched — no whole-cache copy, no select.

Per-sequence gating: ``full[b]`` (scalar prefetch) marks sequences whose
residual just filled.  Programs for non-full sequences copy their (aliased)
input block back unchanged — a one-block VMEM round-trip, only ever paid
when *some other* sequence in the batch flushes, because the caller wraps
the whole kernel invocation in ``lax.cond(any(full), ...)`` and skips it
entirely on the per-token hot path.

Constraints (TPU, non-interpret): ``d % 128 == 0`` (the aliased cache cannot
be lane-padded in place — ops.py falls back to the XLA path otherwise) and
``block_n % (32 // bits) == 0`` (layout invariant).

The paged variant (:func:`paged_residual_flush_pallas`) commits through a
page table instead: the destination is a *pool page* index (``dest_page[b]``,
scalar prefetch) into the shared ``[P, H, ...]`` pools rather than a block of
sequence ``b``'s own cache.  Same aliasing trick, one extra invariant: the
per-sequence destinations must be pairwise distinct, because two grid rows
writing the same pool page would race.  Callers guarantee it by routing
non-flushing sequences to a reserved per-slot scratch page (pages
``[0, B)`` of every pool — see serve/pages.py); flushing sequences always
own distinct allocated pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_quant.kernel import quant_block_tile

try:  # jax >= 0.7 renamed TPUCompilerParams
    _CompilerParams = pltpu.CompilerParams
except AttributeError:  # pragma: no cover
    _CompilerParams = pltpu.TPUCompilerParams


def aliased_minor_dims(d_k, d_v, block_n, k_gran, shared_kv) -> list[int]:
    """Minor (lane) dims of every in-place aliased output: the packed words'
    head dims plus the block_n-wide rows of tensor-granularity params.  All
    must be 128-aligned on TPU (the aliased cache cannot be lane-padded in
    place); shared between the kernel's trace-time check and ops.py's 'auto'
    dispatch so the two never drift."""
    minor = [d_k] + ([block_n] if k_gran == "tensor" else [])
    if not shared_kv:
        minor += [d_v, block_n]
    return minor


def _body(
    full_ref,
    dest_ref,
    kres_ref,
    *refs,
    bits,
    k_gran,
    shared_kv,
    param_dtype,
):
    if shared_kv:
        (kw_in, ks_in, kz_in, kw_out, ks_out, kz_out) = refs
        vres_ref = vw_in = vs_in = vz_in = vw_out = vs_out = vz_out = None
    else:
        (vres_ref, kw_in, ks_in, kz_in, vw_in, vs_in, vz_in,
         kw_out, ks_out, kz_out, vw_out, vs_out, vz_out) = refs
    b = pl.program_id(0)
    full = full_ref[b] != 0

    @pl.when(full)
    def _flush():
        k = kres_ref[0, 0].astype(jnp.float32)  # (block_n, d_k)
        w, s, z = quant_block_tile(
            k, bits=bits, granularity=k_gran, param_dtype=param_dtype
        )
        kw_out[0, 0, 0] = w
        ks_out[0, 0, 0] = s
        kz_out[0, 0, 0] = z
        if not shared_kv:
            v = vres_ref[0, 0].astype(jnp.float32)
            wv, sv, zv = quant_block_tile(
                v, bits=bits, granularity="tensor", param_dtype=param_dtype
            )
            vw_out[0, 0, 0] = wv
            vs_out[0, 0, 0] = sv
            vz_out[0, 0, 0] = zv

    @pl.when(jnp.logical_not(full))
    def _keep():
        # the output VMEM block must be written every grid step (it is DMA'd
        # back over the aliased cache block); restore the fetched input
        kw_out[0, 0, 0] = kw_in[0, 0, 0]
        ks_out[0, 0, 0] = ks_in[0, 0, 0]
        kz_out[0, 0, 0] = kz_in[0, 0, 0]
        if not shared_kv:
            vw_out[0, 0, 0] = vw_in[0, 0, 0]
            vs_out[0, 0, 0] = vs_in[0, 0, 0]
            vz_out[0, 0, 0] = vz_in[0, 0, 0]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_n", "k_gran", "shared_kv", "interpret"),
)
def residual_flush_pallas(
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    full,
    dest_block,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool,
    interpret: bool,
):
    """Commit ``k_res[b]``/``v_res[b]`` into packed block ``dest_block[b]``
    of every sequence with ``full[b] != 0``; other sequences' caches pass
    through untouched.  Returns the updated packed arrays
    ``(kw, k_scale, k_zero, vw, v_scale, v_zero)`` (None V-side when
    ``shared_kv``), aliased in place on TPU.
    """
    b, h, nb, npr, d_k = kw.shape
    param_dtype = k_scale.dtype
    if not interpret:
        minor = aliased_minor_dims(
            d_k, None if shared_kv else vw.shape[-1], block_n, k_gran, shared_kv
        )
        if any(m % 128 for m in minor):
            raise ValueError(
                "residual_flush_pallas writes the cache in place and cannot "
                f"lane-pad it: minor dims {minor} must all be multiples of "
                "128 on TPU — use impl='xla' for this shape"
            )

    def dst(i, j, full_ref, dest_ref):
        # clamp keeps the DMA in range; NB a flush at pack_blocks == nb (a
        # sequence decoded past capacity) saturates here and OVERWRITES
        # block nb-1 — the same saturation the oracle's dynamic_slice
        # applies.  Callers size nb from max_seq so this is unreachable.
        return jnp.minimum(dest_ref[i], nb - 1)

    w_spec = pl.BlockSpec(
        (1, 1, 1, npr, d_k), lambda i, j, f, dr: (i, j, dst(i, j, f, dr), 0, 0)
    )
    kp_shape = (1, 1, 1, d_k) if k_gran == "channel" else (1, 1, 1, block_n)
    kp_spec = pl.BlockSpec(kp_shape, lambda i, j, f, dr: (i, j, dst(i, j, f, dr), 0))
    kres_spec = pl.BlockSpec((1, 1, block_n, d_k), lambda i, j, f, dr: (i, j, 0, 0))

    in_specs = [kres_spec]
    operands = [k_res]
    out_specs = [w_spec, kp_spec, kp_spec]
    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (kw, k_scale, k_zero)]
    if not shared_kv:
        d_v = vw.shape[-1]
        vres_spec = pl.BlockSpec(
            (1, 1, block_n, d_v), lambda i, j, f, dr: (i, j, 0, 0)
        )
        vw_spec = pl.BlockSpec(
            (1, 1, 1, npr, d_v), lambda i, j, f, dr: (i, j, dst(i, j, f, dr), 0, 0)
        )
        vp_spec = pl.BlockSpec(
            (1, 1, 1, block_n), lambda i, j, f, dr: (i, j, dst(i, j, f, dr), 0)
        )
        in_specs += [vres_spec]
        operands += [v_res]
        out_specs += [vw_spec, vp_spec, vp_spec]
        out_shape += [
            jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (vw, v_scale, v_zero)
        ]
        packed_in_specs = [w_spec, kp_spec, kp_spec, vw_spec, vp_spec, vp_spec]
        packed_operands = [kw, k_scale, k_zero, vw, v_scale, v_zero]
    else:
        packed_in_specs = [w_spec, kp_spec, kp_spec]
        packed_operands = [kw, k_scale, k_zero]
    in_specs += packed_in_specs
    operands += packed_operands

    # alias each packed input onto its output; indices count the two
    # scalar-prefetch operands (full, dest_block) and the residual inputs
    n_lead = 2 + (1 if shared_kv else 2)
    aliases = {n_lead + i: i for i in range(len(packed_operands))}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    body = functools.partial(
        _body,
        bits=bits,
        k_gran=k_gran,
        shared_kv=shared_kv,
        param_dtype=param_dtype,
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
    )(full.astype(jnp.int32), dest_block.astype(jnp.int32), *operands)
    if shared_kv:
        kw, k_scale, k_zero = out
        return kw, k_scale, k_zero, None, None, None
    return tuple(out)


def _paged_body(
    full_ref,
    dest_ref,
    kres_ref,
    *refs,
    bits,
    k_gran,
    shared_kv,
    param_dtype,
):
    if shared_kv:
        (kw_in, ks_in, kz_in, kw_out, ks_out, kz_out) = refs
        vres_ref = vw_in = vs_in = vz_in = vw_out = vs_out = vz_out = None
    else:
        (vres_ref, kw_in, ks_in, kz_in, vw_in, vs_in, vz_in,
         kw_out, ks_out, kz_out, vw_out, vs_out, vz_out) = refs
    b = pl.program_id(0)
    full = full_ref[b] != 0

    @pl.when(full)
    def _flush():
        k = kres_ref[0, 0].astype(jnp.float32)  # (block_n, d_k)
        w, s, z = quant_block_tile(
            k, bits=bits, granularity=k_gran, param_dtype=param_dtype
        )
        kw_out[0, 0] = w
        ks_out[0, 0] = s
        kz_out[0, 0] = z
        if not shared_kv:
            v = vres_ref[0, 0].astype(jnp.float32)
            wv, sv, zv = quant_block_tile(
                v, bits=bits, granularity="tensor", param_dtype=param_dtype
            )
            vw_out[0, 0] = wv
            vs_out[0, 0] = sv
            vz_out[0, 0] = zv

    @pl.when(jnp.logical_not(full))
    def _keep():
        # pool page dest_page[b] is this sequence's private scratch page (the
        # caller's injectivity contract); restore the fetched input block
        kw_out[0, 0] = kw_in[0, 0]
        ks_out[0, 0] = ks_in[0, 0]
        kz_out[0, 0] = kz_in[0, 0]
        if not shared_kv:
            vw_out[0, 0] = vw_in[0, 0]
            vs_out[0, 0] = vs_in[0, 0]
            vz_out[0, 0] = vz_in[0, 0]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_n", "k_gran", "shared_kv", "interpret"),
)
def paged_residual_flush_pallas(
    kw_pool,
    k_scale_pool,
    k_zero_pool,
    vw_pool,
    v_scale_pool,
    v_zero_pool,
    k_res,
    v_res,
    full,
    dest_page,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool = False,
    interpret: bool,
):
    """Commit ``k_res[b]``/``v_res[b]`` into pool page ``dest_page[b]`` of the
    shared ``[P, H, ...]`` page pools for every sequence with ``full[b] != 0``;
    other sequences' destination pages pass through untouched (callers point
    them at per-slot scratch pages so destinations stay pairwise distinct).
    Returns the six updated pool arrays (V side ``None`` when ``shared_kv`` —
    the MLA latent pools have no V stream), aliased in place on TPU.
    """
    n_pages, h, npr, d_k = kw_pool.shape
    b = k_res.shape[0]
    param_dtype = k_scale_pool.dtype
    if not interpret:
        minor = aliased_minor_dims(
            d_k, None if shared_kv else vw_pool.shape[-1], block_n, k_gran,
            shared_kv,
        )
        if any(m % 128 for m in minor):
            raise ValueError(
                "paged_residual_flush_pallas writes the pools in place and "
                f"cannot lane-pad them: minor dims {minor} must all be "
                "multiples of 128 on TPU — use impl='xla' for this shape"
            )

    def dst(i, j, full_ref, dest_ref):
        # clamp keeps the DMA in range; callers never pass out-of-pool pages
        return jnp.minimum(dest_ref[i], n_pages - 1)

    w_spec = pl.BlockSpec(
        (1, 1, npr, d_k), lambda i, j, f, dr: (dst(i, j, f, dr), j, 0, 0)
    )
    kp_shape = (1, 1, d_k) if k_gran == "channel" else (1, 1, block_n)
    kp_spec = pl.BlockSpec(kp_shape, lambda i, j, f, dr: (dst(i, j, f, dr), j, 0))
    kres_spec = pl.BlockSpec((1, 1, block_n, d_k), lambda i, j, f, dr: (i, j, 0, 0))

    if shared_kv:
        pool_specs = [w_spec, kp_spec, kp_spec]
        pools = [kw_pool, k_scale_pool, k_zero_pool]
        in_specs = [kres_spec] + pool_specs
        operands = [k_res] + pools
        n_lead = 3  # full, dest_page, k_res precede the aliased pools
    else:
        d_v = vw_pool.shape[-1]
        vw_spec = pl.BlockSpec(
            (1, 1, npr, d_v), lambda i, j, f, dr: (dst(i, j, f, dr), j, 0, 0)
        )
        vp_spec = pl.BlockSpec(
            (1, 1, block_n), lambda i, j, f, dr: (dst(i, j, f, dr), j, 0)
        )
        vres_spec = pl.BlockSpec(
            (1, 1, block_n, d_v), lambda i, j, f, dr: (i, j, 0, 0))
        pool_specs = [w_spec, kp_spec, kp_spec, vw_spec, vp_spec, vp_spec]
        pools = [kw_pool, k_scale_pool, k_zero_pool, vw_pool, v_scale_pool,
                 v_zero_pool]
        in_specs = [kres_spec, vres_spec] + pool_specs
        operands = [k_res, v_res] + pools
        n_lead = 4  # full, dest_page, k_res, v_res precede the aliased pools
    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in pools]

    # alias each pool input onto its output; indices count the scalar-prefetch
    # operands (full, dest_page) and the residual inputs
    aliases = {n_lead + i: i for i in range(len(pools))}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h),
        in_specs=in_specs,
        out_specs=pool_specs,
    )
    body = functools.partial(
        _paged_body, bits=bits, k_gran=k_gran, shared_kv=shared_kv,
        param_dtype=param_dtype,
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
    )(full.astype(jnp.int32), dest_page.astype(jnp.int32), *operands)
    if shared_kv:
        kw_pool, k_scale_pool, k_zero_pool = out
        return kw_pool, k_scale_pool, k_zero_pool, None, None, None
    return tuple(out)
