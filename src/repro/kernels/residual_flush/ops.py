"""Public entry point for the fused residual-flush (quantize+pack+commit)."""
from __future__ import annotations

import jax

from repro.kernels.residual_flush import kernel as _kernel
from repro.kernels.residual_flush import ref as _ref


def residual_flush(
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    full,
    dest_block,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool,
    impl: str = "auto",
):
    """Commit the bf16 residual of every sequence with ``full[b] != 0`` into
    packed block ``dest_block[b]`` of the low-bit cache.

    Arguments mirror the QuantKVCache packed/residual fields (V side None
    when ``shared_kv``); returns the six updated packed arrays.  Callers gate
    the invocation on ``jnp.any(full)`` (see ``qcache.append_decode``) so the
    per-token hot path performs no quantization work at all.

    impl: 'pallas' (single fused kernel, in-place via aliasing; interpret
    mode off-TPU), 'xla' (the select-based reference oracle), or 'auto'
    (pallas on TPU when the head dim is lane-aligned, xla otherwise — the
    aliased cache cannot be lane-padded in place, unlike quantize_kv's
    operand copy).
    """
    if impl == "auto":
        minor = _kernel.aliased_minor_dims(
            kw.shape[-1], None if shared_kv else vw.shape[-1],
            block_n, k_gran, shared_kv,
        )
        lane_ok = not any(m % 128 for m in minor)
        impl = "pallas" if jax.default_backend() == "tpu" and lane_ok else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return _kernel.residual_flush_pallas(
            kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            full, dest_block,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
            interpret=interpret,
        )
    if impl == "xla":
        return _ref.residual_flush_ref(
            kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            full, dest_block,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
        )
    raise ValueError(f"unknown impl {impl!r}")
