"""Public entry point for the fused residual-flush (quantize+pack+commit)."""
from __future__ import annotations

import jax

from repro.kernels.residual_flush import kernel as _kernel
from repro.kernels.residual_flush import ref as _ref


def residual_flush(
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    full,
    dest_block,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool,
    impl: str = "auto",
):
    """Commit the bf16 residual of every sequence with ``full[b] != 0`` into
    packed block ``dest_block[b]`` of the low-bit cache.

    Arguments mirror the QuantKVCache packed/residual fields (V side None
    when ``shared_kv``); returns the six updated packed arrays.  Callers gate
    the invocation on ``jnp.any(full)`` (see ``qcache.append_decode``) so the
    per-token hot path performs no quantization work at all.

    impl: 'pallas' (single fused kernel, in-place via aliasing; interpret
    mode off-TPU), 'xla' (the select-based reference oracle), or 'auto'
    (pallas on TPU when the head dim is lane-aligned, xla otherwise — the
    aliased cache cannot be lane-padded in place, unlike quantize_kv's
    operand copy).
    """
    if impl == "auto":
        minor = _kernel.aliased_minor_dims(
            kw.shape[-1], None if shared_kv else vw.shape[-1],
            block_n, k_gran, shared_kv,
        )
        lane_ok = not any(m % 128 for m in minor)
        impl = "pallas" if jax.default_backend() == "tpu" and lane_ok else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return _kernel.residual_flush_pallas(
            kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            full, dest_block,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
            interpret=interpret,
        )
    if impl == "xla":
        return _ref.residual_flush_ref(
            kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            full, dest_block,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
        )
    raise ValueError(f"unknown impl {impl!r}")


def paged_residual_flush(
    kw_pool,
    k_scale_pool,
    k_zero_pool,
    vw_pool,
    v_scale_pool,
    v_zero_pool,
    k_res,
    v_res,
    full,
    dest_page,
    *,
    bits: int,
    block_n: int,
    k_gran: str,
    shared_kv: bool = False,
    impl: str = "auto",
):
    """Paged face of the fused residual flush: commit the bf16 residual of
    every sequence with ``full[b] != 0`` into pool page ``dest_page[b]`` of
    the shared ``[P, H, ...]`` page pools.

    Same gating contract as :func:`residual_flush` (callers wrap the call in
    ``lax.cond(any(full))`` — see ``qcache.paged_append_decode``), plus the
    paged injectivity contract: ``dest_page`` entries must be pairwise
    distinct.  Callers satisfy it by pointing non-flushing sequences at their
    reserved per-slot scratch page (pool pages ``[0, B)``, never allocated to
    requests — serve/pages.py).  ``shared_kv`` is the MLA latent-pool mode
    (no V-side pools; V operands are ``None``).

    impl: 'pallas' | 'xla' | 'auto' (pallas on TPU when the pool minor dims
    are lane-aligned, xla otherwise — the aliased pools cannot be lane-padded
    in place, exactly like the dense flush).
    """
    if impl == "auto":
        minor = _kernel.aliased_minor_dims(
            kw_pool.shape[-1], None if shared_kv else vw_pool.shape[-1],
            block_n, k_gran, shared_kv,
        )
        lane_ok = not any(m % 128 for m in minor)
        impl = "pallas" if jax.default_backend() == "tpu" and lane_ok else "xla"
    if impl == "pallas":
        return _kernel.paged_residual_flush_pallas(
            kw_pool, k_scale_pool, k_zero_pool, vw_pool, v_scale_pool,
            v_zero_pool, k_res, v_res, full, dest_page,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
            interpret=jax.default_backend() != "tpu",
        )
    if impl == "xla":
        return _ref.paged_residual_flush_ref(
            kw_pool, k_scale_pool, k_zero_pool, vw_pool, v_scale_pool,
            v_zero_pool, k_res, v_res, full, dest_page,
            bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
        )
    raise ValueError(f"unknown impl {impl!r}")
