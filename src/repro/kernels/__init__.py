"""Pallas TPU kernels for BitDecoding.

Each kernel is a subpackage with:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, dispatch, interpret on CPU)
  ref.py    — pure-jnp oracle used by tests and as the XLA fallback path
"""
