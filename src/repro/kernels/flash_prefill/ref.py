"""Pure-jnp oracle for the flash-prefill kernel (naive causal attention with
the kernel's mixed-precision choices: bf16 operands, f32 softmax/accum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, sm_scale=None, causal=True):
    """q [B,Hq,S,d]; k,v [B,Hkv,S,d] -> (out [B,Hq,S,d] bf16, lse [B,Hq,S])."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / d**0.5
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.bfloat16), kx.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    ) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e37)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", (p / l).astype(jnp.bfloat16), vx.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    lse = (m + jnp.log(l))[..., 0]
    return out.astype(jnp.bfloat16), lse
