"""Public entry point for the flash-prefill kernel (padding + dispatch).

Forward-only: used in the prefill/serving path (no grads needed).  Training
keeps the XLA blockwise path; wiring a flash backward kernel is the natural
next perf iteration (EXPERIMENTS §Perf cells B/C discussion).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill import kernel as _kernel
from repro.kernels.flash_prefill import ref as _ref


def _round_up(x, m):
    return -(-x // m) * m


def flash_prefill_attention(
    q,  # [B, Hq, S, d]
    k,  # [B, Hkv, S, d]
    v,
    *,
    sm_scale: float | None = None,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    impl: str = "auto",
    return_lse: bool = False,
):
    b, hq, s, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / d**0.5
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        out, lse = _ref.flash_prefill_ref(q, k, v, sm_scale=sm_scale, causal=causal)
        return (out, lse) if return_lse else out
    if impl != "pallas":
        raise ValueError(impl)

    blk = max(bq, bk)
    s_pad = _round_up(s, blk)
    d_pad = _round_up(d, 128)

    def pad(x):
        cfg = [(0, 0)] * 4
        cfg[2] = (0, s_pad - s)
        cfg[3] = (0, d_pad - d)
        return jnp.pad(x, cfg) if (s_pad != s or d_pad != d) else x

    out, lse = _kernel.flash_prefill_pallas(
        pad(q).astype(jnp.bfloat16), pad(k).astype(jnp.bfloat16),
        pad(v).astype(jnp.bfloat16),
        bq=bq, bk=bk, sm_scale=float(sm_scale), causal=causal, s_valid=s,
        interpret=jax.default_backend() != "tpu",
    )
    out = out[:, :, :s, :d]
    lse = lse[:, :, :s]
    return (out, lse) if return_lse else out
