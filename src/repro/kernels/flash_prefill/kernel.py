"""Pallas TPU kernel: causal flash attention for prefill/training forward.

This is the train/prefill counterpart of the bitdecode kernel, closing the
dominant roofline gap identified in §Perf cells B/C: the XLA attention path
materializes every f32 score tile to HBM (S·block·heads per step), which the
dry-run shows is 10-20x the rest of the program's traffic.  Here score tiles
live entirely in VMEM: HBM traffic collapses to Q/K/V/O once per block pair
(K/V re-streamed per q-block — the flash tradeoff).

Grid = (B, H_q, nq, nk), nk innermost with online-softmax carries in VMEM.
GQA is handled in the BlockSpec index maps (q head h reads kv head h // g) —
the training-time face of the paper's query transformation.  Blocks above
the causal diagonal are skipped (pl.when), the diagonal block is masked with
iota comparisons.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitdecode.kernel import _CompilerParams

MASK_VALUE = -1e37


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
            *, bq, bk, nk, s_valid, sm_scale, causal):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, MASK_VALUE, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # causal block-level skip: kv block j starts after q block i ends
    live = (j * bk <= i * bq + (bq - 1)) if causal else (j >= 0)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.bfloat16)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.bfloat16)  # (bk, d)
        s = lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk) — stays in VMEM
        rows = i * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = cols < s_valid
        if causal:
            valid = valid & (cols <= rows)
        s = jnp.where(valid, s, MASK_VALUE)

        m_prev = m_scr[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next[:, :1])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = lax.dot_general(
            p.astype(jnp.bfloat16), v_ref[0, 0].astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + pv
        m_scr[...] = m_next

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, :1]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[:, 0] + jnp.log(l[:, 0])


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "sm_scale", "causal", "s_valid", "interpret"),
)
def flash_prefill_pallas(
    q,  # [B, Hq, S_pad, d]  bf16 (pre-padded: S_pad % bq == 0 == % bk, d % 128)
    k,  # [B, Hkv, S_pad, d]
    v,  # [B, Hkv, S_pad, d]
    *,
    bq: int, bk: int, sm_scale: float, causal: bool, s_valid: int,
    interpret: bool,
):
    b, hq, s_pad, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    nq, nk = s_pad // bq, s_pad // bk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, i, j: (bi, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, i, j: (bi, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    body = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, s_valid=s_valid, sm_scale=sm_scale,
        causal=causal,
    )
    out, lse = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s_pad, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((b, hq, s_pad), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
    )(q, k, v)
    return out, lse
