"""Pallas TPU kernel: fused low-bit flash-decode attention (Packing Kernel).

Grid = (B, H_kv, nb + 1): FlashDecoding-style iteration over packed KV blocks
with online-softmax carries in VMEM scratch; the final grid step processes the
half-precision *residual* buffer (paper §IV-A(2)) and normalizes.

Cooperative-unit mapping (paper §III-A):
  * unpack + dequant: shift/mask/FMA on the VPU — the CUDA-core role;
  * QK^T and PV: `lax.dot_general` with bf16 operands, f32 accumulation on
    the MXU — the Tensor-Core role;
  * Mosaic's grid pipeline double-buffers the HBM→VMEM DMA of block i+1
    against the compute of block i — the paper's cp.async/wgmma software
    pipeline (§V-C(2)) falls out of the BlockSpec machinery;
  * the online-softmax carry in VMEM scratch across sequential grid steps
    replaces the multi-warp cooperative softmax (§IV-B(2)): on TPU the KV
    blocks of one (b, h) are visited by one core, so cross-warp shared-memory
    reduction is structural rather than synchronized.

The strided packed layout (core/layout.py) makes the unpack a handful of
full-width vector ops whose output is already in natural token order inside
the (sublane, lane) tile — the ldmatrix-induced-layout analogue.

`shared_kv=True` is the MLA latent-cache mode (DeepSeek): the cache holds a
single quantized latent stream; V is a channel-slice of the dequantized K
tile, so the latent is unpacked once and feeds both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import layout

MASK_VALUE = -1e37

try:  # jax >= 0.7 renamed TPUCompilerParams
    _CompilerParams = pltpu.CompilerParams
except AttributeError:  # pragma: no cover
    _CompilerParams = pltpu.TPUCompilerParams


def _unpack(w, bits):
    """int32 (npr, d) -> int32 (block_n, d), natural token order (strided layout)."""
    shifts, mask = layout.plane_shift_mask(bits)
    planes = [(w >> s) & mask for s in shifts]
    return jnp.concatenate(planes, axis=0)


def make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale):
    """Online-softmax update closure shared by the dense and paged kernels.
    q: (g, d_k) bf16; scratch refs hold the running (m, l, acc) carries."""

    def update(k_tile, v_tile, row_mask=None):
        s = (
            lax.dot_general(
                q, k_tile, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * sm_scale
        )  # (g, n) — MXU
        if row_mask is not None:
            s = jnp.where(row_mask, s, MASK_VALUE)
        m_prev = m_scr[...]  # (g, 128) lane-replicated
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (g, 1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next[:, :1])  # (g, n)
        l_next = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = lax.dot_general(
            p.astype(jnp.bfloat16), v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (g, d_v) — MXU
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + pv
        m_scr[...] = m_next
        l_scr[...] = l_next

    return update


def dequant_tile(wq, scale, zero, k_gran):
    """(n, d) int codes + params -> bf16 tile (VPU scale-FMA)."""
    s = scale.astype(jnp.float32)
    z = zero.astype(jnp.float32)
    if k_gran == "channel":  # params per channel: (d,)
        return (wq.astype(jnp.float32) * s[None, :] + z[None, :]).astype(jnp.bfloat16)
    return (wq.astype(jnp.float32) * s[:, None] + z[:, None]).astype(jnp.bfloat16)


def finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr):
    # guard l=0 (all tokens masked, e.g. an empty split-KV shard): output
    # zeros with lse ~ -inf so the cross-chip merge weights it out exactly
    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / l[:, :1]).astype(o_ref.dtype)
    lse_ref[0, 0] = m_scr[:, 0] + jnp.log(l[:, 0])


def init_carries(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full(m_scr.shape, MASK_VALUE, jnp.float32)
    l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
    acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)


def _body(
    pb_ref,
    rl_ref,
    q_ref,
    kw_ref,
    ks_ref,
    kz_ref,
    vw_ref,
    vs_ref,
    vz_ref,
    kres_ref,
    vres_ref,
    o_ref,
    lse_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    bits,
    block_n,
    nb,
    res_n,
    sm_scale,
    k_gran,
    shared_kv,
    d_v,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_steps = nb + 1

    @pl.when(j == 0)
    def _init():
        init_carries(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.bfloat16)  # (g, d_k)
    update = make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale)

    @pl.when(jnp.logical_and(j < n_steps - 1, j < pb_ref[b]))
    def _packed_block():
        kw = kw_ref[0, 0, 0]  # (npr, d_k) int32
        kq = _unpack(kw, bits)  # (block_n, d_k) — VPU
        k_hat = dequant_tile(kq, ks_ref[0, 0, 0], kz_ref[0, 0, 0], k_gran)
        if shared_kv:
            v_hat = k_hat[:, :d_v]
        else:
            vq = _unpack(vw_ref[0, 0, 0], bits)
            v_hat = dequant_tile(vq, vs_ref[0, 0, 0], vz_ref[0, 0, 0], "tensor")
        update(k_hat, v_hat)

    @pl.when(j == n_steps - 1)
    def _residual_and_finalize():
        kr = kres_ref[0, 0].astype(jnp.bfloat16)  # (res_n, d_k)
        if shared_kv:
            vr = kres_ref[0, 0, :, :d_v].astype(jnp.bfloat16)
        else:
            vr = vres_ref[0, 0].astype(jnp.bfloat16)
        mask = lax.broadcasted_iota(jnp.int32, (1, res_n), 1) < rl_ref[b]
        update(kr, vr, row_mask=mask)
        finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr)


def _kernel_standard(pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres,
                     o, lse, m, l, acc, **kwargs):
    _body(pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres, o, lse, m, l, acc, **kwargs)


def _kernel_shared(pb, rl, q, kw, ks, kz, kres, o, lse, m, l, acc, **kwargs):
    _body(pb, rl, q, kw, ks, kz, None, None, None, kres, None, o, lse, m, l, acc,
          **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "block_n", "sm_scale", "k_gran", "shared_kv", "d_v", "interpret",
    ),
)
def bitdecode_attention_pallas(
    q,
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    pack_blocks,
    res_len,
    *,
    bits: int,
    block_n: int,
    sm_scale: float,
    k_gran: str,
    shared_kv: bool,
    d_v: int,
    interpret: bool,
):
    """Inputs must be pre-padded: g % 8 == 0, d_k % 128 == 0, d_v % 128 == 0.

    Returns (out [B,H,g,d_v] f32, lse [B,H,g] f32).
    """
    b, h, g, d_k = q.shape
    nb, npr = kw.shape[2], kw.shape[3]
    res_n = k_res.shape[2]
    n_steps = nb + 1

    def last_blk(j):
        return jnp.minimum(j, nb - 1)

    q_spec = pl.BlockSpec((1, 1, g, d_k), lambda i, hh, j, *_: (i, hh, 0, 0))
    kw_spec = pl.BlockSpec(
        (1, 1, 1, npr, d_k), lambda i, hh, j, *_: (i, hh, last_blk(j), 0, 0)
    )
    kp_shape = (1, 1, 1, d_k) if k_gran == "channel" else (1, 1, 1, block_n)
    kp_spec = pl.BlockSpec(kp_shape, lambda i, hh, j, *_: (i, hh, last_blk(j), 0))
    kres_spec = pl.BlockSpec((1, 1, res_n, d_k), lambda i, hh, j, *_: (i, hh, 0, 0))

    in_specs = [q_spec, kw_spec, kp_spec, kp_spec]
    operands = [q, kw, k_scale, k_zero]
    if not shared_kv:
        vw_spec = pl.BlockSpec(
            (1, 1, 1, npr, d_v), lambda i, hh, j, *_: (i, hh, last_blk(j), 0, 0)
        )
        vp_spec = pl.BlockSpec(
            (1, 1, 1, block_n), lambda i, hh, j, *_: (i, hh, last_blk(j), 0)
        )
        vres_spec = pl.BlockSpec(
            (1, 1, res_n, d_v), lambda i, hh, j, *_: (i, hh, 0, 0)
        )
        in_specs += [vw_spec, vp_spec, vp_spec, kres_spec, vres_spec]
        operands += [vw, v_scale, v_zero, k_res, v_res]
        kernel = _kernel_standard
    else:
        in_specs += [kres_spec]
        operands += [k_res]
        kernel = _kernel_shared

    out_specs = [
        pl.BlockSpec((1, 1, g, d_v), lambda i, hh, j, *_: (i, hh, 0, 0)),
        pl.BlockSpec((1, 1, g), lambda i, hh, j, *_: (i, hh, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, g, d_v), jnp.float32),
        jax.ShapeDtypeStruct((b, h, g), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((g, 128), jnp.float32),
        pltpu.VMEM((g, 128), jnp.float32),
        pltpu.VMEM((g, d_v), jnp.float32),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    body = functools.partial(
        kernel,
        bits=bits,
        block_n=block_n,
        nb=nb,
        res_n=res_n,
        sm_scale=sm_scale,
        k_gran=k_gran,
        shared_kv=shared_kv,
        d_v=d_v,
    )
    out, lse = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(pack_blocks.astype(jnp.int32), res_len.astype(jnp.int32), *operands)
    return out, lse
