"""Pallas TPU kernel: fused low-bit flash-decode attention (Packing Kernel),
with FlashDecoding-style split-KV sequence parallelism.

Two-phase reduction
-------------------
Phase 1 — grid = (B, H_kv, num_splits, bps + 1), bps = ceil(nb / num_splits):
each split owns a contiguous range of ``bps`` packed KV blocks and walks them
with online-softmax carries in VMEM scratch; the final grid step of the LAST
split additionally processes the half-precision *residual* buffer (paper
§IV-A(2)).  Every split finalizes into its own slot of the partials outputs
``o[num_splits, B, H, g, d_v]`` / ``lse[num_splits, B, H, g]`` — the first
three grid dimensions are independent ("parallel"), so a single-batch
long-context decode exposes ``B x H_kv x num_splits``-way parallelism instead
of the ``B x H_kv`` of the unsplit kernel (the FlashDecoding-v2 trick the
paper benchmarks against).

Phase 2 — :func:`merge_partials`, a small XLA epilogue: a logsumexp-weighted
combine of the per-split partials.  A split whose block range is entirely
beyond ``pack_blocks[b]`` never updates its carries, so ``finalize``'s l=0
guard emits lse ~ -inf and the merge weights it out *exactly* (the same
contract tests/test_splitkv_math.py pins for the cross-chip merge in
repro.dist.splitkv, which reuses this math over a mesh axis).

Cooperative-unit mapping (paper §III-A):
  * unpack + dequant: shift/mask/FMA on the VPU — the CUDA-core role;
  * QK^T and PV: `lax.dot_general` with bf16 operands, f32 accumulation on
    the MXU — the Tensor-Core role;
  * Mosaic's grid pipeline double-buffers the HBM→VMEM DMA of block i+1
    against the compute of block i — the paper's cp.async/wgmma software
    pipeline (§V-C(2)) falls out of the BlockSpec machinery;
  * the online-softmax carry in VMEM scratch across sequential grid steps
    replaces the multi-warp cooperative softmax (§IV-B(2)); the split axis
    replaces FlashDecoding's inter-CTA partials+combine.

The strided packed layout (core/layout.py) makes the unpack a handful of
full-width vector ops whose output is already in natural token order inside
the (sublane, lane) tile — the ldmatrix-induced-layout analogue.

`shared_kv=True` is the MLA latent-cache mode (DeepSeek): the cache holds a
single quantized latent stream; V is a channel-slice of the dequantized K
tile, so the latent is unpacked once and feeds both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import layout

MASK_VALUE = -1e37

try:  # jax >= 0.7 renamed TPUCompilerParams
    _CompilerParams = pltpu.CompilerParams
except AttributeError:  # pragma: no cover
    _CompilerParams = pltpu.TPUCompilerParams


def _unpack(w, bits):
    """int32 (npr, d) -> int32 (block_n, d), natural token order (strided layout)."""
    shifts, mask = layout.plane_shift_mask(bits)
    planes = [(w >> s) & mask for s in shifts]
    return jnp.concatenate(planes, axis=0)


def make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale):
    """Online-softmax update closure shared by the dense and paged kernels.
    q: (g, d_k) bf16; scratch refs hold the running (m, l, acc) carries."""

    def update(k_tile, v_tile, row_mask=None):
        s = (
            lax.dot_general(
                q, k_tile, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * sm_scale
        )  # (g, n) — MXU
        if row_mask is not None:
            s = jnp.where(row_mask, s, MASK_VALUE)
        m_prev = m_scr[...]  # (g, 128) lane-replicated
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (g, 1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next[:, :1])  # (g, n)
        l_next = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = lax.dot_general(
            p.astype(jnp.bfloat16), v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (g, d_v) — MXU
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + pv
        m_scr[...] = m_next
        l_scr[...] = l_next

    return update


def dequant_tile(wq, scale, zero, k_gran):
    """(n, d) int codes + params -> bf16 tile (VPU scale-FMA)."""
    s = scale.astype(jnp.float32)
    z = zero.astype(jnp.float32)
    if k_gran == "channel":  # params per channel: (d,)
        return (wq.astype(jnp.float32) * s[None, :] + z[None, :]).astype(jnp.bfloat16)
    return (wq.astype(jnp.float32) * s[:, None] + z[:, None]).astype(jnp.bfloat16)


def finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr):
    # guard l=0 (all tokens masked — e.g. a split whose block range lies
    # beyond pack_blocks, or an empty split-KV shard): output zeros with
    # lse ~ -inf so merge_partials / the cross-chip merge weights it out
    # exactly
    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0, 0] = (acc_scr[...] / l[:, :1]).astype(o_ref.dtype)
    lse_ref[0, 0, 0] = m_scr[:, 0] + jnp.log(l[:, 0])


def init_carries(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full(m_scr.shape, MASK_VALUE, jnp.float32)
    l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
    acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)


def merge_partials(o_parts, lse_parts, *, return_lse: bool = True):
    """Phase-2 combine of per-split flash partials (XLA epilogue).

    o_parts: [S, ..., g, d_v] per-split normalized outputs;
    lse_parts: [S, ..., g] per-split logsumexps.  Splits with no valid
    tokens carry lse ~ -inf (finalize's l=0 guard) and get weight exp(-inf)=0,
    so empty splits drop out exactly — the same lse-merge the distributed
    layer (repro.dist.splitkv) runs across a mesh axis, specified by
    tests/test_splitkv_math.py.
    """
    m = jnp.max(lse_parts, axis=0)
    w = jnp.exp(lse_parts - m[None])  # [S, ..., g]
    den = jnp.maximum(jnp.sum(w, axis=0), 1e-30)
    num = jnp.sum(w[..., None] * o_parts, axis=0)
    out = num / den[..., None]
    if not return_lse:
        return out
    return out, m + jnp.log(den)


def _body(
    pb_ref,
    rl_ref,
    q_ref,
    kw_ref,
    ks_ref,
    kz_ref,
    vw_ref,
    vs_ref,
    vz_ref,
    kres_ref,
    vres_ref,
    o_ref,
    lse_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    bits,
    block_n,
    bps,
    num_splits,
    res_n,
    sm_scale,
    k_gran,
    shared_kv,
    d_v,
):
    b = pl.program_id(0)
    s = pl.program_id(2)
    j = pl.program_id(3)
    jj = s * bps + j  # global packed-block index owned by this grid step

    @pl.when(j == 0)
    def _init():
        init_carries(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.bfloat16)  # (g, d_k)
    update = make_flash_update(q, m_scr, l_scr, acc_scr, sm_scale)

    @pl.when(jnp.logical_and(j < bps, jj < pb_ref[b]))
    def _packed_block():
        kw = kw_ref[0, 0, 0]  # (npr, d_k) int32
        kq = _unpack(kw, bits)  # (block_n, d_k) — VPU
        k_hat = dequant_tile(kq, ks_ref[0, 0, 0], kz_ref[0, 0, 0], k_gran)
        if shared_kv:
            v_hat = k_hat[:, :d_v]
        else:
            vq = _unpack(vw_ref[0, 0, 0], bits)
            v_hat = dequant_tile(vq, vs_ref[0, 0, 0], vz_ref[0, 0, 0], "tensor")
        update(k_hat, v_hat)

    # residual tail belongs to the LAST split only; every split finalizes
    # its own partials slot at its last grid step
    @pl.when(jnp.logical_and(j == bps, s == num_splits - 1))
    def _residual():
        kr = kres_ref[0, 0].astype(jnp.bfloat16)  # (res_n, d_k)
        if shared_kv:
            vr = kres_ref[0, 0, :, :d_v].astype(jnp.bfloat16)
        else:
            vr = vres_ref[0, 0].astype(jnp.bfloat16)
        mask = lax.broadcasted_iota(jnp.int32, (1, res_n), 1) < rl_ref[b]
        update(kr, vr, row_mask=mask)

    @pl.when(j == bps)
    def _finalize():
        finalize(o_ref, lse_ref, m_scr, l_scr, acc_scr)


def _kernel_standard(pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres,
                     o, lse, m, l, acc, **kwargs):
    _body(pb, rl, q, kw, ks, kz, vw, vs, vz, kres, vres, o, lse, m, l, acc, **kwargs)


def _kernel_shared(pb, rl, q, kw, ks, kz, kres, o, lse, m, l, acc, **kwargs):
    _body(pb, rl, q, kw, ks, kz, None, None, None, kres, None, o, lse, m, l, acc,
          **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "block_n", "sm_scale", "k_gran", "shared_kv", "d_v",
        "num_splits", "interpret",
    ),
)
def bitdecode_attention_pallas(
    q,
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    pack_blocks,
    res_len,
    *,
    bits: int,
    block_n: int,
    sm_scale: float,
    k_gran: str,
    shared_kv: bool,
    d_v: int,
    num_splits: int = 1,
    interpret: bool,
):
    """Inputs must be pre-padded: g % 8 == 0, d_k % 128 == 0, d_v % 128 == 0.

    Returns per-split partials (o [S,B,H,g,d_v] f32, lse [S,B,H,g] f32) with
    S = num_splits; combine with :func:`merge_partials` (exact for S = 1).
    """
    b, h, g, d_k = q.shape
    nb, npr = kw.shape[2], kw.shape[3]
    res_n = k_res.shape[2]
    num_splits = max(1, min(num_splits, nb))
    bps = -(-nb // num_splits)  # packed blocks per split
    n_steps = bps + 1

    def blk(s, j):
        # block fetched at step (s, j); clamped so the residual/tail steps
        # DMA an in-range (ignored) block
        return jnp.minimum(s * bps + j, nb - 1)

    q_spec = pl.BlockSpec((1, 1, g, d_k), lambda i, hh, s, j, *_: (i, hh, 0, 0))
    kw_spec = pl.BlockSpec(
        (1, 1, 1, npr, d_k), lambda i, hh, s, j, *_: (i, hh, blk(s, j), 0, 0)
    )
    kp_shape = (1, 1, 1, d_k) if k_gran == "channel" else (1, 1, 1, block_n)
    kp_spec = pl.BlockSpec(kp_shape, lambda i, hh, s, j, *_: (i, hh, blk(s, j), 0))
    kres_spec = pl.BlockSpec((1, 1, res_n, d_k), lambda i, hh, s, j, *_: (i, hh, 0, 0))

    in_specs = [q_spec, kw_spec, kp_spec, kp_spec]
    operands = [q, kw, k_scale, k_zero]
    if not shared_kv:
        vw_spec = pl.BlockSpec(
            (1, 1, 1, npr, d_v), lambda i, hh, s, j, *_: (i, hh, blk(s, j), 0, 0)
        )
        vp_spec = pl.BlockSpec(
            (1, 1, 1, block_n), lambda i, hh, s, j, *_: (i, hh, blk(s, j), 0)
        )
        vres_spec = pl.BlockSpec(
            (1, 1, res_n, d_v), lambda i, hh, s, j, *_: (i, hh, 0, 0)
        )
        in_specs += [vw_spec, vp_spec, vp_spec, kres_spec, vres_spec]
        operands += [vw, v_scale, v_zero, k_res, v_res]
        kernel = _kernel_standard
    else:
        in_specs += [kres_spec]
        operands += [k_res]
        kernel = _kernel_shared

    out_specs = [
        pl.BlockSpec((1, 1, 1, g, d_v), lambda i, hh, s, j, *_: (s, i, hh, 0, 0)),
        pl.BlockSpec((1, 1, 1, g), lambda i, hh, s, j, *_: (s, i, hh, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((num_splits, b, h, g, d_v), jnp.float32),
        jax.ShapeDtypeStruct((num_splits, b, h, g), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((g, 128), jnp.float32),
        pltpu.VMEM((g, 128), jnp.float32),
        pltpu.VMEM((g, d_v), jnp.float32),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, num_splits, n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    body = functools.partial(
        kernel,
        bits=bits,
        block_n=block_n,
        bps=bps,
        num_splits=num_splits,
        res_n=res_n,
        sm_scale=sm_scale,
        k_gran=k_gran,
        shared_kv=shared_kv,
        d_v=d_v,
    )
    out, lse = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
    )(pack_blocks.astype(jnp.int32), res_len.astype(jnp.int32), *operands)
    return out, lse
