"""Public entry point for low-bit fused decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitdecode import kernel as _kernel
from repro.kernels.bitdecode import ref as _ref


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def bitdecode_attention(
    q,
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    pack_blocks,
    res_len,
    *,
    bits: int,
    block_n: int = 128,
    sm_scale: float | None = None,
    k_gran: str = "channel",
    shared_kv: bool = False,
    d_v: int | None = None,
    impl: str = "auto",
    return_lse: bool = False,
):
    """Fused low-bit decode attention over (packed cache + bf16 residual).

    q: [B, H_kv, g_q, d_k] (query-transformed).  See ref.py for full shapes.
    impl: 'pallas' | 'xla' | 'auto'.  Pallas runs interpret-mode off-TPU.
    """
    b, h, g, d_k = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    if shared_kv:
        if d_v is None:
            raise ValueError("shared_kv requires d_v")
    else:
        d_v = v_res.shape[-1]

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    if impl == "xla":
        out, lse = _ref.bitdecode_attention_ref(
            q, kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            pack_blocks, res_len,
            bits=bits, block_n=block_n, sm_scale=sm_scale, k_gran=k_gran,
            shared_kv=shared_kv, d_v=d_v,
        )
        return (out, lse) if return_lse else out
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    # ---- pad to TPU tile alignment: g -> x8 sublanes, d -> x128 lanes ----
    g_p = max(8, _round_up(g, 8))
    dk_p = _round_up(d_k, 128)
    dv_p = _round_up(d_v, 128)

    def pad(x, axis_pads):
        cfg = [(0, 0)] * x.ndim
        for ax, p in axis_pads:
            cfg[ax] = (0, p)
        return jnp.pad(x, cfg) if any(p for _, p in axis_pads) else x

    q_p = pad(q, [(2, g_p - g), (3, dk_p - d_k)])
    kw_p = pad(kw, [(4, dk_p - d_k)])
    k_res_p = pad(k_res, [(3, dk_p - d_k)])
    if k_gran == "channel":
        # pad channels with scale=1 / zero=0 so dequantized padding is 0
        if dk_p != d_k:
            ones = jnp.ones(k_scale.shape[:-1] + (dk_p - d_k,), k_scale.dtype)
            k_scale_p = jnp.concatenate([k_scale, ones], axis=-1)
            k_zero_p = pad(k_zero, [(3, dk_p - d_k)])
        else:
            k_scale_p, k_zero_p = k_scale, k_zero
    else:
        k_scale_p, k_zero_p = k_scale, k_zero

    if shared_kv:
        vw_p = v_scale_p = v_zero_p = v_res_p = None
        # d_v must remain a lane-aligned slice of d_k
        if d_v % 128:
            raise ValueError(f"shared_kv requires d_v % 128 == 0, got {d_v}")
        dv_eff = d_v
    else:
        vw_p = pad(vw, [(4, dv_p - d_v)])
        v_scale_p, v_zero_p = v_scale, v_zero
        v_res_p = pad(v_res, [(3, dv_p - d_v)])
        dv_eff = dv_p

    out, lse = _kernel.bitdecode_attention_pallas(
        q_p, kw_p, k_scale_p, k_zero_p, vw_p, v_scale_p, v_zero_p,
        k_res_p, v_res_p, pack_blocks, res_len,
        bits=bits, block_n=block_n, sm_scale=float(sm_scale), k_gran=k_gran,
        shared_kv=shared_kv, d_v=dv_eff,
        interpret=jax.default_backend() != "tpu",
    )
    out = out[:, :, :g, :d_v]
    lse = lse[:, :, :g]
    return (out, lse) if return_lse else out
