"""Public entry point for low-bit fused decode attention.

Split-KV (FlashDecoding) dispatch lives here: ``num_splits`` partitions the
packed-block axis into contiguous ranges that the Pallas grid processes as an
extra parallel dimension (kernel.py phase 1), combined by the logsumexp merge
epilogue (kernel.merge_partials, phase 2).  ``num_splits="auto"`` applies the
serving heuristic: split only when the natural ``B x H_kv`` grid parallelism
underfills the chip's cores AND the sequence is long enough that each split
still amortizes its setup over >= 2 packed blocks — i.e. exactly the paper's
headline long-context small-batch decode regime.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.bitdecode import kernel as _kernel
from repro.kernels.bitdecode import ref as _ref

# Parallel grid slots the attached accelerators can fill concurrently.  TPU
# Mosaic maps "parallel" grid dims over Megacore (2 cores/chip); we target a
# little above that per device so splits also cover pipeline bubbles.  The
# REPRO_SPLITKV_CORES env var overrides the whole product (calibration /
# GPU Pallas / interpret-mode studies); unset, the default scales with the
# process's device count.
_CORES_PER_DEVICE = 4  # ~2 physical cores x2 oversubscription
_MAX_SPLITS = 16
_cores_cache: int | None = None


def default_splitkv_cores() -> int:
    """Parallel-slot target for the split heuristic: REPRO_SPLITKV_CORES if
    set, else ``jax.device_count() * 4``.  Resolved lazily (device_count
    initializes the backend) and cached for the process lifetime."""
    global _cores_cache
    env = os.environ.get("REPRO_SPLITKV_CORES")
    if env:
        return int(env)
    if _cores_cache is None:
        _cores_cache = max(1, jax.device_count() * _CORES_PER_DEVICE)
    return _cores_cache


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def auto_num_splits(b: int, h_kv: int, nb: int, *, cores: int | None = None) -> int:
    """Split-KV heuristic: 1 unless B*H_kv underfills the cores and the
    packed sequence is long enough for every split to own >= 2 blocks."""
    cores = default_splitkv_cores() if cores is None else cores
    if b * h_kv >= cores or nb < 4:
        return 1
    want = -(-cores // (b * h_kv))  # splits needed to fill the cores
    return max(1, min(want, nb // 2, _MAX_SPLITS))


def resolve_num_splits(num_splits, b: int, h_kv: int, nb: int) -> int:
    if num_splits in (None, "auto"):
        return auto_num_splits(b, h_kv, nb)
    s = int(num_splits)
    if s < 1:
        raise ValueError(f"num_splits must be >= 1, got {num_splits}")
    return max(1, min(s, nb)) if nb else 1


def bitdecode_attention(
    q,
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    pack_blocks,
    res_len,
    *,
    bits: int,
    block_n: int = 128,
    sm_scale: float | None = None,
    k_gran: str = "channel",
    shared_kv: bool = False,
    d_v: int | None = None,
    impl: str = "auto",
    num_splits: int | str | None = "auto",
    return_lse: bool = False,
    draft_bits: int | None = None,
):
    """Fused low-bit decode attention over (packed cache + bf16 residual).

    q: [B, H_kv, g_q, d_k] (query-transformed).  See ref.py for full shapes.
    impl: 'pallas' | 'xla' | 'auto'.  Pallas runs interpret-mode off-TPU.
    num_splits: 'auto' | int — split-KV partitions of the packed-block axis;
    the result is policy-equivalent to num_splits=1 (logsumexp merge).
    draft_bits: speculative draft read — dequantize the packed cache at a
    truncated bit-width (XLA reference path only; 'auto' resolves to 'xla',
    explicit 'pallas' raises).
    """
    b, h, g, d_k = q.shape
    nb = kw.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    if shared_kv:
        if d_v is None:
            raise ValueError("shared_kv requires d_v")
    else:
        d_v = v_res.shape[-1]

    if draft_bits is not None and draft_bits >= bits:
        draft_bits = None  # full-fidelity read: identical to the normal path
    if draft_bits is not None:
        if impl == "pallas":
            raise ValueError(
                "draft_bits (speculative draft read) has no Pallas kernel; "
                "use impl='xla' or 'auto'"
            )
        impl = "xla"
    elif impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    # the auto heuristic targets the Pallas grid; the XLA ref path gains
    # nothing from splitting (it *multiplies* work by the split count), so
    # auto resolves to 1 there — explicit integers are always honored (the
    # split oracle / parity harness)
    if num_splits in (None, "auto") and impl == "xla":
        num_splits = 1
    else:
        num_splits = resolve_num_splits(num_splits, b, h, nb)

    if impl == "xla":
        out, lse = _ref.bitdecode_attention_ref(
            q, kw, k_scale, k_zero, vw, v_scale, v_zero, k_res, v_res,
            pack_blocks, res_len,
            bits=bits, block_n=block_n, sm_scale=sm_scale, k_gran=k_gran,
            shared_kv=shared_kv, d_v=d_v, num_splits=num_splits,
            draft_bits=draft_bits,
        )
        return (out, lse) if return_lse else out
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    # ---- pad to TPU tile alignment: g -> x8 sublanes, d -> x128 lanes ----
    g_p = max(8, _round_up(g, 8))
    dk_p = _round_up(d_k, 128)
    dv_p = _round_up(d_v, 128)

    def pad(x, axis_pads):
        cfg = [(0, 0)] * x.ndim
        for ax, p in axis_pads:
            cfg[ax] = (0, p)
        return jnp.pad(x, cfg) if any(p for _, p in axis_pads) else x

    q_p = pad(q, [(2, g_p - g), (3, dk_p - d_k)])
    kw_p = pad(kw, [(4, dk_p - d_k)])
    k_res_p = pad(k_res, [(3, dk_p - d_k)])
    if k_gran == "channel":
        # pad channels with scale=1 / zero=0 so dequantized padding is 0
        if dk_p != d_k:
            ones = jnp.ones(k_scale.shape[:-1] + (dk_p - d_k,), k_scale.dtype)
            k_scale_p = jnp.concatenate([k_scale, ones], axis=-1)
            k_zero_p = pad(k_zero, [(3, dk_p - d_k)])
        else:
            k_scale_p, k_zero_p = k_scale, k_zero
    else:
        k_scale_p, k_zero_p = k_scale, k_zero

    if shared_kv:
        vw_p = v_scale_p = v_zero_p = v_res_p = None
        # d_v must remain a lane-aligned slice of d_k
        if d_v % 128:
            raise ValueError(f"shared_kv requires d_v % 128 == 0, got {d_v}")
        dv_eff = d_v
    else:
        vw_p = pad(vw, [(4, dv_p - d_v)])
        v_scale_p, v_zero_p = v_scale, v_zero
        v_res_p = pad(v_res, [(3, dv_p - d_v)])
        dv_eff = dv_p

    o_parts, lse_parts = _kernel.bitdecode_attention_pallas(
        q_p, kw_p, k_scale_p, k_zero_p, vw_p, v_scale_p, v_zero_p,
        k_res_p, v_res_p, pack_blocks, res_len,
        bits=bits, block_n=block_n, sm_scale=float(sm_scale), k_gran=k_gran,
        shared_kv=shared_kv, d_v=dv_eff, num_splits=num_splits,
        interpret=jax.default_backend() != "tpu",
    )
    if o_parts.shape[0] == 1:  # unsplit: partials are already the answer
        out, lse = o_parts[0], lse_parts[0]
    else:
        out, lse = _kernel.merge_partials(o_parts, lse_parts)
    out = out[:, :, :g, :d_v]
    lse = lse[:, :, :g]
    return (out, lse) if return_lse else out
