"""Pure-jnp oracle for the fused low-bit decode-attention (Packing) kernel.

Also serves as the XLA fallback path on CPU and the dry-run lowering target:
it performs the *same* work (unpack, dequant, QK^T, online-softmax-equivalent
masked softmax, PV) as the Pallas kernel, so ``cost_analysis()`` of a program
built on this path reflects the mixed-precision pipeline honestly.

``num_splits > 1`` runs the split-KV (FlashDecoding) semantics: per-split
masked-softmax partials over contiguous packed-block ranges (residual tail
owned by the last split), combined with the logsumexp merge — the oracle for
both the in-kernel split grid and the cross-chip repro.dist.splitkv layer.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import layout, quantizer

MASK_VALUE = -1e37


def _dequant_blocks(words, scale, zero, bits, granularity, dtype=jnp.bfloat16,
                    draft_bits=None):
    """words [B,H,nb,npr,d] -> [B,H,nb*block_n,d] in natural token order.

    ``draft_bits`` (speculative draft read, QuantSpec-style): dequantize as if
    only the top ``draft_bits`` of each ``bits``-bit code had been stored —
    ``q >> (bits - draft_bits)`` against a scale widened by ``2^(bits -
    draft_bits)``.  Same packed words, same (scale, zero) metadata, no second
    cache: just a cheaper *read* of the committed pool that the verify pass
    re-reads at full fidelity.
    """
    if draft_bits is not None and draft_bits < bits:
        shift = bits - draft_bits
        q = layout.unpack_strided(words, bits) >> shift
        x = quantizer.dequantize_block(
            q, scale.astype(jnp.float32) * (1 << shift), zero, granularity,
            dtype=dtype,
        )
    else:
        x = quantizer.unpack_and_dequantize(words, scale, zero, bits, granularity, dtype=dtype)
    b, h, nb, n, d = x.shape
    return x.reshape(b, h, nb * n, d)


def _softmax_partial(scores, v_all):
    """Masked-softmax partial over the last (token) axis: (o, lse).

    Fully-masked rows (empty split) produce o = 0 and lse ~ -inf — the same
    l=0 guard the Pallas ``finalize`` applies, so the merge drops them."""
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = lax.dot_general(
        p.astype(jnp.bfloat16),
        v_all,
        (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32,
    )
    out = out / l.astype(jnp.float32)
    lse = (m + jnp.log(l))[..., 0]
    return out, lse


def bitdecode_attention_ref(
    q,
    kw,
    k_scale,
    k_zero,
    vw,
    v_scale,
    v_zero,
    k_res,
    v_res,
    pack_blocks,
    res_len,
    *,
    bits: int,
    block_n: int = 128,
    sm_scale: float | None = None,
    k_gran: str = "channel",
    shared_kv: bool = False,
    d_v: int | None = None,
    num_splits: int = 1,
    draft_bits: int | None = None,
):
    """Low-bit flash-decode attention, reference semantics.

    q: [B, H_kv, g_q, d_k]    (already query-transformed: g_q = h_q / h_kv)
    kw: int32 [B, H_kv, nb, npr, d_k]; k params per k_gran.
    vw: int32 [B, H_kv, nb, npr, d_v] + per-token params [B,H,nb,block_n]
        (ignored when shared_kv: V is the first d_v channels of dequant K —
        the MLA latent-cache mode).
    k_res/v_res: bf16 [B, H_kv, N_r, d_k/d_v]; pack_blocks/res_len: int32 [B].
    num_splits: split-KV partition count (1 = classic single-pass softmax).
    draft_bits: speculative draft read — dequantize the packed cache at a
    truncated bit-width (see :func:`_dequant_blocks`); the bf16 residual is
    read at full fidelity either way.

    Returns (out [B,H,g,d_v] f32, lse [B,H,g] f32).
    """
    b, h, g, d_k = q.shape
    nb = kw.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    if shared_kv:
        assert d_v is not None
    else:
        d_v = v_res.shape[-1]
    if draft_bits is not None and not 1 <= draft_bits <= bits:
        raise ValueError(f"draft_bits={draft_bits} outside [1, bits={bits}]")

    k_hat = _dequant_blocks(kw, k_scale, k_zero, bits, k_gran,
                            draft_bits=draft_bits)  # [B,H,Sp,dk]
    if shared_kv:
        v_hat = k_hat[..., :d_v]
        if v_res is None:  # latent mode: residual V is the slice of residual K
            v_res = k_res[..., :d_v]
    else:
        v_hat = _dequant_blocks(vw, v_scale, v_zero, bits, "tensor",
                                draft_bits=draft_bits)

    k_all = jnp.concatenate([k_hat, k_res.astype(k_hat.dtype)], axis=2)
    v_all = jnp.concatenate([v_hat, v_res.astype(v_hat.dtype)], axis=2)

    s_pack = nb * block_n
    res_n = k_res.shape[2]
    t = jnp.arange(s_pack + res_n, dtype=jnp.int32)
    valid_pack = t[None, :] < (pack_blocks[:, None] * block_n)
    in_res = t[None, :] >= s_pack
    valid_res = in_res & (t[None, :] - s_pack < res_len[:, None])
    valid = jnp.where(in_res, valid_res, valid_pack)  # [B, S_tot]

    scores = lax.dot_general(
        q.astype(jnp.bfloat16),
        k_all,
        (((3,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32,
    ) * sm_scale  # [B,H,g,S_tot]

    num_splits = max(1, min(num_splits, nb))
    if num_splits == 1:
        scores = jnp.where(valid[:, None, None, :], scores, MASK_VALUE)
        return _softmax_partial(scores, v_all)

    # split-KV oracle: split i owns packed blocks [i*bps, (i+1)*bps); the
    # residual tail rides with the last split.  Partials per split, then the
    # logsumexp merge (identical math to kernel.merge_partials).
    bps = -(-nb // num_splits)
    parts_o, parts_lse = [], []
    for i in range(num_splits):
        lo, hi = i * bps * block_n, min((i + 1) * bps, nb) * block_n
        own = (t[None, :] >= lo) & (t[None, :] < hi)
        if i == num_splits - 1:
            own = own | in_res
        mask = valid & own
        s_i = jnp.where(mask[:, None, None, :], scores, MASK_VALUE)
        o_i, lse_i = _softmax_partial(s_i, v_all)
        parts_o.append(o_i)
        parts_lse.append(lse_i)

    from repro.kernels.bitdecode.kernel import merge_partials

    return merge_partials(jnp.stack(parts_o), jnp.stack(parts_lse))
