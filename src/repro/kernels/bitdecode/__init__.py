from repro.kernels.bitdecode.ops import bitdecode_attention  # noqa: F401
