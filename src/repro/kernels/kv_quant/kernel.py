"""Pallas TPU kernel: fused quantize + strided pack (paper's Residual Kernel).

Grid = (B, H, n_blocks); one program quantizes one (block_n, d) KV block:
  1. min/max reduction on the VPU (channel-wise: over the token/sublane axis;
     tensor-wise: over the channel/lane axis) — the TPU analogue of the
     paper's __shfl_xor_sync warp reductions, which Mosaic owns at VREG level;
  2. in-register scale/zero computation ("half2" pairs, stored bf16/f16);
  3. in-register quantize (round/clip) and strided bit-pack (shift+or) so the
     packed words land directly in the layout the decode kernel's unpack
     reproduces in natural token order (core/layout.py).

All tiles live in VMEM via BlockSpec; no HBM round-trip between the
quantization statistics and the pack — the paper's "fused computation and
quantization within fragments" (§IV-A(1)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import layout

_F32_BIG = 3.0e38  # python float: jnp scalars would be captured consts in pallas
_EPS = 1e-6


def quant_block_tile(x, *, bits, granularity, param_dtype, d_orig=None):
    """Quantize + strided-pack one f32 ``(block_n, d)`` tile, in registers.

    Shared by the prefill-time kv_quant kernel and the decode-time
    residual_flush kernel so both commit bitwise-identical packed blocks.
    ``d_orig`` masks lane padding out of the tensor-granularity stats (pass
    None / d when the tile is unpadded).  Returns
    ``(words (npr, d) int32, scale, zero)`` with params cast to
    ``param_dtype`` *before* quantizing, so codes are consistent with what
    the decode kernel will dequantize with.
    """
    block_n, d_pad = x.shape
    qmax = layout.qmax(bits)

    if granularity == "channel":
        # stats along the token (sublane) axis, one pair per channel
        xmin = jnp.min(x, axis=0)
        xmax = jnp.max(x, axis=0)
        scale = jnp.maximum((xmax - xmin) / qmax, _EPS).astype(param_dtype)
        zero = xmin.astype(param_dtype)
        sf, zf = scale.astype(jnp.float32), zero.astype(jnp.float32)
        q = jnp.round((x - zf[None, :]) / sf[None, :])
    elif granularity == "tensor":
        # stats along the channel (lane) axis, one pair per token
        if d_orig is not None and d_pad != d_orig:
            lane = lax.broadcasted_iota(jnp.int32, x.shape, 1)
            valid = lane < d_orig
            xmin = jnp.min(jnp.where(valid, x, _F32_BIG), axis=1)
            xmax = jnp.max(jnp.where(valid, x, -_F32_BIG), axis=1)
        else:
            xmin = jnp.min(x, axis=1)
            xmax = jnp.max(x, axis=1)
        scale = jnp.maximum((xmax - xmin) / qmax, _EPS).astype(param_dtype)
        zero = xmin.astype(param_dtype)
        sf, zf = scale.astype(jnp.float32), zero.astype(jnp.float32)
        q = jnp.round((x - zf[:, None]) / sf[:, None])
    else:
        raise ValueError(granularity)

    q = jnp.clip(q, 0, qmax).astype(jnp.int32)

    # strided pack: word[i] collects bit-plane k from token k*npr + i
    shifts, _ = layout.plane_shift_mask(bits)
    npr = layout.words_per_block(block_n, bits)
    w = q[0:npr] << shifts[0]
    for k in range(1, len(shifts)):
        w = w | (q[k * npr : (k + 1) * npr] << shifts[k])
    return w, scale, zero


def _kvquant_kernel(
    x_ref, w_ref, s_ref, z_ref, *, bits, block_n, d_orig, granularity, param_dtype
):
    x = x_ref[0, 0].astype(jnp.float32)  # (block_n, d_pad)
    w, scale, zero = quant_block_tile(
        x, bits=bits, granularity=granularity, param_dtype=param_dtype,
        d_orig=d_orig,
    )
    s_ref[0, 0, 0] = scale
    z_ref[0, 0, 0] = zero
    w_ref[0, 0] = w


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits",
        "granularity",
        "block_n",
        "param_dtype",
        "interpret",
    ),
)
def quantize_kv_pallas(
    x: jnp.ndarray,
    *,
    bits: int,
    granularity: str,
    block_n: int = 128,
    param_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    """x: [B, H, S, d] (S % block_n == 0) -> (words, scale, zero).

    d is padded to a multiple of 128 lanes internally; outputs keep padded d
    for channel-wise params/words (callers slice) — here we slice back to the
    original d so the public contract matches ref.py exactly.
    """
    b, h, s, d = x.shape
    if s % block_n:
        raise ValueError(f"S={s} not a multiple of block_n={block_n}")
    nb = s // block_n
    npr = layout.words_per_block(block_n, bits)

    d_pad = max(128, -(-d // 128) * 128)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, d_pad - d)))

    if granularity == "channel":
        param_shape = (b, h, nb, d_pad)
        param_block = (1, 1, 1, d_pad)
    else:
        param_shape = (b, h, nb, block_n)
        param_block = (1, 1, 1, block_n)

    kernel = functools.partial(
        _kvquant_kernel,
        bits=bits,
        block_n=block_n,
        d_orig=d,
        granularity=granularity,
        param_dtype=param_dtype,
    )
    words, scale, zero = pl.pallas_call(
        kernel,
        grid=(b, h, nb),
        in_specs=[pl.BlockSpec((1, 1, block_n, d_pad), lambda i, j, k: (i, j, k, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, npr, d_pad), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec(param_block, lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec(param_block, lambda i, j, k: (i, j, k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nb * npr, d_pad), jnp.int32),
            jax.ShapeDtypeStruct(param_shape, param_dtype),
            jax.ShapeDtypeStruct(param_shape, param_dtype),
        ],
        interpret=interpret,
    )(x)

    words = words.reshape(b, h, nb, npr, d_pad)
    if d_pad != d:
        words = words[..., :d]
        if granularity == "channel":
            scale = scale[..., :d]
            zero = zero[..., :d]
    return words, scale, zero
