"""Pure-jnp oracle for the fused quantize+pack (Residual) kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import layout, quantizer


def quantize_kv_ref(
    x: jnp.ndarray,
    bits: int,
    granularity: str,
    *,
    block_n: int = 128,
    param_dtype=jnp.bfloat16,
):
    """Quantize+pack a KV tensor with the strided block layout.

    x: [B, H, S, d] with S % block_n == 0.
    Returns:
      words: int32 [B, H, nb, npr, d]
      scale/zero: [B, H, nb, d] (channel) or [B, H, nb, block_n] (tensor)
    """
    b, h, s, d = x.shape
    if s % block_n:
        raise ValueError(f"S={s} must be a multiple of block_n={block_n}")
    nb = s // block_n
    xb = x.reshape(b, h, nb, block_n, d)
    words, scale, zero = quantizer.quantize_and_pack(
        xb, bits, granularity, param_dtype=param_dtype
    )
    npr = layout.words_per_block(block_n, bits)
    assert words.shape == (b, h, nb, npr, d)
    return words, scale, zero


def dequantize_kv_ref(words, scale, zero, bits, granularity, *, dtype=jnp.bfloat16):
    """Inverse: words [B,H,nb,npr,d] -> [B,H,nb*block_n,d] natural order."""
    x = quantizer.unpack_and_dequantize(words, scale, zero, bits, granularity, dtype=dtype)
    b, h, nb, n, d = x.shape
    return x.reshape(b, h, nb * n, d)
