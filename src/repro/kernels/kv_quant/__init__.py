from repro.kernels.kv_quant.ops import quantize_kv  # noqa: F401
