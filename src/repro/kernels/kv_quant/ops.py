"""Public entry point for fused KV quantize+pack."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kv_quant import kernel as _kernel
from repro.kernels.kv_quant import ref as _ref


def quantize_kv(
    x: jnp.ndarray,
    bits: int,
    granularity: str,
    *,
    block_n: int = 128,
    param_dtype=jnp.bfloat16,
    impl: str = "auto",
):
    """Quantize+pack x[B,H,S,d] into (words[B,H,nb,npr,d], scale, zero).

    impl: 'pallas' (interpret-mode on CPU), 'xla' (pure-jnp reference path,
    used by the dry-run so cost_analysis sees the real dequant/pack work),
    or 'auto' (pallas on TPU, xla otherwise).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return _kernel.quantize_kv_pallas(
            x,
            bits=bits,
            granularity=granularity,
            block_n=block_n,
            param_dtype=param_dtype,
            interpret=interpret,
        )
    if impl == "xla":
        return _ref.quantize_kv_ref(
            x, bits, granularity, block_n=block_n, param_dtype=param_dtype
        )
    raise ValueError(f"unknown impl {impl!r}")
