"""Adafactor (factored second moments, no momentum) — the memory-frugal
option for the 671B-class configs where AdamW fp32 states exceed the HBM
budget (DESIGN.md §7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_rms=1.0, weight_decay=0.0,
              warmup=100, **_):
    def lr_at(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * jnp.minimum(1.0, s / max(1, warmup))

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        beta = 1.0 - (jnp.asarray(step + 1, jnp.float32)) ** (-decay)
        lr_t = lr_at(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                row = beta * s["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * s["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = row / jnp.mean(row, axis=-1, keepdims=True)
                u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(col)[..., None, :] + 1e-12)
                ns = {"row": row, "col": col}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + 1e-12)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), ns

        flat = jax.tree.map(upd, grads, state, params,
                            is_leaf=lambda x: isinstance(x, dict) and ("row" in x or "v" in x))
        updates = jax.tree.map(lambda o: o[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return Optimizer(init=init, update=update)
