"""Cross-pod gradient compression with error feedback.

Within a pod the ICI fabric is fast — gradients reduce in full precision
(implicit pjit all-reduce).  Across pods the DCI links are the bottleneck, so
the pod-local-reduced gradient is quantized to int8 (per-tensor scale),
exchanged with an all_gather over the ``pod`` axis (int8 on the wire: 4x
fewer bytes than an f32 ring all-reduce over 2 pods, 8x counting both
directions), summed locally, and dequantized.  The quantization residual is
carried in an error-feedback buffer so the compression is unbiased over time
(EF-SGD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def compress_allreduce(g, err, axis_name: str = "pod"):
    """One tensor: (grad f32-ish, error buffer f32) -> (reduced grad, new err).

    Must run inside shard_map with ``axis_name`` in scope; the input is this
    pod's (already pod-locally-reduced) gradient shard.
    """
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale

    qs = lax.all_gather(q, axis_name)            # int8 on the wire
    scales = lax.all_gather(scale, axis_name)    # one f32 per pod
    total = jnp.sum(qs.astype(jnp.float32) * scales.reshape(-1, *[1] * g.ndim), axis=0)
    n = qs.shape[0]
    return (total / n).astype(g.dtype), new_err


def compress_allreduce_tree(grads, err_tree, axis_name: str = "pod"):
    out = jax.tree.map(lambda g, e: compress_allreduce(g, e, axis_name), grads, err_tree)
    red = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return red, err


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
