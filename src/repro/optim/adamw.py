"""AdamW with f32 moments.  States inherit the parameter shardings (params
are themselves FSDP/TP-sharded), so moments are ZeRO-partitioned for free."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, opt_state, params, step) -> (updates, opt_state)


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, warmup=100,
          schedule: str = "cosine", total_steps: int = 10000):
    def lr_at(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, s / max(1, warmup))
        if schedule == "cosine":
            t = jnp.clip((s - warmup) / max(1, total_steps - warmup), 0, 1)
            base = 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            base = 1.0
        return lr * warm * base

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step + 1, jnp.float32)
        lr_t = lr_at(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1**stepf)
            vhat = v / (1 - b2**stepf)
            u = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # no decay on norms/biases
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return Optimizer(init=init, update=update)
