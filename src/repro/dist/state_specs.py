"""PartitionSpec trees for decode state (the serving-side face of sharding).

Decode state is a pytree of stacked QuantKVCache dataclasses plus per-model
recurrent state (SSM/xLSTM) and a position vector.  Placement policy:

  * batch dims shard over the largest ("pod", "data") group that divides the
    global batch (mirrors launch/mesh.pick_batch_axes);
  * the KV-head dim of caches shards over "model" (TP decode);
  * when ``seq_ax`` is given (long-context small-batch shapes, where the
    batch group is empty), the *packed-block* axis of every QuantKVCache
    shards along it — the at-rest layout matching repro.dist.splitkv, so the
    sequence-parallel decode reads its shard locally instead of re-gathering
    the cache every step.

Leaves that are not cache fields (pos, SSM states, ...) shard their batch
dim, identified as the first dim equal to ``global_batch`` — a heuristic,
but a safe one: specs only place data, they never change semantics.

Cache-field roles map onto the QuantKVCache shapes of docs/ARCHITECTURE.md
§2 (``kw [B, H, nb, npr, d]`` etc.), shifted right by the model's stacking
dims (layers, super-blocks).  Axis names are physical mesh axes
(``"pod"/"data"/"model"`` plus the caller's ``seq_ax``), matching
dist.sharding's :func:`~repro.dist.sharding.base_rules` targets for the
same tensors.  Like dist.sharding, placement never pads: an axis group that
does not divide a dim is dropped (the leaf stays replicated on that dim) —
any padding needed to honor a split (e.g. the block axis when
``nb % axis_size != 0``) happens in dist.splitkv at call time instead.

Specs are consumed via ``jax.device_put`` / shardings built under
``jax.set_mesh`` — shimmed onto legacy jax by ``repro.dist.__init__``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import PartitionSpec as PS

from repro.core.qcache import PagedQuantKVCache, QuantKVCache

# field -> (base rank without stacking dims, {base-dim index: role})
_CACHE_FIELD_ROLES = {
    "kw": (5, {0: "batch", 1: "heads", 2: "blocks"}),
    "k_scale": (4, {0: "batch", 1: "heads", 2: "blocks"}),
    "k_zero": (4, {0: "batch", 1: "heads", 2: "blocks"}),
    "vw": (5, {0: "batch", 1: "heads", 2: "blocks"}),
    "v_scale": (4, {0: "batch", 1: "heads", 2: "blocks"}),
    "v_zero": (4, {0: "batch", 1: "heads", 2: "blocks"}),
    "k_res": (4, {0: "batch", 1: "heads"}),
    "v_res": (4, {0: "batch", 1: "heads"}),
    "pack_blocks": (1, {0: "batch"}),
    "res_len": (1, {0: "batch"}),
}

# Paged layout: by default the pools ([P, H, ...]) replicate their page dim
# (pages are scattered arbitrarily, only the table *walk* is
# sequence-parallel — see dist.splitkv.splitkv_paged_decode_attention) and
# shard KV heads over "model"; the page_table columns carry the "blocks"
# role so the at-rest placement matches the sharded walk.  Prefix sharing
# rides this placement unchanged: a shared page id may appear in several
# table rows (or twice in one row's shard), and because every chip holds
# the full pools each shard dereferences it locally — sharing needs no
# cross-chip coordination, and copy-on-write repoints are plain table
# updates under the same spec.
_PAGED_FIELD_ROLES = {
    "kw": (4, {1: "heads"}),
    "k_scale": (3, {1: "heads"}),
    "k_zero": (3, {1: "heads"}),
    "vw": (4, {1: "heads"}),
    "v_scale": (3, {1: "heads"}),
    "v_zero": (3, {1: "heads"}),
    "k_res": (4, {0: "batch", 1: "heads"}),
    "v_res": (4, {0: "batch", 1: "heads"}),
    "page_table": (2, {0: "batch", 1: "blocks"}),
    "pack_blocks": (1, {0: "batch"}),
    "res_len": (1, {0: "batch"}),
}

# Page-affine layout (docs/SERVING.md §14): the pools' leading (page) dim
# ALSO shards along ``seq_ax``, matching the allocator contract of
# serve/pages.py (``shards`` = axis size): the page backing table column j
# lives only on the chip that walks column j, so aggregate pool bytes scale
# linearly with the mesh.  Residuals stay batch/heads-placed (slot-indexed),
# and the table keeps its column sharding.
_PAGED_AFFINE_FIELD_ROLES = {
    **_PAGED_FIELD_ROLES,
    "kw": (4, {0: "pages", 1: "heads"}),
    "k_scale": (3, {0: "pages", 1: "heads"}),
    "k_zero": (3, {0: "pages", 1: "heads"}),
    "vw": (4, {0: "pages", 1: "heads"}),
    "v_scale": (3, {0: "pages", 1: "heads"}),
    "v_zero": (3, {0: "pages", 1: "heads"}),
}


def _batch_axes(mesh, global_batch: int) -> tuple:
    """Largest batch-sharding axis group that divides the global batch."""
    for axes in (("pod", "data"), ("data",), ()):
        if all(a in mesh.axis_names for a in axes):
            size = math.prod(mesh.shape[a] for a in axes)
            if size and global_batch % size == 0:
                return axes
    return ()


def _entry(names, mesh, dim: int):
    names = tuple(n for n in names if n in mesh.axis_names and mesh.shape[n] > 1)
    if not names or dim % math.prod(mesh.shape[n] for n in names):
        return None
    return names if len(names) > 1 else names[0]


def _cache_specs(c, mesh, batch_axes, seq_ax, page_affine=False):
    role_axes = {
        "batch": batch_axes,
        "heads": ("model",),
        "blocks": (seq_ax,) if seq_ax else (),
        "pages": (seq_ax,) if seq_ax else (),
    }
    if isinstance(c, PagedQuantKVCache):
        roles_table = (
            _PAGED_AFFINE_FIELD_ROLES if page_affine else _PAGED_FIELD_ROLES
        )
    else:
        roles_table = _CACHE_FIELD_ROLES

    def field_spec(name: str, arr):
        if arr is None:
            return None
        base_rank, roles = roles_table[name]
        lead = arr.ndim - base_rank  # stacked layer dims stay replicated
        parts = [None] * arr.ndim
        used: set = set()  # a mesh axis may appear once per PartitionSpec
        for i, role in sorted(roles.items()):
            e = _entry(role_axes[role], mesh, arr.shape[lead + i])
            names = e if isinstance(e, tuple) else (e,) if e else ()
            if any(n in used for n in names):
                continue  # earlier dim claimed the axis; stay replicated
            used.update(names)
            parts[lead + i] = e
        return PS(*parts)

    kwargs = {name: field_spec(name, getattr(c, name)) for name in roles_table}
    return dataclasses.replace(c, **kwargs)


def decode_state_specs(model, mesh, *, global_batch: int, seq_ax: str | None = None,
                       paged: bool = False, n_pages: int | None = None,
                       nb_max: int | None = None, page_affine: bool = False):
    """PartitionSpec tree matching ``model.init_decode_state`` structure
    (or ``model.init_paged_decode_state`` when ``paged``).

    ``page_affine`` (paged only) additionally shards the pools' page dim
    along ``seq_ax`` — pair with serve/pages.py's sharded allocator and
    ``splitkv_paged_decode_attention(page_affine=True)``.  Placement drops
    an axis whose size does not divide the *probed* dim, so callers whose
    real state differs from the default probe shape (the serve engine's
    mesh-aligned ``nb_max``, its pool size) must pass ``nb_max`` /
    ``n_pages`` explicitly."""
    cfg = model.cfg
    batch_axes = _batch_axes(mesh, global_batch)
    # structure only — nb just has to be positive; actual decode states may
    # have any block count, specs are rank/dim-role based.  Divisibility is
    # checked against these probe dims though, so nb_max/n_pages overrides
    # matter whenever an axis must actually split the dim (page_affine).
    if nb_max is None:
        nb_max = 4
    max_seq = nb_max * getattr(cfg, "kv_block", 128)
    # closure (not args) so batch/max_seq stay concrete python ints
    if paged:
        np_ = n_pages if n_pages is not None else global_batch * (nb_max + 1)
        state = jax.eval_shape(
            lambda: model.init_paged_decode_state(
                global_batch, n_pages=np_, nb_max=nb_max
            )
        )
    else:
        state = jax.eval_shape(lambda: model.init_decode_state(global_batch, max_seq))

    def generic(arr):
        parts = [None] * arr.ndim
        if batch_axes:
            for i, d in enumerate(arr.shape):
                if d == global_batch:
                    parts[i] = _entry(batch_axes, mesh, d)
                    break
        return PS(*parts)

    _cache_types = (QuantKVCache, PagedQuantKVCache)

    def node(x):
        if isinstance(x, _cache_types):
            return _cache_specs(x, mesh, batch_axes, seq_ax, page_affine)
        return generic(x)

    return jax.tree.map(
        node, state, is_leaf=lambda x: isinstance(x, _cache_types)
    )
