"""Cross-chip split-KV decode: FlashDecoding partitioning across a mesh axis.

The single-chip kernel already splits the packed-block walk across its grid
(kernels/bitdecode, ``num_splits``); this module is the level above: the
packed cache is sharded *across chips* along the block axis of a mesh axis
(normally "data", which carries no batch at the long-context small-batch
shapes — see launch/mesh.pick_batch_axes), every chip runs the local fused
kernel over its shard, and the per-chip partials are combined with the
logsumexp merge specified by tests/test_splitkv_math.py:

    m = max_i lse_i;  w_i = exp(lse_i - m);  out = sum_i w_i o_i / sum_i w_i

A shard whose block range lies beyond ``pack_blocks[b]`` computes no valid
tokens; the kernel's finalize l=0 guard emits lse ~ -inf, so its weight
underflows to exactly 0 and the merge is unaffected.  The bf16 residual tail
is replicated and processed by the *last* shard only (it usually owns the
fewest valid blocks, so the extra block balances the walk).

Padding: this module shards dim 2 (the packed-block axis ``nb``) of every
packed cache field — ``kw [B, H, nb, npr, d]`` and the ``[B, H, nb, …]``
scale/zero arrays (layout spec: docs/ARCHITECTURE.md §2).  When
``nb % axis_size != 0`` the axis is zero-padded *per call* before the
shard_map; padded blocks sit beyond ``pack_blocks`` so they are never read
as valid, but the pad is a full-cache copy every decode step at that shape —
size caches so ``axis_size`` divides ``nb`` (ROADMAP: mesh-aligned cache
allocation).  Queries, residuals, and occupancy counters are replicated.

Mesh axes are *physical* names here (normally ``"data"``) — the logical-axis
indirection of dist.sharding applies to parameters, not to this explicitly
shard_mapped path.  The mesh is passed in explicitly; callers entering it as
a context use ``jax.set_mesh``, which ``repro.dist.__init__`` shims onto
legacy jax (< 0.6) where ``Mesh`` itself is the context manager.

Merge math and diagrams: docs/ARCHITECTURE.md §5.  Wired in through
:class:`repro.core.attention.use_splitkv`, which the launchers enter around
lowering the long-context decode cells and the serve engine enters for its
split-KV decode step.

Paged twin: :func:`splitkv_paged_decode_attention` shards the page-table
*walk* (not the pools) for PagedQuantKVCache states — see its docstring and
docs/ARCHITECTURE.md §7.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.paged_bitdecode import ops as pg_ops


def merge_collective(o, lse, axis: str):
    """lse-merge of per-shard flash partials across mesh axis ``axis``.

    o: [..., g, d_v] normalized per-shard output; lse: [..., g].  Returns the
    merged output, replicated along ``axis``.
    """
    m = lax.pmax(lse, axis)
    w = jnp.exp(lse - m)
    num = lax.psum(w[..., None] * o, axis)
    den = lax.psum(w, axis)
    return num / jnp.maximum(den, 1e-30)[..., None]


def _pad_block_axis(x, pad: int):
    """Zero-pad the packed-block axis (dim 2 of [B, H, nb, ...]) so it splits
    evenly across the mesh axis.  Padded blocks sit beyond pack_blocks and
    are never read as valid.

    NB: when nb is not already a multiple of the axis size this copies the
    cache every call — size caches so nb divides the split axis (ROADMAP:
    mesh-aligned cache allocation)."""
    if not pad or x is None:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[2] = (0, pad)
    return jnp.pad(x, cfg)


def splitkv_decode_attention(
    q,
    cache,
    mesh,
    *,
    axis: str = "data",
    sm_scale: float | None = None,
    d_v: int | None = None,
    impl: str = "auto",
    num_splits: int | str | None = "auto",
):
    """Sequence-parallel decode attention against a block-sharded QuantKVCache.

    q: [B, 1, h_q, d_k] (model layout; the query transformation happens
    here).  Returns [B, 1, h_q, d_v], replicated along ``axis``.  Composes
    with the in-kernel split: each shard's local kernel may further split its
    block range (``num_splits``), giving mesh x grid sequence parallelism.
    """
    from repro.core.attention import inverse_query_transform, query_transform

    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {axis!r}; available: {tuple(mesh.axis_names)}"
        )
    n = mesh.shape[axis]
    h_kv = cache.kw.shape[1]
    qt = query_transform(q, h_kv)
    nb = cache.kw.shape[2]
    pad = -(-nb // n) * n - nb

    shared = cache.shared_kv
    blk = PS(None, None, axis)  # shard dim 2 (packed blocks) of [B,H,nb,...]
    rep = PS()

    operands = [
        qt,
        _pad_block_axis(cache.kw, pad),
        _pad_block_axis(cache.k_scale, pad),
        _pad_block_axis(cache.k_zero, pad),
    ]
    in_specs = [rep, blk, blk, blk]
    if not shared:
        operands += [
            _pad_block_axis(cache.vw, pad),
            _pad_block_axis(cache.v_scale, pad),
            _pad_block_axis(cache.v_zero, pad),
        ]
        in_specs += [blk, blk, blk]
    operands += [cache.k_res, cache.v_res, cache.pack_blocks, cache.res_len]
    in_specs += [rep] + ([rep] if not shared else []) + [rep, rep]
    if shared:
        operands = [x for x in operands if x is not None]

    def local(*args):
        if shared:
            qt_, kw_, ks_, kz_, kres_, pb_, rl_ = args
            vw_ = vs_ = vz_ = vres_ = None
        else:
            qt_, kw_, ks_, kz_, vw_, vs_, vz_, kres_, vres_, pb_, rl_ = args
        idx = lax.axis_index(axis)
        nb_local = kw_.shape[2]
        lo = idx * nb_local
        pb_local = jnp.clip(pb_ - lo, 0, nb_local)
        rl_local = jnp.where(idx == n - 1, rl_, 0)
        o, lse = bd_ops.bitdecode_attention(
            qt_, kw_, ks_, kz_, vw_, vs_, vz_, kres_, vres_,
            pb_local, rl_local,
            bits=cache.bits, block_n=cache.block_n, sm_scale=sm_scale,
            k_gran=cache.k_gran, shared_kv=shared, d_v=d_v,
            impl=impl, num_splits=num_splits, return_lse=True,
        )
        return merge_collective(o, lse, axis)

    out = shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs), out_specs=rep,
        check_rep=False,
    )(*operands)
    return inverse_query_transform(out)


def splitkv_paged_decode_attention(
    q,
    cache,
    mesh,
    *,
    axis: str = "data",
    sm_scale: float | None = None,
    d_v: int | None = None,
    impl: str = "auto",
    num_splits: int | str | None = "auto",
    page_affine: bool = False,
):
    """Sequence-parallel *paged* decode: shard the page-table **walk**, and
    optionally the pool *storage* behind it.

    The paged cache scatters a sequence's blocks across arbitrary pool pages,
    so the pools themselves have no contiguous block axis to shard; instead
    the ``page_table`` columns (dim 1 of ``[B, nb_max]``) are sharded along
    ``axis`` — each chip walks a contiguous slice of every sequence's table,
    clips ``pack_blocks`` to its slice, and the per-chip flash partials merge
    with the usual lse collectives.  The bf16 residual rides with the last
    shard, exactly as in the dense path.

    ``page_affine=False`` (default) walks the table against *replicated*
    pools — every chip stores every page.  ``page_affine=True`` additionally
    shards the pools' leading (page) axis along the same mesh axis, under
    the page-affine allocator contract (serve/pages.py with ``shards > 1``):
    every page referenced at table column ``j`` lives in shard
    ``j // nb_local`` — the chip that walks that column — so each chip walks
    its table slice against only its own ``n_pages / n`` pages and aggregate
    pool bytes scale with the mesh.  The local walk rebases global page ids
    into the shard (``tbl - idx * pp_local``); entries that violate affinity
    would clamp into range and read garbage, but by the allocator invariant
    the only out-of-shard entries are scratch ids in masked (beyond
    ``pack_blocks``) columns — the same masking the padded-table path
    already relies on.

    q: [B, 1, h_q, d_k]; cache: PagedQuantKVCache.  Returns
    [B, 1, h_q, d_v], replicated along ``axis``.  Composes with the
    in-kernel split (``num_splits``) per chip.  ``shared_kv`` caches (the
    MLA latent pools) shard the same way — one pool set, no V operands —
    with ``d_v`` naming the latent's value slice.
    """
    from repro.core.attention import inverse_query_transform, query_transform

    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {axis!r}; available: {tuple(mesh.axis_names)}"
        )
    n = mesh.shape[axis]
    h_kv = cache.kw.shape[1]
    qt = query_transform(q, h_kv)
    nb = cache.page_table.shape[1]
    pad = -(-nb // n) * n - nb
    table = cache.page_table
    if pad:
        # padded entries point at page 0 (a scratch page): they sit beyond
        # every pack_blocks so the kernel masks them; size nb_max to the
        # axis (serve engine does) to keep the per-step path pad-free
        table = jnp.pad(table, ((0, 0), (0, pad)))

    shared = cache.shared_kv
    rep = PS()
    # pool fields shard their leading (page) axis under page affinity; the
    # residuals stay replicated (they are slot-indexed, not page-indexed)
    pool = PS(axis) if page_affine else rep
    if page_affine and cache.kw.shape[0] % n:
        raise ValueError(
            f"page_affine needs the pool page count ({cache.kw.shape[0]}) "
            f"divisible by the {axis!r} axis size ({n}); allocate the pool "
            "with shards equal to the axis size (serve/pages.py)"
        )
    if shared:
        operands = (
            qt, cache.kw, cache.k_scale, cache.k_zero,
            cache.k_res, table, cache.pack_blocks, cache.res_len,
        )
        in_specs = (rep, pool, pool, pool, rep, PS(None, axis), rep, rep)
    else:
        operands = (
            qt, cache.kw, cache.k_scale, cache.k_zero,
            cache.vw, cache.v_scale, cache.v_zero,
            cache.k_res, cache.v_res, table, cache.pack_blocks, cache.res_len,
        )
        in_specs = (
            (rep,) + (pool,) * 6 + (rep, rep) + (PS(None, axis), rep, rep)
        )

    def local(*args):
        if shared:
            qt_, kw_, ks_, kz_, kres_, tbl_, pb_, rl_ = args
            vw_ = vs_ = vz_ = vres_ = None
        else:
            (qt_, kw_, ks_, kz_, vw_, vs_, vz_, kres_, vres_, tbl_, pb_,
             rl_) = args
        idx = lax.axis_index(axis)
        nb_local = tbl_.shape[1]
        lo = idx * nb_local
        pb_local = jnp.clip(pb_ - lo, 0, nb_local)
        rl_local = jnp.where(idx == n - 1, rl_, 0)
        if page_affine:
            # rebase global page ids into this shard's pool slice; by the
            # allocator's affinity invariant every valid entry in this
            # shard's table columns is shard-local, so only masked entries
            # (scratch ids beyond pb_local) clamp
            pp_local = kw_.shape[0]
            tbl_ = jnp.clip(tbl_ - idx * pp_local, 0, pp_local - 1)
        o, lse = pg_ops.paged_bitdecode_attention(
            qt_, kw_, ks_, kz_, vw_, vs_, vz_, kres_, vres_,
            tbl_, pb_local, rl_local,
            bits=cache.bits, block_n=cache.block_n, sm_scale=sm_scale,
            k_gran=cache.k_gran, shared_kv=shared, d_v=d_v,
            impl=impl, num_splits=num_splits, return_lse=True,
        )
        return merge_collective(o, lse, axis)

    out = shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=rep, check_rep=False,
    )(*operands)
    return inverse_query_transform(out)
