"""Distributed layer: sharding rules, decode-state placement, and the
cross-chip split-KV decode path.

Modules:
  * :mod:`repro.dist.sharding`    — logical-axis rules -> PartitionSpecs,
    plus :func:`constrain`, the activation sharding-constraint helper used
    by the models and the train step;
  * :mod:`repro.dist.state_specs` — PartitionSpec trees for decode state
    (dense QuantKVCache and paged PagedQuantKVCache placement, incl. the
    split-KV block-axis / page-table-column sharding);
  * :mod:`repro.dist.splitkv`     — sequence-parallel decode across a mesh
    axis with the logsumexp partials merge (FlashDecoding across chips),
    for both the dense block-sharded and paged table-walk-sharded layouts.

Compat: older jax (< 0.6) has no ``jax.set_mesh``; ``Mesh`` itself is the
context manager that installs the active mesh.  The launchers and tests use
the modern spelling, so install a minimal shim when it is missing.
"""
from __future__ import annotations

import jax

if not hasattr(jax, "set_mesh"):  # pragma: no cover - depends on jax version
    def _set_mesh_compat(mesh):
        """``with jax.set_mesh(m):`` == ``with m:`` on legacy jax."""
        return mesh

    jax.set_mesh = _set_mesh_compat

from repro.dist import sharding, splitkv, state_specs  # noqa: E402,F401
