"""Logical-axis sharding rules (MaxText-style) and activation constraints.

Parameters declare *logical* axes (models/params.py ``P.axes``); a rules dict
maps logical axis -> mesh axis (or tuple of mesh axes, or None).

Logical axis vocabulary (see :func:`base_rules` for the default mapping onto
the ``("pod", "data", "model")`` mesh):

  ==============  ===========================================  =============
  logical axis    appears on                                   default mesh
  ==============  ===========================================  =============
  ``embed``       d_model dims of projections/embeddings        ``data`` (FSDP)
  ``mlp``         FFN hidden dim                                ``model``
  ``heads``       query-head dim                                ``model``
  ``kv_heads``    KV-head dim (caches too: state_specs)         ``model``
  ``head_dim``    per-head feature dim                          replicated
  ``vocab``       (padded) vocabulary dim                       ``model``
  ``experts``     MoE expert dim                                ``model``
  ``expert_mlp``  per-expert FFN hidden                         replicated
  ``layers``      stacked-layer leading dim (scan axis)         replicated
  ``inner``       nested stack dim (hybrid super-blocks)        replicated
  ==============  ===========================================  =============

Everything here degrades gracefully: axes absent from the mesh are dropped,
dims that a mesh-axis group does not **divide** stay replicated (sharding
never pads — contrast dist.splitkv, which does zero-pad the cache block axis
per call when it must split an indivisible dim), a mesh axis already used by
an earlier dim of the same leaf is dropped, and with no active mesh
:func:`constrain` is a no-op — so the same model code runs on a laptop CPU,
an 8-device fake mesh, and a multi-pod slice unchanged.

Mesh-context caveat: the active mesh may be installed either via native
``jax.set_mesh`` (jax >= 0.6, published through ``get_abstract_mesh``) or
via the legacy ``with mesh:`` context (``thread_resources``);
:func:`_active_mesh` probes both, and ``repro.dist.__init__`` shims
``jax.set_mesh`` onto legacy jax so callers can use the modern spelling
everywhere.  Missing either probe would silently drop every sharding
constraint.

Layout/spec background: docs/ARCHITECTURE.md §6.
"""
from __future__ import annotations

import math

import jax
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.models.params import P


def _active_mesh():
    """The mesh installed by ``with jax.set_mesh(mesh):`` (or ``with mesh:``
    on legacy jax), or None outside any mesh context.

    Checks both generations of the API: native ``set_mesh`` (jax >= 0.6)
    publishes an abstract mesh via ``get_abstract_mesh``; the legacy
    ``Mesh.__enter__`` context fills ``thread_resources``.  Missing either
    probe would silently drop every sharding constraint."""
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        try:
            m = get_am()
            if m is not None and getattr(m, "axis_names", ()) and not m.empty:
                return m
        except Exception:  # pragma: no cover - API drift
            pass
    try:
        from jax._src import mesh as _mesh_lib

        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - private-API drift
        pass
    return None


def _axis_entry(entry, mesh, dim_size: int, used: set):
    """Resolve one PartitionSpec entry against the mesh: drop axes that are
    missing, already used in this spec, or whose group does not divide the
    dim."""
    if entry is None:
        return None
    names = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
    names = tuple(
        n for n in names
        if n in mesh.axis_names and n not in used and mesh.shape[n] > 1
    )
    if not names:
        return None
    size = math.prod(mesh.shape[n] for n in names)
    if dim_size % size:
        return None
    used.update(names)
    return names if len(names) > 1 else names[0]


def constrain(x, *axes):
    """``with_sharding_constraint`` with per-dim mesh-axis names, tolerant of
    meshes that lack some axes (e.g. no "pod" on a single-pod mesh) and of
    running with no mesh at all (returns x unchanged).

    Trailing dims without an entry stay unconstrained.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    used: set = set()
    parts = [
        _axis_entry(a, mesh, x.shape[i], used)
        for i, a in enumerate(axes[: x.ndim])
    ]
    if not any(p is not None for p in parts):
        return x
    return lax.with_sharding_constraint(x, PS(*parts))


def base_rules(cfg) -> dict:
    """Logical axis -> mesh axis mapping for the config's sharding profile.

    ``fsdp_tp`` (default): FSDP over "data" on the embed dim, tensor/expert
    parallelism over "model" on heads/mlp/vocab/experts.  ``tp``: TP only,
    params replicated over "data" ("pod" always carries pure DP).
    """
    fsdp = getattr(cfg, "sharding_profile", "fsdp_tp") != "tp"
    return {
        "embed": "data" if fsdp else None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "layers": None,
        "inner": None,
    }


def specs_for(defs, rules: dict, mesh) -> object:
    """PartitionSpec per P-leaf: map logical axes through ``rules``, dropping
    entries the mesh cannot honor (missing axis, non-dividing dim, mesh axis
    already used by an earlier dim of the same leaf)."""

    def leaf(p: P):
        used: set = set()
        parts = [
            _axis_entry(rules.get(a), mesh, dim, used)
            for dim, a in zip(p.shape, p.axes)
        ]
        return PS(*parts)

    return jax.tree.map(leaf, defs, is_leaf=lambda x: isinstance(x, P))


def shardings_for(defs, rules: dict, mesh) -> object:
    """NamedShardings for :func:`specs_for` (device_put-ready)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs_for(defs, rules, mesh),
        is_leaf=lambda x: isinstance(x, PS),
    )
