"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — for
scan-over-layers models that undercounts FLOPs/bytes/collectives by ~L.  This
module parses the optimized HLO, walks computations recursively, and
multiplies while-body costs by the ``known_trip_count`` backend_config, giving
per-device totals suitable for roofline analysis:

  flops       — dot ops: 2 * numel(result) * contracted_size
  bytes       — per top-level op: operand bytes + result bytes (fusion
                internals excluded: a fused region reads its operands and
                writes its result once — closer to real HBM traffic than
                cost_analysis' per-op accounting)
  collectives — wire bytes per collective kind (all-gather counts the
                gathered output, reduce ops count the payload), multiplied
                through enclosing loops
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_line(line: str):
    """'%name = TYPE opcode(rest' -> (name, type_str, opcode, rest) or None.

    TYPE may be a tuple containing '/*index=k*/' comments, so it is scanned
    with paren balancing rather than a regex.
    """
    nm = _NAME_RE.match(line)
    if not nm:
        return None
    pos = nm.end()
    if pos < len(line) and line[pos] == "(":
        depth = 0
        i = pos
        while i < len(line):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        type_str = line[pos : i + 1]
        rest_start = i + 1
    else:
        sp = line.find(" ", pos)
        if sp < 0:
            return None
        type_str = line[pos:sp]
        rest_start = sp
    om = _OPCODE_RE.match(line, rest_start)
    if not om:
        return None
    return nm.group(1), type_str, om.group(1), line[om.end() :]
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_numel_bytes(shape_str: str):
    """Total (numel, bytes) over all array shapes in the string (tuples sum)."""
    numel_total, bytes_total = 0, 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return numel_total, bytes_total


@dataclasses.dataclass
class OpInfo:
    name: str
    shape_str: str
    opcode: str
    rest: str  # operands + attributes (text after the opening paren)


def _split_computations(hlo: str):
    """name -> list[OpInfo]; also records computation parameter shapes."""
    comps: dict[str, list[OpInfo]] = {}
    params: dict[str, dict[str, str]] = {}
    cur = None
    header_re = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = header_re.match(line.strip())
        if hm and line.strip().endswith("{"):
            cur = hm.group(1)
            comps[cur] = []
            params[cur] = {}
            # parse "name: shape, name: shape"
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[^,()]+)", hm.group(2)):
                params[cur][pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_line(line)
        if parsed:
            comps[cur].append(OpInfo(*parsed))
    return comps, params


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVE_KINDS}

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f):
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.params = _split_computations(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.entry = None
        m = re.search(r"^ENTRY\s+%([\w.\-]+)", hlo_text, re.MULTILINE)
        if m:
            self.entry = m.group(1)
        else:  # fall back to last computation
            self.entry = list(self.comps)[-1] if self.comps else None

    # ------------------------------------------------------------ helpers

    def _symbol_shapes(self, comp: str):
        table = dict(self.params.get(comp, {}))
        for op in self.comps[comp]:
            table[op.name] = op.shape_str
        return table

    def _dot_flops(self, op: OpInfo, table):
        numel, _ = _shape_numel_bytes(op.shape_str)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        contract = 1
        if m:
            ops = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
            if ops:
                lhs_shape = table.get(ops[0], "")
                sm = _SHAPE_RE.search(lhs_shape)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for di in m.group(1).split(","):
                        if di and int(di) < len(dims):
                            contract *= dims[int(di)]
        return 2.0 * numel * contract

    def _op_bytes(self, op: OpInfo, table):
        if op.opcode in _SKIP_BYTES_OPS:
            return 0.0
        _, out_b = _shape_numel_bytes(op.shape_str)
        # windowed ops touch only the window, not the full operand — counting
        # full operands would charge scan-body slicing O(L) per iteration
        # (O(L^2) overall), wildly inflating scan-over-layers programs
        if op.opcode == "dynamic-slice":
            return 2.0 * out_b  # read window + write result
        if op.opcode == "dynamic-update-slice":
            ops = _OPERAND_RE.findall(op.rest.split("), ", 1)[0])
            upd = _shape_numel_bytes(table.get(ops[1], ""))[1] if len(ops) > 1 else out_b
            return 3.0 * upd  # read window + read update + write window
        if op.opcode == "gather":
            return 2.0 * out_b
        in_b = 0.0
        operand_str = op.rest.split("), ", 1)[0]
        for name in _OPERAND_RE.findall(operand_str):
            if name in table:
                _, b = _shape_numel_bytes(table[name])
                in_b += b
        return out_b + in_b

    def _fusion_bytes(self, op: OpInfo, table, called: str) -> float:
        """Window-aware byte accounting at a fusion boundary.

        A fusion's parameters that are only ever *windowed* inside (the
        operand of a dynamic-slice, or the in-place target of a root
        dynamic-update-slice) contribute window bytes, not full-array bytes —
        otherwise scan-residual saving (fused DUS into an [L, ...] buffer)
        gets charged the whole buffer every iteration, inflating train
        programs ~50-100x.
        """
        inner_ops = self.comps.get(called)
        if not inner_ops:
            return self._op_bytes(op, table)
        inner_table = self._symbol_shapes(called)
        root = inner_ops[-1]

        # uses of each symbol inside the fusion
        uses: dict[str, list[tuple[OpInfo, int]]] = {}
        for o in inner_ops:
            operand_str = o.rest.split("), ", 1)[0]
            for idx, nm in enumerate(_OPERAND_RE.findall(operand_str)):
                uses.setdefault(nm, []).append((o, idx))

        in_b = 0.0
        for o in inner_ops:
            if o.opcode != "parameter":
                continue
            _, full_b = _shape_numel_bytes(o.shape_str)
            u = uses.get(o.name, [])
            if u and all(uo.opcode == "dynamic-slice" and pos == 0 for uo, pos in u):
                in_b += sum(_shape_numel_bytes(uo.shape_str)[1] for uo, _ in u)
            elif (root.opcode == "dynamic-update-slice" and u
                  and all(uo is root and pos == 0 for uo, pos in u)):
                # in-place accumulation target: read the window only
                ops_n = _OPERAND_RE.findall(root.rest.split("), ", 1)[0])
                upd = inner_table.get(ops_n[1], "") if len(ops_n) > 1 else ""
                in_b += _shape_numel_bytes(upd)[1]
            else:
                in_b += full_b

        if root.opcode == "dynamic-update-slice":
            ops_n = _OPERAND_RE.findall(root.rest.split("), ", 1)[0])
            upd = inner_table.get(ops_n[1], "") if len(ops_n) > 1 else ""
            out_b = _shape_numel_bytes(upd)[1]
        else:
            _, out_b = _shape_numel_bytes(op.shape_str)
        return in_b + out_b

    # ------------------------------------------------------------ walk

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # break cycles defensively
        total = Cost()
        table = self._symbol_shapes(comp)
        for op in self.comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(op.rest)
                if bm:
                    total += self.comp_cost(bm.group(1)).scaled(trip)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total += self.comp_cost(cm.group(1)).scaled(trip)
                continue
            if oc in ("fusion", "call", "async-start", "custom-call"):
                cm = _CALLS_RE.search(op.rest)
                inner = Cost()
                if cm and cm.group(1) in self.comps:
                    inner = self.comp_cost(cm.group(1))
                    byts = self._fusion_bytes(op, table, cm.group(1))
                else:
                    byts = self._op_bytes(op, table)
                # fusion: flops from the fused computation, bytes at the
                # fusion boundary only (window-aware)
                total += Cost(inner.flops, byts, inner.coll)
                continue
            if oc == "conditional":
                for cname in re.findall(r"(?:branch_computations=\{|true_computation=%|false_computation=%)([\w.\-]+)", op.rest):
                    if cname in self.comps:
                        total += self.comp_cost(cname)
                continue
            base = oc.replace("-start", "") if oc.endswith("-start") else oc
            if base in COLLECTIVE_KINDS:
                _, b = _shape_numel_bytes(op.shape_str)
                c = Cost(0.0, self._op_bytes(op, table))
                c.coll[base] += b
                total += c
                continue
            if oc.endswith("-done"):
                continue
            if oc == "dot" or oc == "convolution":
                total += Cost(self._dot_flops(op, table), self._op_bytes(op, table))
                continue
            total += Cost(0.0, self._op_bytes(op, table))
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    c = HloCostModel(hlo_text).entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": sum(c.coll.values()),
        "collectives": dict(c.coll),
    }
