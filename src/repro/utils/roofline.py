"""Roofline-term extraction from compiled XLA artifacts (TPU v5e model).

compute_s   = HLO_FLOPs(per device) / peak_FLOPs
memory_s    = HLO_bytes(per device) / HBM_bw
collective_s= collective bytes (per device, parsed from optimized HLO) / ICI_bw

cost_analysis() reports per-device numbers for SPMD-partitioned programs;
collective bytes are NOT in cost_analysis, so we parse the optimized HLO text
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (async *-start forms counted once).
"""
from __future__ import annotations

import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind from optimized HLO text.

    Output-shape bytes are the wire-relevant payload for gather/reduce ops
    ('-done' ops and fused regions are skipped; '-start' counted once).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float) -> dict:
    comp = flops / PEAK_FLOPS_BF16
    mem = bytes_accessed / HBM_BW
    coll = coll_bytes / ICI_BW
    dominant = max(("compute", comp), ("memory", mem), ("collective", coll),
                   key=lambda kv: kv[1])[0]
    return {
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dominant,
        "bound_s": max(comp, mem, coll),
    }


def model_flops(cfg, shape, n_params_active: float, n_params_total: float) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for training, 2·N·B per decoded token,
    2·N·(B·S) for prefill (forward only)."""
    if shape.kind == "train":
        return 6.0 * n_params_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_params_active * shape.global_batch * shape.seq_len
    return 2.0 * n_params_active * shape.global_batch  # one decode step


def count_params(shapes_tree) -> float:
    import jax

    return float(sum(s.size for s in jax.tree.leaves(shapes_tree)))


def active_params(cfg, total: float) -> float:
    """MoE: approximate active params = total - (inactive expert fraction)."""
    if not cfg.n_experts:
        return total
    import jax

    # expert weights: wi + wo per layer
    moe_layers = cfg.n_layers - cfg.first_dense_layers
    expert_p = moe_layers * cfg.n_experts * (cfg.d_model * 2 * cfg.d_expert + cfg.d_expert * cfg.d_model)
    active_expert_p = expert_p * cfg.top_k / cfg.n_experts
    return total - expert_p + active_expert_p
