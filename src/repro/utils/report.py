"""Aggregate dry-run artifacts into the EXPERIMENTS.md §Dry-run/§Roofline
markdown tables.

  PYTHONPATH=src python -m repro.utils.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_b(x):
    for unit, s in ((1e12, "TB"), (1e9, "GB"), (1e6, "MB"), (1e3, "KB")):
        if abs(x) >= unit:
            return f"{x/unit:.2f}{s}"
    return f"{x:.0f}B"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(dir_, include_tagged=False):
    recs = []
    for f in sorted(Path(dir_).glob("*.json")):
        if not include_tagged and "__h_" in f.stem:
            continue  # hillclimb artifacts live in §Perf, not the baseline
        rec = json.loads(f.read_text())
        rec["_tag"] = f.stem.split("__")[3] if f.stem.count("__") >= 3 else ""
        recs.append(rec)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]), r["mesh"]))
    return recs


def dryrun_table(recs):
    out = ["| arch | shape | mesh | bytes/dev (args+out+temp) | HLO GFLOP/dev | HLO bytes/dev | coll bytes/dev (ag/ar/rs/a2a/cp) | compile |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory_analysis"]
        resident = m.get("argument_size_in_bytes", 0) + m.get("output_size_in_bytes", 0)
        temp = m.get("temp_size_in_bytes", 0)
        c = r["collectives"]
        cstr = "/".join(fmt_b(c.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_b(resident)} + {fmt_b(temp)} temp "
            f"| {r['flops_per_device']/1e9:.1f} "
            f"| {fmt_b(r['bytes_per_device'])} "
            f"| {fmt_b(r['collective_bytes_per_device'])} ({cstr}) "
            f"| {r['compile_s']:.0f}s |"
        )
    return "\n".join(out)


def roofline_table(recs, mesh="single"):
    out = ["| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "compute": "more MXU-efficient layout / larger tiles",
        "memory": "cut HBM traffic: lower bits, fuse dequant, better remat",
        "collective": "reshape sharding: fewer/smaller gathers or overlap",
    }
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} "
            f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} | {notes[t['dominant']]} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"# {len(recs)} cells\n")
    print("## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
