"""Training step factory: microbatched grad accumulation (lax.scan), mixed
precision (bf16 params/activations, f32 loss & optimizer math), optional
gradient clipping.  Under pjit the FSDP all-gathers of step i+1 overlap the
backprop of step i via XLA's latency-hiding scheduler (flags set in
launch/train.py)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "step"], meta_fields=[]
)


def init_train_state(model, optimizer, rng):
    params = model.init(rng)
    return TrainState(params=params, opt_state=optimizer.init(params), step=jnp.zeros((), jnp.int32))


def train_state_shapes(model, optimizer):
    """Abstract TrainState for the dry-run (no allocation)."""
    p_shapes = model.param_shapes()

    def mk(rng):
        params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shapes)
        return TrainState(
            params=params, opt_state=optimizer.init(params),
            step=jnp.zeros((), jnp.int32),
        )

    return jax.eval_shape(mk, jax.random.PRNGKey(0))


def _split_microbatches(batch, n):
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(model, optimizer, *, microbatches: int = 1, clip_norm: float = 1.0):
    cfg = model.cfg

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state: TrainState, batch):
        batch = jax.tree.map(
            lambda x: constrain(x, ("pod", "data")), batch
        )
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                g_acc, l_acc = carry
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches, g_acc, grads
                )
                return (g_acc, l_acc + loss / microbatches), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss), _ = lax.scan(acc_body, (g0, 0.0), mbs)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        if clip_norm:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        updates, opt_state = optimizer.update(grads, state.opt_state, state.params, state.step)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state, step=state.step + 1)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_state, metrics

    return train_step
