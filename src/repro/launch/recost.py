"""Re-derive roofline terms from saved dry-run HLO artifacts without
recompiling (cost-model iterations are decoupled from the compile sweep).

  PYTHONPATH=src python -m repro.launch.recost [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import gzip
import json
from pathlib import Path

from repro.utils import hlo_cost, roofline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    d = Path(args.dir)
    for jf in sorted(d.glob("*.json")):
        hf = d / "hlo" / (jf.stem + ".hlo.gz")
        if not hf.exists():
            print(f"[recost] {jf.stem}: no saved HLO, skipping")
            continue
        rec = json.loads(jf.read_text())
        with gzip.open(hf, "rt") as f:
            hc = hlo_cost.analyze(f.read())
        rec["flops_per_device"] = hc["flops"]
        rec["bytes_per_device"] = hc["bytes"]
        rec["collective_bytes_per_device"] = hc["collective_bytes"]
        rec["collectives"] = hc["collectives"]
        rec["roofline"] = roofline.roofline_terms(
            hc["flops"], hc["bytes"], hc["collective_bytes"]
        )
        rec["useful_flops_ratio"] = rec["model_flops"] / max(
            1.0, hc["flops"] * rec["chips"]
        )
        jf.write_text(json.dumps(rec, indent=2))
        print(f"[recost] {jf.stem}: flops={hc['flops']:.3e} bytes={hc['bytes']:.3e} "
              f"coll={hc['collective_bytes']:.3e}")


if __name__ == "__main__":
    main()
