"""Training launcher: pjit train loop with fault tolerance.

Features: FSDP/TP sharding from logical rules, synthetic host-sharded data
pipeline with background prefetch, checkpoint/restart (atomic, keep-k,
resharding restore -> elastic scaling), step retry with rollback on transient
failure, XLA latency-hiding-scheduler flags for compute/comm overlap.

Multi-host note: on a real cluster each process calls
``jax.distributed.initialize(coordinator, num_processes, process_id)`` before
anything else; preemption of a host surfaces as a failed step -> the loop
restores the latest checkpoint on the surviving mesh (make_elastic_mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import os
import time

# Latency-hiding scheduler: overlap FSDP all-gathers/reduce-scatters with
# compute inside the scan-over-layers (no-op on CPU, essential on TPU).
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true",
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs.base import SHAPES, ShapeSpec, get_config, smoke_config  # noqa: E402
from repro.data.pipeline import Prefetcher, make_batch  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.mesh import make_elastic_mesh  # noqa: E402
from repro.models.zoo import build_model  # noqa: E402
from repro.optim import get_optimizer  # noqa: E402
from repro.train.step import init_train_state, make_train_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--max-failures", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    optimizer = get_optimizer(cfg.optimizer, total_steps=args.steps)
    mesh = make_elastic_mesh(model_parallel=args.model_parallel)
    rules = shd.base_rules(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    train_step = make_train_step(model, optimizer, microbatches=cfg.microbatches)
    param_sh = shd.shardings_for(model.param_defs(), rules, mesh)

    with jax.set_mesh(mesh):
        state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
        # place params on their shardings (optimizer states follow via jit)
        state = state.__class__(
            params=jax.device_put(state.params, param_sh),
            opt_state=state.opt_state,
            step=state.step,
        )
        start = 0
        if args.resume and mgr.latest_step() is not None:
            state, start = mgr.restore(None, state)
            print(f"[train] resumed from step {start}")

        jitted = jax.jit(train_step, donate_argnums=(0,))
        pre = Prefetcher(cfg, shape, mesh=mesh, start_step=start)
        failures = 0
        t0 = time.time()
        step = start
        try:
            while step < args.steps:
                _, batch = pre.next()
                try:
                    state, metrics = jitted(state, batch)
                except Exception as e:  # transient failure -> rollback
                    failures += 1
                    print(f"[train] step {step} failed ({e!r}); "
                          f"failure {failures}/{args.max_failures}")
                    if failures > args.max_failures or mgr.latest_step() is None:
                        raise
                    state, step = mgr.restore(None, state)
                    print(f"[train] rolled back to step {step}")
                    continue
                step += 1
                if step % args.log_every == 0:
                    loss = float(metrics["loss"])
                    gn = float(metrics["grad_norm"])
                    dt = (time.time() - t0) / max(1, step - start)
                    print(f"[train] step {step} loss={loss:.4f} gnorm={gn:.3f} "
                          f"{dt*1e3:.0f} ms/step")
                if step % args.ckpt_every == 0:
                    mgr.save_async(step, state)
            mgr.save(step, state)
            print(f"[train] done at step {step}; final loss "
                  f"{float(metrics['loss']):.4f}")
        finally:
            pre.close()
            mgr.wait()


if __name__ == "__main__":
    main()
