import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract memory/cost/collective statistics for the roofline analysis.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init) — hence the two lines above.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from repro.configs.base import SHAPES, _REGISTRY, get_config  # noqa: E402
from repro.core.attention import use_splitkv  # noqa: E402
from repro.data.pipeline import batch_specs  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.dist.state_specs import decode_state_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh, pick_batch_axes  # noqa: E402
from repro.models.zoo import build_model  # noqa: E402
from repro.optim import get_optimizer  # noqa: E402
from repro.train.step import make_train_step, train_state_shapes  # noqa: E402
from repro.utils import hlo_cost, roofline  # noqa: E402


def _to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PS) else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, PS) or x is None,
    )


def _opt_state_specs(defs, pspecs, optimizer_name):
    from repro.models.params import P

    if optimizer_name == "adamw":
        return {"m": pspecs, "v": pspecs}

    def leaf(p: P, spec: PS):
        parts = list(spec) + [None] * (len(p.shape) - len(spec))
        if len(p.shape) >= 2:
            return {"row": PS(*parts[:-1]), "col": PS(*parts[:-2], parts[-1])}
        return {"v": PS(*parts)}

    return jax.tree.map(leaf, defs, pspecs, is_leaf=lambda x: isinstance(x, (P, PS)))


def _train_state_specs(model, cfg, mesh, rules):
    from repro.train.step import TrainState

    defs = model.param_defs()
    pspecs = shd.specs_for(defs, rules, mesh)
    ospecs = _opt_state_specs(defs, pspecs, cfg.optimizer)
    return TrainState(params=pspecs, opt_state=ospecs, step=PS())


def _decode_inputs(model, cfg, mesh, shape):
    b = shape.global_batch
    max_seq = shape.seq_len
    state_struct = jax.eval_shape(lambda: model.init_decode_state(b, max_seq))
    seq_ax = "data" if pick_batch_axes(mesh, b) == () else None
    state_specs = decode_state_specs(model, mesh, global_batch=b, seq_ax=seq_ax)
    batch_ax = pick_batch_axes(mesh, b) or None
    tok_struct = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_spec = PS(batch_ax)
    return state_struct, state_specs, tok_struct, tok_spec, seq_ax


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, verbose: bool = True, overrides: dict | None = None,
             tag_suffix: str = "", serve_state_auto: bool = False):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{cfg.name}__{shape_name}__{mesh_name}" + (f"__{tag_suffix}" if tag_suffix else "")
    model = build_model(cfg)
    rules = shd.base_rules(cfg)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            optimizer = get_optimizer(cfg.optimizer)
            step_fn = make_train_step(model, optimizer, microbatches=cfg.microbatches)
            state_struct = train_state_shapes(model, optimizer)
            state_specs = _train_state_specs(model, cfg, mesh, rules)
            state_sh = _to_shardings(state_specs, mesh)
            b_specs = batch_specs(cfg, shape, mesh=mesh)
            metric_sh = {"loss": NamedSharding(mesh, PS()),
                         "grad_norm": NamedSharding(mesh, PS())}
            jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                             out_shardings=(state_sh, metric_sh))
            lowered = jitted.lower(state_struct, b_specs)
        elif shape.kind == "prefill":
            params_struct = model.param_shapes()
            params_sh = _to_shardings(shd.specs_for(model.param_defs(), rules, mesh), mesh)
            b_specs = batch_specs(cfg, shape, mesh=mesh)
            max_seq = shape.seq_len + cfg.kv_block

            def prefill_fn(params, batch):
                return model.prefill(params, batch, max_seq)

            jitted = jax.jit(prefill_fn, in_shardings=(params_sh, None))
            lowered = jitted.lower(params_struct, b_specs)
        else:  # decode
            params_struct = model.param_shapes()
            params_sh = _to_shardings(shd.specs_for(model.param_defs(), rules, mesh), mesh)
            state_struct, state_specs, tok_struct, tok_spec, seq_ax = _decode_inputs(
                model, cfg, mesh, shape
            )
            if serve_state_auto:
                # compiler-placed decode state (§Perf iteration A2): forcing
                # hand-written cache shardings made the partitioner re-gather
                # the whole packed cache at entry; letting XLA choose the
                # state placement (and pinning the state there between steps,
                # via compiled.input_shardings) removes the round-trip.
                state_sh = jax.tree.map(lambda _: None, state_specs,
                                        is_leaf=lambda x: True)
            else:
                state_sh = _to_shardings(state_specs, mesh)
            tok_sh = NamedSharding(mesh, tok_spec)

            def serve_step(params, state, tokens):
                return model.decode_step(params, state, tokens)

            jitted = jax.jit(serve_step, in_shardings=(params_sh, state_sh, tok_sh),
                             out_shardings=(None, state_sh))
            ctx = use_splitkv(mesh) if seq_ax else _NullCtx()
            with ctx:
                lowered = jitted.lower(params_struct, state_struct, tok_struct)

        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()

    # trip-count-aware HLO cost model (XLA cost_analysis counts while bodies
    # once — useless for scan-over-layers programs; see utils/hlo_cost.py)
    hc = hlo_cost.analyze(hlo)
    flops = hc["flops"]
    bytes_acc = hc["bytes"]
    coll = dict(hc["collectives"], total=hc["collective_bytes"])
    terms = roofline.roofline_terms(flops, bytes_acc, coll["total"])
    xla_raw = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }

    n_total = roofline.count_params(model.param_shapes())
    n_active = roofline.active_params(cfg, n_total)
    n_chips = mesh.size
    mflops = roofline.model_flops(cfg, shape, n_active, n_total)
    useful_ratio = mflops / max(1.0, flops * n_chips)

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            mem_fields[f] = int(getattr(mem, f))
        except Exception:
            pass

    rec = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "kind": shape.kind,
        "compile_s": round(compile_s, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "memory_analysis": mem_fields,
        "roofline": terms,
        "n_params_total": n_total,
        "n_params_active": n_active,
        "model_flops": mflops,
        "useful_flops_ratio": useful_ratio,
        "xla_cost_analysis_raw": xla_raw,  # per-while-body-once (reference)
        "hlo_bytes": len(hlo),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    hlo_dir = out_dir / "hlo"
    hlo_dir.mkdir(exist_ok=True)
    with gzip.open(hlo_dir / f"{tag}.hlo.gz", "wt") as f:
        f.write(hlo)
    if verbose:
        print(f"[dryrun] {tag}: compile ok in {compile_s:.0f}s")
        print(f"  memory_analysis: {mem_fields}")
        print(f"  cost_analysis: flops={flops:.3e} bytes={bytes_acc:.3e}")
        print(f"  collective bytes/device: {coll['total']:.3e}")
        print(f"  roofline: {terms}")
    return rec


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VAL",
                    help="ArchConfig overrides for perf iterations, e.g. "
                         "--set sharding_profile=tp --set kv_bits=2")
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    ap.add_argument("--serve-state-auto", action="store_true",
                    help="compiler-placed decode state (perf iteration)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    out = Path(args.out)
    archs = [a for a in _REGISTRY if a != "llama2_7b"] if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cfg_name = get_config(arch).name
                tag = f"{cfg_name}__{shape}__{'multi' if mp else 'single'}"
                if args.skip_existing and (out / f"{tag}.json").exists():
                    print(f"[dryrun] {tag}: cached, skipping")
                    continue
                try:
                    run_cell(arch, shape, mp, out, overrides=overrides or None,
                             tag_suffix=args.tag,
                             serve_state_auto=args.serve_state_auto)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] {tag}: FAILED: {e}")
                    traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-run cells compiled successfully")


if __name__ == "__main__":
    main()
