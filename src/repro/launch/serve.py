"""Serving launcher: continuous-batching paged serving with the quantized
KV cache (dense slot fallback for models without a paged decode path).

Usage (CPU demo with a reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --slots 4 --max-new 24

Page-pool sizing: --pages bounds the KV pool; by default the pool is fully
provisioned (slots * max_seq worth of pages).  Undersize it (e.g.
--pages 12) to exercise admission backpressure: requests wait in the queue
until completions return pages.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--kv-bits", type=int, default=4)
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: fully provisioned)")
    ap.add_argument("--dense", action="store_true",
                    help="force the legacy dense slot engine")
    ap.add_argument("--splitkv", choices=("auto", "always", "never"),
                    default="auto", help="cross-chip split-KV routing policy")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.with_(kv_bits=args.kv_bits)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, slots=args.slots, max_seq=args.max_seq,
        paged=False if args.dense else None, n_pages=args.pages,
        splitkv=args.splitkv,
    )
    print(f"[serve] engine mode: {'paged' if engine.paged else 'dense'}"
          + (f", pool={engine.n_pages} pages" if engine.paged else ""))

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    stats = engine.run()
    print(f"[serve] {stats}")


if __name__ == "__main__":
    main()
