"""Serving launcher: continuous-batching paged serving with the quantized
KV cache — every cache family decodes through the page table (plain/GQA
attention, MLA latent pools, hybrid Mamba2+attention; no-KV recurrent
models serve through the exact-length shim).

Usage (CPU demo with a reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --slots 4 --max-new 24
  PYTHONPATH=src python -m repro.launch.serve --family mla --smoke \
      --requests 8

``--family {attn,mla,hybrid,xlstm}`` picks a representative arch for the
cache family (llama3-8b / deepseek-v3-671b / zamba2-7b / xlstm-1.3b) so the
unified paged engine is exercisable from the CLI for all families.

Page-pool sizing: --pages bounds the KV pool; by default the pool is fully
provisioned (slots * max_seq worth of pages).  Undersize it (e.g.
--pages 12) to exercise admission backpressure: requests wait in the queue
until completions return pages.  With ``--reserve-policy expected`` the
scheduler admits against a quantile of the remaining decode budget instead
of the worst case; if the pool later runs dry the engine preempts a victim
(``--preempt-policy``) and rematerializes it bitwise-identically on
re-admission (docs/SERVING.md §10).  ``--audit-every N`` cross-checks the
pool/page-table/prefix-index invariants every N cycles.  ``--spec-k K``
(K > 1) turns on self-speculative decoding: K-token greedy drafts read the
same committed pools at ``--spec-bits`` precision and a single batched
full-fidelity pass verifies them, keeping the output stream bitwise equal
to sequential decode (docs/SERVING.md §11).

Telemetry (docs/OBSERVABILITY.md): ``--trace-out trace.json`` records every
request lifecycle span and engine phase slice and writes a Chrome
``trace_event`` file (open in Perfetto / chrome://tracing) plus a
``.jsonl`` sibling with the raw events.  ``--metrics-every N`` prints the
Prometheus text exposition of the metrics registry every N cycles.  The
summary line always includes TTFT/TPOT percentiles and the host-stall
fraction (share of each decode cycle NOT spent waiting on the device).
"""
from __future__ import annotations

import argparse
import pathlib

import jax
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine

FAMILY_ARCHS = {
    "attn": "llama3-8b",
    "mla": "deepseek-v3-671b",
    "hybrid": "zamba2-7b",
    "xlstm": "xlstm-1.3b",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="explicit architecture (overrides --family)")
    ap.add_argument("--family", choices=sorted(FAMILY_ARCHS), default=None,
                    help="serve a representative arch of this cache family")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--kv-bits", type=int, default=4)
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: fully provisioned)")
    ap.add_argument("--dense", action="store_true",
                    help="force the exact-length shim (dense decode state)")
    ap.add_argument("--splitkv", choices=("auto", "always", "never"),
                    default="auto", help="cross-chip split-KV routing policy")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="give every prompt a common template prefix of this "
                         "many tokens so the prefix index reuses resident "
                         "pages (docs/SERVING.md)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the scheduler's prompt-prefix index")
    ap.add_argument("--reserve-policy", choices=("worst_case", "expected"),
                    default="worst_case",
                    help="admission reservation: full lifetime worst case, "
                         "or a quantile of the remaining decode budget "
                         "(backed by preemption-by-rematerialization)")
    ap.add_argument("--expected-quantile", type=float, default=0.5,
                    help="decode-budget quantile reserved under "
                         "--reserve-policy expected (0=only what is certain)")
    ap.add_argument("--preempt-policy", choices=("youngest", "fewest_pages"),
                    default="youngest",
                    help="victim selection when the pool runs dry mid-decode")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="run the pool/table/index invariant auditor every N "
                         "engine cycles (0 disables; always audits at drain "
                         "when enabled)")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="self-speculative decode depth: draft K tokens per "
                         "cycle against the low-bit committed pools, verify "
                         "in one batched full-fidelity pass (>1 enables; "
                         "docs/SERVING.md §11)")
    ap.add_argument("--spec-bits", type=int, default=None,
                    help="draft-path read precision in bits (default: "
                         "min(2, kv_bits); must be <= kv_bits)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL on the engine clock; overdue "
                         "requests retire as EXPIRED")
    ap.add_argument("--strict", action="store_true",
                    help="raise on unadmittable submissions instead of "
                         "retiring them as REJECTED")
    ap.add_argument("--async-runtime", action="store_true",
                    help="overlapped decode runtime: no per-cycle host sync "
                         "(bounded in-flight window + background completion "
                         "thread); bitwise-identical to the sync cycle "
                         "(docs/SERVING.md §13)")
    ap.add_argument("--async-window", type=int, default=2, metavar="W",
                    help="in-flight decode steps before the host consumes "
                         "the oldest (higher = more overlap, more lag "
                         "discovering retirement)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON here (open in "
                         "Perfetto) plus a .jsonl sibling with the raw "
                         "structured events (docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="print the Prometheus text exposition of the "
                         "metrics registry every N engine cycles (0 off)")
    args = ap.parse_args()
    if args.arch is None:
        if args.family is None:
            ap.error("one of --arch / --family is required")
        args.arch = FAMILY_ARCHS[args.family]

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.with_(kv_bits=args.kv_bits)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, slots=args.slots, max_seq=args.max_seq,
        paged=False if args.dense else None, n_pages=args.pages,
        splitkv=args.splitkv, share_prefix=not args.no_prefix_sharing,
        reserve_policy=args.reserve_policy,
        expected_quantile=args.expected_quantile,
        preempt_policy=args.preempt_policy,
        audit_every=args.audit_every, strict=args.strict,
        spec_k=args.spec_k, spec_bits=args.spec_bits,
        async_runtime=args.async_runtime, async_window=args.async_window,
        trace=args.trace_out is not None,
        metrics_every=args.metrics_every,
    )
    print(f"[serve] engine mode: {'paged' if engine.paged else 'exact-length shim'}"
          + (f", pool={engine.n_pages} pages "
             f"({engine.kv_page_bytes} B/page)" if engine.paged else ""))

    rng = np.random.default_rng(0)
    sharing_demo = (
        engine.paged and not args.no_prefix_sharing
        and args.shared_prefix_len > 0
    )
    shared_len = min(args.shared_prefix_len, args.prompt_len)
    prefix = rng.integers(0, cfg.vocab, shared_len).astype(np.int32)
    for uid in range(args.requests):
        tail = rng.integers(
            0, cfg.vocab, args.prompt_len - shared_len
        ).astype(np.int32)
        # sharing demo: stagger completions (real traffic never retires in
        # lockstep) so request lifetimes overlap and the prefix index keeps
        # live donors — pages are discoverable only while a holder is
        # resident.  Without sharing, keep the legacy fixed --max-new.
        engine.submit(Request(
            uid=uid,
            prompt=np.concatenate([prefix, tail]),
            max_new_tokens=args.max_new + (uid % 3 if sharing_demo else 0),
            deadline_s=args.deadline_s,
        ))
    stats = engine.run()
    print(f"[serve] {stats}")
    phase = stats.get("phase_s", {})
    cyc = phase.get("cycle", 0.0)
    print(
        "[serve] latency: "
        f"ttft_p50={stats['ttft_p50_ms']:.2f}ms"
        f" ttft_p99={stats['ttft_p99_ms']:.2f}ms"
        f" tpot_p50={stats['tpot_p50_ms']:.3f}ms"
        f" tpot_p99={stats['tpot_p99_ms']:.3f}ms"
        f" queue_wait_p50={stats['queue_wait_p50_ms']:.2f}ms"
    )
    breakdown = " ".join(
        f"{k}={v:.3f}s({v / cyc:.0%})" if cyc > 0 else f"{k}={v:.3f}s"
        for k, v in sorted(phase.items()) if k != "cycle"
    )
    print(
        f"[serve] phases: cycle={cyc:.3f}s {breakdown} "
        f"host_stall={stats['host_stall_fraction']:.1%}"
    )
    if stats.get("preempted"):
        print(
            f"[serve] pressure: preempted={stats['preempted']}"
            f" preempt_remat_tokens={stats['preempt_remat_tokens']}"
            f" audits={stats['audits']}"
        )
    if args.spec_k > 1:
        print(
            f"[serve] speculative: k={args.spec_k}"
            f" accept_rate={stats.get('spec_accept_rate', 0.0):.3f}"
            f" drafted={stats.get('spec_draft_tokens', 0)}"
            f" accepted={stats.get('spec_accepted_tokens', 0)}"
        )
    if engine.paged and not args.no_prefix_sharing:
        print(
            f"[serve] prefix sharing: hit_rate={stats['prefix_hit_rate']:.3f}"
            f" prefill_tokens_saved={stats['prefill_tokens_saved']}"
            f" cow_copies={stats['cow_copies']}"
        )
    if args.trace_out is not None:
        out = pathlib.Path(args.trace_out)
        engine.tracer.write_chrome(out)
        jsonl = out.with_suffix(".jsonl")
        engine.tracer.write_jsonl(jsonl)
        print(
            f"[serve] trace: {len(engine.tracer.events)} events -> {out} "
            f"(Chrome trace_event; open in Perfetto), raw -> {jsonl}"
        )


if __name__ == "__main__":
    main()
