"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model); the pod axis carries
pure data parallelism across the DCI, with optional int8+error-feedback
gradient compression (optim/grad_compress.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(*, model_parallel: int = 16):
    """Build the largest valid (data, model) mesh from currently-available
    devices — elastic scaling: after a restart with fewer healthy hosts, the
    same program runs on a smaller data axis and checkpoints reshard on
    restore (checkpoint/manager.py)."""
    n = len(jax.devices())
    model = min(model_parallel, n)
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def pick_batch_axes(mesh, global_batch: int) -> tuple:
    """Largest batch-sharding axis group that divides the global batch."""
    for axes in (("pod", "data"), ("data",), ()):
        if all(a in mesh.axis_names for a in axes):
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size and global_batch % size == 0:
                return axes
    return ()
