"""Strided low-bit packing layout — the TPU analogue of BitDecoding's
ldmatrix-induced fragment layout (paper §IV-A(1)).

A block of ``block_n`` tokens × ``d`` channels is quantized to ``bits``-wide
unsigned integers and packed into int32 words, ``R = 32 // bits`` values per
word.  The packing permutation is *strided*:

    word[i, c]  packs tokens  {k * (block_n // R) + i : k in [0, R)}
    bit-field k of word[i, c] = q[k * (block_n // R) + i, c]

so that extracting bit-plane ``k`` — one shift and one mask, full-width VPU
ops — yields the *contiguous* token range ``[k*block_n/R, (k+1)*block_n/R)``
and stacking the planes in order reconstructs the block in natural token
order.  Unpacking therefore needs **zero** relayout/permutation: the packing
order was chosen so the unpack the hardware wants is the identity, exactly
the paper's "induce the layout while computing" insight mapped from GPU
register fragments to TPU (sublane, lane) tiles.

Both the quantization (Residual) kernel and the decode (Packing) kernel
import these constants/functions so their layouts mirror each other, as the
paper requires ("the Packing Kernel mirrors the Residual Kernel's
instruction configuration").

In jnp terms the strided pack/unpack are pure reshapes along the leading
(sublane) axis:

    pack  : q.reshape(R, block_n // R, d)  ->  or-reduce over axis 0
    unpack: planes k=0..R-1 stacked on axis 0 -> reshape(block_n, d)
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

SUPPORTED_BITS = (2, 4, 8)
WORD_BITS = 32


def packing_ratio(bits: int) -> int:
    """Values per int32 word (paper's R = word / beta)."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    return WORD_BITS // bits


def words_per_block(block_n: int, bits: int) -> int:
    r = packing_ratio(bits)
    if block_n % r:
        raise ValueError(f"block_n={block_n} must be a multiple of R={r}")
    return block_n // r


def qmax(bits: int) -> int:
    return (1 << bits) - 1


def pack_strided(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned quantized values into int32 words with the strided layout.

    q: int32[..., block_n, d] with values in [0, 2**bits).
    returns int32[..., block_n // R, d].

    Disjoint bit-ranges mean the or-combine can be expressed as a sum; we use
    explicit ``|`` to make the no-carry property structural.
    """
    r = packing_ratio(bits)
    *lead, n, d = q.shape
    npr = words_per_block(n, bits)
    planes = q.reshape(*lead, r, npr, d)
    word = planes[..., 0, :, :] << 0
    for k in range(1, r):
        word = word | (planes[..., k, :, :] << (bits * k))
    return word.astype(jnp.int32)


def unpack_strided(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`pack_strided`.

    w: int32[..., npr, d]  ->  int32[..., npr * R, d] in natural token order.

    Mask-after-shift makes the extraction correct under arithmetic shift of
    the (possibly negative) int32 word — the lop3-free TPU dequant path.
    """
    r = packing_ratio(bits)
    mask = qmax(bits)
    planes = [(w >> (bits * k)) & mask for k in range(r)]
    stacked = jnp.stack(planes, axis=-3)  # [..., R, npr, d]
    *lead, _, npr, d = stacked.shape
    return stacked.reshape(*lead, r * npr, d)


@functools.lru_cache(maxsize=None)
def plane_shift_mask(bits: int) -> tuple[tuple[int, ...], int]:
    """Static (shifts, mask) used by the Pallas kernels' in-register unpack."""
    r = packing_ratio(bits)
    return tuple(bits * k for k in range(r)), qmax(bits)
