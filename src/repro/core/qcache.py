"""Quantized KV cache with half-precision residual buffer (paper §IV-A(2), §V-B).

The cache partitions the sequence  X = X_pack ∪ X_res  (paper Eq. before (1)):
packed low-bit blocks of ``block_n`` tokens plus a bf16 residual tail of
capacity ``N_r = block_n`` — the TPU tile-aligned instantiation of the paper's
``N_r = P_n × W_n × R``.  Newly decoded tokens append to the residual; when it
fills, the whole block is quantized+packed in one fused step (Residual
Kernel) and the residual restarts.  ``shared_kv=True`` stores a single latent
stream (MLA mode) — no V-side fields.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import layout, quantizer
from repro.kernels.kv_quant import ops as kvq_ops


@dataclasses.dataclass
class QuantKVCache:
    # packed low-bit cache + metadata ("half2" scale/zero pairs)
    kw: jax.Array          # int32 [B, H, nb, npr, d_k]
    k_scale: jax.Array
    k_zero: jax.Array
    vw: jax.Array | None   # int32 [B, H, nb, npr, d_v]; None when shared_kv
    v_scale: jax.Array | None
    v_zero: jax.Array | None
    # half-precision residual cache
    k_res: jax.Array       # bf16 [B, H, block_n, d_k]
    v_res: jax.Array | None
    # occupancy
    pack_blocks: jax.Array  # int32 [B]
    res_len: jax.Array      # int32 [B]
    # static config
    bits: int
    block_n: int
    k_gran: str
    shared_kv: bool

    @property
    def length(self) -> jax.Array:
        return self.pack_blocks * self.block_n + self.res_len

    @property
    def capacity(self) -> int:
        return (self.kw.shape[2] + 1) * self.block_n


jax.tree_util.register_dataclass(
    QuantKVCache,
    data_fields=[
        "kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero",
        "k_res", "v_res", "pack_blocks", "res_len",
    ],
    meta_fields=["bits", "block_n", "k_gran", "shared_kv"],
)


def init_cache(
    batch: int,
    h_kv: int,
    d_k: int,
    max_seq: int,
    *,
    d_v: int | None = None,
    bits: int = 4,
    block_n: int = 128,
    k_gran: str = "channel",
    shared_kv: bool = False,
    param_dtype=jnp.bfloat16,
    res_dtype=jnp.bfloat16,
) -> QuantKVCache:
    """Allocate an empty cache with capacity >= max_seq tokens."""
    nb = max(1, -(-max_seq // block_n))
    npr = layout.words_per_block(block_n, bits)
    if k_gran == "channel":
        kp_shape = (batch, h_kv, nb, d_k)
    else:
        kp_shape = (batch, h_kv, nb, block_n)
    z32 = lambda s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zp = lambda s: jnp.zeros(s, param_dtype)  # noqa: E731
    if shared_kv:
        vw = v_scale = v_zero = v_res = None
    else:
        d_v = d_v if d_v is not None else d_k
        vw = z32((batch, h_kv, nb, npr, d_v))
        v_scale = zp((batch, h_kv, nb, block_n))
        v_zero = zp((batch, h_kv, nb, block_n))
        v_res = jnp.zeros((batch, h_kv, block_n, d_v), res_dtype)
    return QuantKVCache(
        kw=z32((batch, h_kv, nb, npr, d_k)),
        k_scale=zp(kp_shape),
        k_zero=zp(kp_shape),
        vw=vw, v_scale=v_scale, v_zero=v_zero,
        k_res=jnp.zeros((batch, h_kv, block_n, d_k), res_dtype),
        v_res=v_res,
        pack_blocks=z32((batch,)),
        res_len=z32((batch,)),
        bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
    )


def _quant_one_block(x, cache: QuantKVCache, gran: str, impl: str):
    """x [H, block_n, d] -> (words [H,1,npr,d], scale, zero) via the ref path
    (vmap-safe; used per-batch-element inside append)."""
    w, s, z = kvq_ops.quantize_kv(
        x[None], cache.bits, gran, block_n=cache.block_n,
        param_dtype=cache.k_scale.dtype, impl=impl,
    )
    return w[0], s[0], z[0]


def append_decode(
    cache: QuantKVCache,
    k_new: jax.Array,  # [B, H, 1, d_k]
    v_new: jax.Array | None,  # [B, H, 1, d_v]; None when shared_kv
    *,
    quant_impl: str = "xla",
) -> QuantKVCache:
    """Append one decoded token per sequence; flush the residual block when
    full (paper: "Once per token generation, the Residual Kernel ... optionally
    quantizes it (when res_len = N_r) into packed format")."""
    block_n = cache.block_n

    def one(kw, ksc, kzp, vw, vsc, vzp, kres, vres, pb, rl, kn, vn):
        # 1. write the new token into the residual buffer
        kres = lax.dynamic_update_slice(kres, kn.astype(kres.dtype), (0, rl, 0))
        if not cache.shared_kv:
            vres = lax.dynamic_update_slice(vres, vn.astype(vres.dtype), (0, rl, 0))
        rl = rl + 1
        full = rl == block_n

        # 2. unconditionally quantize the residual block (cheap: one block),
        #    commit only when full.  The select happens at BLOCK granularity
        #    (read-modify-write one block), not on the whole cache array —
        #    a whole-array jnp.where would copy the full per-layer cache
        #    every decode step (§Perf iteration: ~50 GB/step saved at 32K).
        def commit(dst, upd, idx):
            cur = lax.dynamic_slice(dst, idx, upd.shape)
            sel = jnp.where(full, upd, cur)
            return lax.dynamic_update_slice(dst, sel, idx)

        w, s, z = _quant_one_block(kres, cache, cache.k_gran, quant_impl)
        kw = commit(kw, w, (0, pb, 0, 0))
        ksc = commit(ksc, s, (0, pb, 0))
        kzp = commit(kzp, z, (0, pb, 0))
        if not cache.shared_kv:
            wv, sv, zv = _quant_one_block(vres, cache, "tensor", quant_impl)
            vw = commit(vw, wv, (0, pb, 0, 0))
            vsc = commit(vsc, sv, (0, pb, 0))
            vzp = commit(vzp, zv, (0, pb, 0))
        pb = jnp.where(full, pb + 1, pb)
        rl = jnp.where(full, 0, rl)
        return kw, ksc, kzp, vw, vsc, vzp, kres, vres, pb, rl

    if cache.shared_kv:
        dummy = jnp.zeros((cache.kw.shape[0],), jnp.int32)
        out = jax.vmap(
            lambda kw, ksc, kzp, kres, pb, rl, kn, _d: one(
                kw, ksc, kzp, None, None, None, kres, None, pb, rl, kn, None
            )
        )(cache.kw, cache.k_scale, cache.k_zero, cache.k_res,
          cache.pack_blocks, cache.res_len, k_new, dummy)
        kw, ksc, kzp, vw, vsc, vzp, kres, vres, pb, rl = out
        vw = vsc = vzp = vres = None
    else:
        kw, ksc, kzp, vw, vsc, vzp, kres, vres, pb, rl = jax.vmap(one)(
            cache.kw, cache.k_scale, cache.k_zero,
            cache.vw, cache.v_scale, cache.v_zero,
            cache.k_res, cache.v_res, cache.pack_blocks, cache.res_len,
            k_new, v_new,
        )
    return dataclasses.replace(
        cache, kw=kw, k_scale=ksc, k_zero=kzp, vw=vw, v_scale=vsc, v_zero=vzp,
        k_res=kres, v_res=vres, pack_blocks=pb, res_len=rl,
    )


def prefill(
    cache: QuantKVCache,
    k: jax.Array,  # [B, H, L, d_k]
    v: jax.Array | None,
    *,
    quant_impl: str = "auto",
) -> QuantKVCache:
    """Fill the cache from a prefill of static length L: quantize the first
    L - (L mod N_r) tokens into packed blocks, keep the tail in the residual
    (paper §V-B(1))."""
    b, h, L, d_k = k.shape
    block_n = cache.block_n
    n_full = L // block_n
    res = L - n_full * block_n
    updates = {}
    if n_full:
        w, s, z = kvq_ops.quantize_kv(
            k[:, :, : n_full * block_n], cache.bits, cache.k_gran,
            block_n=block_n, param_dtype=cache.k_scale.dtype, impl=quant_impl,
        )
        updates["kw"] = lax.dynamic_update_slice(
            cache.kw, w, (0, 0, 0, 0, 0))
        updates["k_scale"] = lax.dynamic_update_slice(cache.k_scale, s, (0, 0, 0, 0))
        updates["k_zero"] = lax.dynamic_update_slice(cache.k_zero, z, (0, 0, 0, 0))
        if not cache.shared_kv:
            wv, sv, zv = kvq_ops.quantize_kv(
                v[:, :, : n_full * block_n], cache.bits, "tensor",
                block_n=block_n, param_dtype=cache.k_scale.dtype, impl=quant_impl,
            )
            updates["vw"] = lax.dynamic_update_slice(cache.vw, wv, (0, 0, 0, 0, 0))
            updates["v_scale"] = lax.dynamic_update_slice(cache.v_scale, sv, (0, 0, 0, 0))
            updates["v_zero"] = lax.dynamic_update_slice(cache.v_zero, zv, (0, 0, 0, 0))
    if res:
        kr = jnp.zeros_like(cache.k_res)
        kr = lax.dynamic_update_slice(
            kr, k[:, :, n_full * block_n :].astype(kr.dtype), (0, 0, 0, 0))
        updates["k_res"] = kr
        if not cache.shared_kv:
            vr = jnp.zeros_like(cache.v_res)
            vr = lax.dynamic_update_slice(
                vr, v[:, :, n_full * block_n :].astype(vr.dtype), (0, 0, 0, 0))
            updates["v_res"] = vr
    updates["pack_blocks"] = jnp.full((b,), n_full, jnp.int32)
    updates["res_len"] = jnp.full((b,), res, jnp.int32)
    return dataclasses.replace(cache, **updates)
