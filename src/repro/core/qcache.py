"""Quantized KV cache with half-precision residual buffer (paper §IV-A(2), §V-B).

The cache partitions the sequence  X = X_pack ∪ X_res  (paper Eq. before (1)):
packed low-bit blocks of ``block_n`` tokens plus a bf16 residual tail of
capacity ``N_r = block_n`` — the TPU tile-aligned instantiation of the paper's
``N_r = P_n × W_n × R``.  Newly decoded tokens append to the residual; when it
fills, the whole block is quantized+packed+committed in one fused pass (the
Residual Kernel, kernels/residual_flush) and the residual restarts.  The
flush is gated behind ``lax.cond`` so the other ``block_n - 1`` decode steps
do no quantization work.  ``shared_kv=True`` stores a single latent stream
(MLA mode) — no V-side fields.

Two at-rest layouts share this data model: the dense :class:`QuantKVCache`
(``[B, H, nb, ...]``, one private block range per sequence) and the paged
:class:`PagedQuantKVCache` (shared ``[P, H, ...]`` page pools walked through
per-sequence page tables — the serving engine's layout, allocated by
serve/pages.py).  Both append paths run the same gated fused flush.

See docs/ARCHITECTURE.md for the packed ``(words, scale, zero)`` layout spec.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import layout, quantizer
from repro.kernels.kv_quant import ops as kvq_ops
from repro.kernels.residual_flush import ops as rf_ops


@dataclasses.dataclass
class QuantKVCache:
    # packed low-bit cache + metadata ("half2" scale/zero pairs)
    kw: jax.Array          # int32 [B, H, nb, npr, d_k]
    k_scale: jax.Array
    k_zero: jax.Array
    vw: jax.Array | None   # int32 [B, H, nb, npr, d_v]; None when shared_kv
    v_scale: jax.Array | None
    v_zero: jax.Array | None
    # half-precision residual cache
    k_res: jax.Array       # bf16 [B, H, block_n, d_k]
    v_res: jax.Array | None
    # occupancy
    pack_blocks: jax.Array  # int32 [B]
    res_len: jax.Array      # int32 [B]
    # static config
    bits: int
    block_n: int
    k_gran: str
    shared_kv: bool

    @property
    def length(self) -> jax.Array:
        return self.pack_blocks * self.block_n + self.res_len

    @property
    def capacity(self) -> int:
        return (self.kw.shape[2] + 1) * self.block_n


jax.tree_util.register_dataclass(
    QuantKVCache,
    data_fields=[
        "kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero",
        "k_res", "v_res", "pack_blocks", "res_len",
    ],
    meta_fields=["bits", "block_n", "k_gran", "shared_kv"],
)


def init_cache(
    batch: int,
    h_kv: int,
    d_k: int,
    max_seq: int,
    *,
    d_v: int | None = None,
    bits: int = 4,
    block_n: int = 128,
    k_gran: str = "channel",
    shared_kv: bool = False,
    param_dtype=jnp.bfloat16,
    res_dtype=jnp.bfloat16,
    block_align: int | None = None,
) -> QuantKVCache:
    """Allocate an empty cache with capacity >= max_seq tokens.

    ``block_align`` rounds the packed block count ``nb`` up to a multiple
    (normally the split-KV mesh-axis size, plumbed through
    ``model.init_decode_state(..., mesh=...)``) so ``dist.splitkv`` shards the
    block axis without its per-call zero-pad — which is otherwise a full
    cache copy every decoded token when ``nb % axis_size != 0``.
    """
    nb = max(1, -(-max_seq // block_n))
    if block_align and block_align > 1:
        nb = -(-nb // block_align) * block_align
    npr = layout.words_per_block(block_n, bits)
    if k_gran == "channel":
        kp_shape = (batch, h_kv, nb, d_k)
    else:
        kp_shape = (batch, h_kv, nb, block_n)
    z32 = lambda s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zp = lambda s: jnp.zeros(s, param_dtype)  # noqa: E731
    if shared_kv:
        vw = v_scale = v_zero = v_res = None
    else:
        d_v = d_v if d_v is not None else d_k
        vw = z32((batch, h_kv, nb, npr, d_v))
        v_scale = zp((batch, h_kv, nb, block_n))
        v_zero = zp((batch, h_kv, nb, block_n))
        v_res = jnp.zeros((batch, h_kv, block_n, d_v), res_dtype)
    return QuantKVCache(
        kw=z32((batch, h_kv, nb, npr, d_k)),
        k_scale=zp(kp_shape),
        k_zero=zp(kp_shape),
        vw=vw, v_scale=v_scale, v_zero=v_zero,
        k_res=jnp.zeros((batch, h_kv, block_n, d_k), res_dtype),
        v_res=v_res,
        pack_blocks=z32((batch,)),
        res_len=z32((batch,)),
        bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
    )


def _append_residual(cache: QuantKVCache, k_new, v_new, mask=None):
    """Write one new token per sequence into the residual buffers.  Returns
    (k_res, v_res, res_len_after, full) — the shared front half of both
    append paths.

    ``mask`` ([B] bool, optional) freezes sequences: a ``False`` lane keeps
    its residual rows and ``res_len`` bitwise unchanged (``jnp.where`` with a
    true predicate returns the written array unchanged, so masked appends on
    live lanes are bitwise identical to unmasked ones).  This is the
    multi-token verify primitive for self-speculative decoding: lanes whose
    draft already diverged stop appending mid-scan.
    """

    def write(res, rl, new):
        return lax.dynamic_update_slice(res, new.astype(res.dtype), (0, rl, 0))

    k_res = jax.vmap(write)(cache.k_res, cache.res_len, k_new)
    v_res = None if cache.shared_kv else jax.vmap(write)(
        cache.v_res, cache.res_len, v_new
    )
    if mask is None:
        rl = cache.res_len + 1
    else:
        sel = mask[:, None, None, None]
        k_res = jnp.where(sel, k_res, cache.k_res)
        if v_res is not None:
            v_res = jnp.where(sel, v_res, cache.v_res)
        rl = cache.res_len + mask.astype(jnp.int32)
    return k_res, v_res, rl, rl == cache.block_n


def _commit_append(cache: QuantKVCache, packed, k_res, v_res, full, rl):
    """Shared back half of both append paths: write the (possibly flushed)
    packed arrays and update occupancy.  ``packed`` is the six packed fields
    in dataclass order (V side None when shared_kv)."""
    kw, ks, kz, vw, vs, vz = packed
    return dataclasses.replace(
        cache, kw=kw, k_scale=ks, k_zero=kz, vw=vw, v_scale=vs, v_zero=vz,
        k_res=k_res, v_res=v_res,
        pack_blocks=jnp.where(full, cache.pack_blocks + 1, cache.pack_blocks),
        res_len=jnp.where(full, 0, rl),
    )


def append_decode(
    cache: QuantKVCache,
    k_new: jax.Array,  # [B, H, 1, d_k]
    v_new: jax.Array | None,  # [B, H, 1, d_v]; None when shared_kv
    *,
    quant_impl: str = "auto",
    mask=None,
) -> QuantKVCache:
    """Append one decoded token per sequence; flush the residual block when
    full (paper: "Once per token generation, the Residual Kernel ... optionally
    quantizes it (when res_len = N_r) into packed format").

    The flush is *gated*: the fused residual-flush kernel
    (kernels/residual_flush) runs under a ``lax.cond`` taken only when some
    sequence's residual just filled — 1 step in ``block_n``.  On the other
    ``block_n - 1`` steps the hot path is exactly one token-row write into
    the bf16 residual plus the occupancy update; no quantization, packing,
    or packed-cache traffic at all (previously the whole residual block was
    re-quantized speculatively every token — kept as
    :func:`append_decode_speculative` for benchmarking).

    quant_impl: 'auto' | 'pallas' | 'xla', forwarded to
    ``residual_flush.ops.residual_flush``.

    ``mask`` ([B] bool, optional): lanes with ``mask=False`` keep the cache
    bitwise unchanged (no residual write, no occupancy change; a concurrent
    flush of *other* lanes selects the frozen lane's old block back — the
    same non-full select the gated flush always performs).  See
    :func:`_append_residual`.
    """
    k_res, v_res, rl, full = _append_residual(cache, k_new, v_new, mask)

    if cache.shared_kv:
        packed = (cache.kw, cache.k_scale, cache.k_zero)
    else:
        packed = (cache.kw, cache.k_scale, cache.k_zero,
                  cache.vw, cache.v_scale, cache.v_zero)

    def flush(p):
        if cache.shared_kv:
            kw, ks, kz = p
            vw = vs = vz = None
        else:
            kw, ks, kz, vw, vs, vz = p
        out = rf_ops.residual_flush(
            kw, ks, kz, vw, vs, vz, k_res, v_res,
            full.astype(jnp.int32), cache.pack_blocks,
            bits=cache.bits, block_n=cache.block_n, k_gran=cache.k_gran,
            shared_kv=cache.shared_kv, impl=quant_impl,
        )
        return out[:3] if cache.shared_kv else out

    packed = lax.cond(jnp.any(full), flush, lambda p: p, packed)
    if cache.shared_kv:
        packed = (*packed, None, None, None)
    return _commit_append(cache, packed, k_res, v_res, full, rl)


def append_decode_speculative(
    cache: QuantKVCache,
    k_new: jax.Array,  # [B, H, 1, d_k]
    v_new: jax.Array | None,  # [B, H, 1, d_v]; None when shared_kv
    *,
    quant_impl: str = "xla",
) -> QuantKVCache:
    """Pre-fusion append path: the flush op runs *unconditionally* on every
    decoded token (no ``lax.cond`` gate), re-quantizing the whole residual
    block and select-committing at block granularity each step.  Kept as the
    baseline for bench_quant_overhead's flush-vs-speculative sweep and as a
    second oracle for the gated path — identical cache contents by
    construction, since both call the same flush op and a non-full sequence
    selects its old block back."""
    k_res, v_res, rl, full = _append_residual(cache, k_new, v_new)
    packed = rf_ops.residual_flush(
        cache.kw, cache.k_scale, cache.k_zero,
        cache.vw, cache.v_scale, cache.v_zero,
        k_res, v_res, full.astype(jnp.int32), cache.pack_blocks,
        bits=cache.bits, block_n=cache.block_n, k_gran=cache.k_gran,
        shared_kv=cache.shared_kv, impl=quant_impl,
    )
    return _commit_append(cache, packed, k_res, v_res, full, rl)


def splitkv_block_align(mesh, axis: str | None) -> int | None:
    """Block-axis alignment implied by a split-KV mesh axis (None when no
    mesh / unknown axis) — the ``block_align`` to pass to :func:`init_cache`
    so ``dist.splitkv`` never zero-pads the packed-block axis per call."""
    if mesh is None or axis is None or axis not in mesh.axis_names:
        return None
    return int(mesh.shape[axis])


def prefill(
    cache: QuantKVCache,
    k: jax.Array,  # [B, H, L, d_k]
    v: jax.Array | None,
    *,
    lengths: jax.Array | None = None,
    quant_impl: str = "auto",
) -> QuantKVCache:
    """Fill the cache from a prefill of static length L: quantize the first
    L - (L mod N_r) tokens into packed blocks, keep the tail in the residual
    (paper §V-B(1)).

    ``lengths`` ([B] int32, optional) marks ragged batches — same-bucket
    prompts right-padded to a common L (the serve scheduler's bucketed
    prefill).  Per sequence ``b``, only ``lengths[b] // block_n`` packed
    blocks are valid and the residual holds tokens
    ``[lengths[b] - lengths[b] % block_n, lengths[b])``; blocks beyond
    ``pack_blocks[b]`` contain pad-polluted stats but are never read (the
    same invariant decode already relies on), and the next decode flush
    overwrites them.  Quantization is per-block, so valid blocks are bitwise
    identical to an exact-length prefill of the same prompt.
    """
    b, h, L, d_k = k.shape
    block_n = cache.block_n
    n_full = L // block_n
    res = L - n_full * block_n
    updates = _quantize_full_region(cache, k, v, n_full, quant_impl)
    if lengths is not None:
        # ragged tail: residual rows come from each sequence's own block
        # boundary (which may sit inside the padded batch's packed region)
        lo = ((lengths // block_n) * block_n).astype(jnp.int32)
        idx = jnp.minimum(
            lo[:, None] + jnp.arange(block_n, dtype=jnp.int32), L - 1
        )  # [B, block_n]; rows >= res_len[b] are unread garbage

        def tail(x, res_buf):
            g = jnp.take_along_axis(x, idx[:, None, :, None], axis=2)
            return g.astype(res_buf.dtype)

        updates["k_res"] = tail(k, cache.k_res)
        if not cache.shared_kv:
            updates["v_res"] = tail(v, cache.v_res)
        updates["pack_blocks"] = (lengths // block_n).astype(jnp.int32)
        updates["res_len"] = (lengths % block_n).astype(jnp.int32)
        return dataclasses.replace(cache, **updates)
    if res:
        kr = jnp.zeros_like(cache.k_res)
        kr = lax.dynamic_update_slice(
            kr, k[:, :, n_full * block_n :].astype(kr.dtype), (0, 0, 0, 0))
        updates["k_res"] = kr
        if not cache.shared_kv:
            vr = jnp.zeros_like(cache.v_res)
            vr = lax.dynamic_update_slice(
                vr, v[:, :, n_full * block_n :].astype(vr.dtype), (0, 0, 0, 0))
            updates["v_res"] = vr
    updates["pack_blocks"] = jnp.full((b,), n_full, jnp.int32)
    updates["res_len"] = jnp.full((b,), res, jnp.int32)
    return dataclasses.replace(cache, **updates)


# --------------------------------------------------------------------------
# Paged cache (vLLM-style page pools + per-sequence block tables)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PagedQuantKVCache:
    """Paged twin of :class:`QuantKVCache`: the packed blocks of all
    sequences live in shared *page pools* (``[P, H, ...]``, one pool entry =
    one ``block_n``-token block) and each sequence walks its blocks through a
    ``page_table`` row.  The bf16 residual tail stays dense per slot — only
    committed blocks are paged.

    Invariants (serve/pages.py is the allocator that maintains them):

    * pool pages ``[0, B)`` are per-slot scratch, never allocated to a
      request; ``page_table`` entries that don't (yet) hold an allocated page
      equal the slot index, so a flush through a stale/idle entry lands in
      the slot's own scratch page and destinations stay pairwise distinct;
    * ``page_table[b, j]`` holds the pool page of sequence ``b``'s packed
      block ``j`` for all ``j < pack_blocks[b]``, and the page for block
      ``pack_blocks[b]`` is allocated *before* the decode step whose flush
      commits it;
    * ``length = pack_blocks * block_n + res_len`` exactly as in the dense
      cache.

    ``shared_kv=True`` (the MLA latent mode) pages a *single* quantized
    latent stream: the V-side pools and residual are ``None`` and the decode
    kernel slices V out of the dequantized K tile, exactly as the dense
    shared mode does (kernels/paged_bitdecode).
    """

    # shared page pools
    kw: jax.Array           # int32 [P, H, npr, d_k]
    k_scale: jax.Array      # [P, H, d_k] (channel) | [P, H, block_n] (tensor)
    k_zero: jax.Array
    vw: jax.Array | None    # int32 [P, H, npr, d_v]; None when shared_kv
    v_scale: jax.Array | None  # [P, H, block_n]
    v_zero: jax.Array | None
    # dense per-slot residual tail
    k_res: jax.Array        # bf16 [B, H, block_n, d_k]
    v_res: jax.Array | None
    # per-sequence block table + occupancy
    page_table: jax.Array   # int32 [B, nb_max]
    pack_blocks: jax.Array  # int32 [B]
    res_len: jax.Array      # int32 [B]
    # static config
    bits: int
    block_n: int
    k_gran: str
    shared_kv: bool = False

    @property
    def length(self) -> jax.Array:
        return self.pack_blocks * self.block_n + self.res_len

    @property
    def n_pages(self) -> int:
        return self.kw.shape[0]


jax.tree_util.register_dataclass(
    PagedQuantKVCache,
    data_fields=[
        "kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero",
        "k_res", "v_res", "page_table", "pack_blocks", "res_len",
    ],
    meta_fields=["bits", "block_n", "k_gran", "shared_kv"],
)


def init_paged_cache(
    n_pages: int,
    batch: int,
    h_kv: int,
    d_k: int,
    nb_max: int,
    *,
    d_v: int | None = None,
    bits: int = 4,
    block_n: int = 128,
    k_gran: str = "channel",
    shared_kv: bool = False,
    param_dtype=jnp.bfloat16,
    res_dtype=jnp.bfloat16,
) -> PagedQuantKVCache:
    """Allocate empty page pools for ``batch`` decode slots.

    ``n_pages`` must be ``> batch``: the first ``batch`` pages are the
    per-slot scratch pages required by the flush-destination injectivity
    contract.  ``nb_max`` is the page-table width (max packed blocks any one
    sequence can hold).  The fresh ``page_table`` points every entry at the
    owning slot's scratch page.  ``shared_kv=True`` allocates the MLA latent
    layout: a single K-side pool set, no V pools/residual.
    """
    if n_pages <= batch:
        raise ValueError(
            f"n_pages={n_pages} must exceed batch={batch} (the first "
            "`batch` pages are reserved per-slot scratch)"
        )
    npr = layout.words_per_block(block_n, bits)
    kp_shape = (n_pages, h_kv, d_k) if k_gran == "channel" else (n_pages, h_kv, block_n)
    z32 = lambda s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zp = lambda s: jnp.zeros(s, param_dtype)  # noqa: E731
    table = jnp.broadcast_to(
        jnp.arange(batch, dtype=jnp.int32)[:, None], (batch, nb_max)
    )
    if shared_kv:
        vw = v_scale = v_zero = v_res = None
    else:
        d_v = d_v if d_v is not None else d_k
        vw = z32((n_pages, h_kv, npr, d_v))
        v_scale = zp((n_pages, h_kv, block_n))
        v_zero = zp((n_pages, h_kv, block_n))
        v_res = jnp.zeros((batch, h_kv, block_n, d_v), res_dtype)
    return PagedQuantKVCache(
        kw=z32((n_pages, h_kv, npr, d_k)),
        k_scale=zp(kp_shape),
        k_zero=zp(kp_shape),
        vw=vw, v_scale=v_scale, v_zero=v_zero,
        k_res=jnp.zeros((batch, h_kv, block_n, d_k), res_dtype),
        v_res=v_res,
        page_table=table,
        pack_blocks=z32((batch,)),
        res_len=z32((batch,)),
        bits=bits, block_n=block_n, k_gran=k_gran, shared_kv=shared_kv,
    )


def paged_append_decode(
    cache: PagedQuantKVCache,
    k_new: jax.Array,  # [B, H, 1, d_k]
    v_new: jax.Array | None,  # [B, H, 1, d_v]; None when shared_kv
    *,
    quant_impl: str = "auto",
    mask=None,
) -> PagedQuantKVCache:
    """Paged per-token append: write the new token row into the dense
    residual, and — gated behind ``lax.cond`` exactly like the dense
    :func:`append_decode` — commit just-filled residual blocks *through the
    page table* into the pools with the fused paged residual-flush kernel.
    Non-flush steps do zero quantize/pack/pool work.

    The flush destination per sequence is ``page_table[b, pack_blocks[b]]``
    when its residual filled, else the slot's scratch page ``b`` (keeps the
    kernel's destination set pairwise distinct; see PagedQuantKVCache's
    invariants).

    ``mask`` ([B] bool, optional): frozen lanes (``mask=False``) keep
    residual, occupancy, and their pool pages bitwise unchanged — a frozen
    lane is never ``full``, so any concurrent flush routes its destination to
    the lane's own scratch page (the standard non-flushing destination).
    """
    b = cache.k_res.shape[0]
    nb_max = cache.page_table.shape[1]
    k_res, v_res, rl, full = _append_residual(cache, k_new, v_new, mask)

    blk = jnp.clip(cache.pack_blocks, 0, nb_max - 1)
    dest = jnp.take_along_axis(cache.page_table, blk[:, None], axis=1)[:, 0]
    dest = jnp.where(full, dest, jnp.arange(b, dtype=jnp.int32))
    dest = jnp.clip(dest, 0, cache.n_pages - 1)

    if cache.shared_kv:
        pools = (cache.kw, cache.k_scale, cache.k_zero)
    else:
        pools = (cache.kw, cache.k_scale, cache.k_zero,
                 cache.vw, cache.v_scale, cache.v_zero)

    def flush(p):
        if cache.shared_kv:
            kw, ks, kz = p
            vw = vs = vz = None
        else:
            kw, ks, kz, vw, vs, vz = p
        out = rf_ops.paged_residual_flush(
            kw, ks, kz, vw, vs, vz, k_res, v_res,
            full.astype(jnp.int32), dest,
            bits=cache.bits, block_n=cache.block_n, k_gran=cache.k_gran,
            shared_kv=cache.shared_kv, impl=quant_impl,
        )
        return out[:3] if cache.shared_kv else out

    pools = lax.cond(jnp.any(full), flush, lambda p: p, pools)
    if cache.shared_kv:
        kw, ks, kz = pools
        vw = vs = vz = None
    else:
        kw, ks, kz, vw, vs, vz = pools
    return dataclasses.replace(
        cache, kw=kw, k_scale=ks, k_zero=kz, vw=vw, v_scale=vs, v_zero=vz,
        k_res=k_res, v_res=v_res,
        pack_blocks=jnp.where(full, cache.pack_blocks + 1, cache.pack_blocks),
        res_len=jnp.where(full, 0, rl),
    )


# --------------------------------------------------------------------------
# Speculative-draft residual helpers (QuantSpec-style self-speculation)
# --------------------------------------------------------------------------


def widen_residual(cache, extra: int):
    """Pad the residual token axis by ``extra`` rows (zeros).

    The speculative *draft* pass appends up to ``spec_k - 1`` tokens without
    ever flushing (the packed pools are read-only to the draft — its state is
    discarded after the verify step).  Widening the residual keeps those
    appends in-bounds when ``res_len`` starts near ``block_n``; the decode
    references read the residual capacity from ``k_res.shape[2]`` and mask by
    ``res_len``, so a wider residual changes nothing numerically.  Works on
    dense and paged caches, including layer-stacked serving state.
    """
    if extra <= 0:
        return cache

    def pad(res):
        cfg = [(0, 0)] * res.ndim
        cfg[-2] = (0, extra)
        return jnp.pad(res, cfg)

    upd = {"k_res": pad(cache.k_res)}
    if cache.v_res is not None:
        upd["v_res"] = pad(cache.v_res)
    return dataclasses.replace(cache, **upd)


def draft_append(cache, k_new, v_new):
    """Residual-only append for the speculative draft pass: write the new
    token row and bump ``res_len`` — no flush, no pool/packed-cache traffic,
    no ``pack_blocks`` change.  The caller guarantees capacity via
    :func:`widen_residual`; draft state is discarded after verification, so
    committed blocks are never touched.  Dense and paged caches alike.
    """
    k_res, v_res, rl, _ = _append_residual(cache, k_new, v_new)
    return dataclasses.replace(cache, k_res=k_res, v_res=v_res, res_len=rl)


# Pool fields of the paged cache, in dataclass order, with the rank each has
# before any model-stacking dims are prepended (the serving engine stacks a
# leading layer axis; serve/pages.py indexes pages at axis 1 accordingly).
_PAGED_POOL_FIELDS = ("kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero")
_PAGED_POOL_BASE_RANK = {
    "kw": 4, "k_scale": 3, "k_zero": 3, "vw": 4, "v_scale": 3, "v_zero": 3,
}


def _page_axis(arr, field: str) -> int:
    """Page-pool axis of a (possibly layer-stacked) pool field."""
    return arr.ndim - _PAGED_POOL_BASE_RANK[field]


def copy_pages(
    cache: PagedQuantKVCache,
    src: jax.Array,  # int32 [N]
    dst: jax.Array,  # int32 [N], pairwise distinct, disjoint from src
) -> PagedQuantKVCache:
    """Device-side pool-page copy — the copy-on-write primitive.

    Every ``dst[i]`` page becomes a bitwise replica of ``src[i]`` across all
    six pool fields (packed words + scale/zero metadata, K and V sides).
    Works on layer-stacked caches (the serving engine's state) as well as the
    base layout: the page axis is located from each field's base rank, so the
    copy moves the page across every stacked layer in one gather+scatter.

    The serving engine calls this when a decode flush is about to land in a
    page with refcount > 1 (serve/engine.py): the request gets a private
    replica and only its own page-table column is repointed, so other
    requests sharing the original page never observe the write.  The copy is
    deliberately unconditional on what the subsequent write touches — today's
    only COW site (the residual flush) overwrites the whole block, but the
    replica contract keeps COW correct for any future partial writer
    (preemption re-materialization, partial-block adoption).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    upd = {}
    for f in _PAGED_POOL_FIELDS:
        pool = getattr(cache, f)
        if pool is None:  # shared_kv latent layout has no V-side pools
            continue
        moved = jnp.moveaxis(pool, _page_axis(pool, f), 0)
        moved = moved.at[dst].set(moved[src])
        upd[f] = jnp.moveaxis(moved, 0, _page_axis(pool, f))
    return dataclasses.replace(cache, **upd)


def dequant_prior(
    cache: PagedQuantKVCache,
    pages: jax.Array,  # int32 [B, J] pool pages (rows right-padded; garbage
    #                    columns are masked by the caller via prior_len)
):
    """Gather pool pages and dequantize them into raw bf16 prior K/V for the
    shared-prefix suffix prefill.

    Returns ``(k, v)`` shaped ``[*lead, B, J*block_n, H, d]`` (lead = the
    cache's stacking dims, e.g. the layer axis) in natural token order —
    the layout :func:`repro.core.attention.prefix_suffix_attention` takes as
    ``k_prior``/``v_prior``.  Pool K is stored post-RoPE, so the dequantized
    prior needs no position re-application; the numeric contract is that
    suffix tokens see the shared prefix exactly as decode attention would
    (dequantized), which is the same approximation the paper's decode path
    already makes.

    ``shared_kv`` caches (the MLA latent pools) return ``(latent, None)``:
    there is no V-side pool, and the per-head K/V views are derived from the
    latent by the model's own up-projections
    (``repro.models.mla.mla_prefill_cache`` with ``prior=``).
    """
    pages = jnp.asarray(pages, jnp.int32)

    def gather(field: str):
        arr = getattr(cache, field)
        return jnp.moveaxis(arr, _page_axis(arr, field), 0)[pages]

    def dq(words, scale, zero, gran: str):
        # words [B, J, *lead, H, npr, d] -> [B, J, *lead, H, block_n, d];
        # one shared dequant path with the kernels' oracles, so prefix
        # sharing can never diverge numerically from decode attention
        return quantizer.unpack_and_dequantize(
            words, scale, zero, cache.bits, gran, dtype=jnp.bfloat16
        )

    k = dq(gather("kw"), gather("k_scale"), gather("k_zero"), cache.k_gran)
    v = None if cache.shared_kv else dq(
        gather("vw"), gather("v_scale"), gather("v_zero"), "tensor"
    )

    def to_prior(x):
        # [B, J, *lead, H, n, d] -> [*lead, B, J*n, H, d]
        b, j = x.shape[0], x.shape[1]
        h, n, d = x.shape[-3], x.shape[-2], x.shape[-1]
        lead = x.shape[2:-3]
        perm = (
            tuple(range(2, 2 + len(lead)))  # lead dims first
            + (0, 1, x.ndim - 2, x.ndim - 3, x.ndim - 1)  # B, J, n, H, d
        )
        x = jnp.transpose(x, perm)
        return x.reshape(*lead, b, j * n, h, d).astype(jnp.bfloat16)

    return to_prior(k), (None if v is None else to_prior(v))


def _quantize_full_region(cache, k, v, n_full: int, quant_impl: str) -> dict:
    """Quantize+pack the first ``n_full`` blocks of a prefill into updates for
    the packed fields (shared front of the uniform and ragged prefill paths)."""
    block_n = cache.block_n
    updates: dict = {}
    if not n_full:
        return updates
    w, s, z = kvq_ops.quantize_kv(
        k[:, :, : n_full * block_n], cache.bits, cache.k_gran,
        block_n=block_n, param_dtype=cache.k_scale.dtype, impl=quant_impl,
    )
    updates["kw"] = lax.dynamic_update_slice(cache.kw, w, (0, 0, 0, 0, 0))
    updates["k_scale"] = lax.dynamic_update_slice(cache.k_scale, s, (0, 0, 0, 0))
    updates["k_zero"] = lax.dynamic_update_slice(cache.k_zero, z, (0, 0, 0, 0))
    if not cache.shared_kv:
        wv, sv, zv = kvq_ops.quantize_kv(
            v[:, :, : n_full * block_n], cache.bits, "tensor",
            block_n=block_n, param_dtype=cache.k_scale.dtype, impl=quant_impl,
        )
        updates["vw"] = lax.dynamic_update_slice(cache.vw, wv, (0, 0, 0, 0, 0))
        updates["v_scale"] = lax.dynamic_update_slice(cache.v_scale, sv, (0, 0, 0, 0))
        updates["v_zero"] = lax.dynamic_update_slice(cache.v_zero, zv, (0, 0, 0, 0))
    return updates
