"""Quantization policies for the low-bit KV cache (paper §V-B, Residual Kernel).

Two scaling granularities, matching the paper:

* **channel-wise** (K default, KIVI-style): statistics are taken *along the
  token axis* of a residual block, one (scale, zero) pair per channel per
  block.  Param shape per block: ``[d]``.
* **tensor-wise** (V always; K optional "KT" mode): statistics are taken
  *along the channel axis* per token, one pair per token (per channel-group
  of size ``group``).  Param shape per block: ``[block_n, d // group]``
  (``group == d`` → per-token scalar, stored ``[block_n]``).

Asymmetric uint quantization:  q = clip(round((x - zero) / scale)),
x̂ = q * scale + zero.  Params are stored in ``param_dtype`` (default
float16 — the paper's ``half2`` (scale, zero) pairs); all arithmetic is f32.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from repro.core import layout

Granularity = Literal["channel", "tensor"]

_EPS = 1e-6


def _minmax_params(xmin, xmax, bits, param_dtype):
    scale = (xmax - xmin) / layout.qmax(bits)
    scale = jnp.maximum(scale, _EPS)
    return scale.astype(param_dtype), xmin.astype(param_dtype)


def quant_params(
    x: jnp.ndarray,
    bits: int,
    granularity: Granularity,
    *,
    group: int | None = None,
    param_dtype=jnp.float16,
):
    """Compute (scale, zero) for a block x[..., block_n, d].

    channel-wise -> scale/zero [..., d]
    tensor-wise  -> scale/zero [..., block_n] (group=None/d) or
                    [..., block_n, d//group]
    """
    x = x.astype(jnp.float32)
    if granularity == "channel":
        xmin = jnp.min(x, axis=-2)
        xmax = jnp.max(x, axis=-2)
        return _minmax_params(xmin, xmax, bits, param_dtype)
    if granularity == "tensor":
        d = x.shape[-1]
        if group is None or group == d:
            xmin = jnp.min(x, axis=-1)
            xmax = jnp.max(x, axis=-1)
            return _minmax_params(xmin, xmax, bits, param_dtype)
        if d % group:
            raise ValueError(f"d={d} not divisible by group={group}")
        xg = x.reshape(*x.shape[:-1], d // group, group)
        xmin = jnp.min(xg, axis=-1)
        xmax = jnp.max(xg, axis=-1)
        return _minmax_params(xmin, xmax, bits, param_dtype)
    raise ValueError(f"unknown granularity {granularity!r}")


def _broadcast_params(p: jnp.ndarray, x_shape, granularity, group):
    """Broadcast (scale or zero) params to the element shape x[..., n, d]."""
    *_, n, d = x_shape
    if granularity == "channel":
        return p[..., None, :]  # [..., 1, d]
    if granularity == "tensor":
        if group is None or group == d:
            return p[..., :, None]  # per-token scalar [..., n] -> [..., n, 1]
        # grouped: [..., n, d//group] -> repeat along the channel group
        return jnp.repeat(p, group, axis=-1)
    raise ValueError(granularity)


def quantize_block(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    bits: int,
    granularity: Granularity,
    *,
    group: int | None = None,
) -> jnp.ndarray:
    """x[..., block_n, d] -> uint codes int32[..., block_n, d]."""
    xf = x.astype(jnp.float32)
    s = _broadcast_params(scale.astype(jnp.float32), x.shape, granularity, group)
    z = _broadcast_params(zero.astype(jnp.float32), x.shape, granularity, group)
    q = jnp.round((xf - z) / s)
    return jnp.clip(q, 0, layout.qmax(bits)).astype(jnp.int32)


def dequantize_block(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    granularity: Granularity,
    *,
    group: int | None = None,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    s = _broadcast_params(scale.astype(jnp.float32), q.shape, granularity, group)
    z = _broadcast_params(zero.astype(jnp.float32), q.shape, granularity, group)
    return (q.astype(jnp.float32) * s + z).astype(dtype)


def quantize_and_pack(
    x: jnp.ndarray,
    bits: int,
    granularity: Granularity,
    *,
    group: int | None = None,
    param_dtype=jnp.float16,
):
    """Fused reference path: block -> (words, scale, zero).

    x: [..., block_n, d] -> words int32[..., block_n // R, d].
    """
    scale, zero = quant_params(x, bits, granularity, group=group, param_dtype=param_dtype)
    q = quantize_block(x, scale, zero, bits, granularity, group=group)
    return layout.pack_strided(q, bits), scale, zero


def unpack_and_dequantize(
    words: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    bits: int,
    granularity: Granularity,
    *,
    group: int | None = None,
    dtype=jnp.bfloat16,
):
    q = layout.unpack_strided(words, bits)
    return dequantize_block(q, scale, zero, granularity, group=group, dtype=dtype)
