"""Attention entry points: query transformation, decode dispatch, blockwise
prefill attention.

Query transformation (paper §V-A): during decode the query tensor is
``[B, 1, h_q, d]``; a naive QK^T is a GEMV that underfills the MXU.  We
reshape to ``[B, h_kv, g_q, d]`` (``g_q = h_q / h_kv``) so the grouped query
heads that share a KV head become the M dimension of a real matmul — MHA
(g_q = 1), GQA (g_q > 1) and MQA (h_kv = 1) all flow through the same kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import qcache
from repro.core.qcache import PagedQuantKVCache, QuantKVCache
from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.paged_bitdecode import ops as pg_ops

MASK_VALUE = -1e37


def query_transform(q: jax.Array, h_kv: int) -> jax.Array:
    """[B, 1, h_q, d] -> [B, h_kv, g_q, d].  Head h shares KV head h // g_q."""
    b, s1, h_q, d = q.shape
    if s1 != 1:
        raise ValueError(f"decode expects q_len=1, got {s1}")
    if h_q % h_kv:
        raise ValueError(f"h_q={h_q} not divisible by h_kv={h_kv}")
    return q.reshape(b, h_kv, h_q // h_kv, d)


def inverse_query_transform(o: jax.Array) -> jax.Array:
    """[B, h_kv, g_q, d_v] -> [B, 1, h_q, d_v]."""
    b, h_kv, g_q, d_v = o.shape
    return o.reshape(b, 1, h_kv * g_q, d_v)


# Split-KV (sequence-parallel) decode context: when set, decode_attention
# routes through dist.splitkv with the packed cache sharded along blocks.
# page_affine additionally declares the pools' leading (page) axis sharded
# along the same mesh axis (page-affine allocator — serve/pages.py), so the
# walk reads each page only on the chip that stores it.
_SPLITKV: dict = {"mesh": None, "axis": "data", "page_affine": False}


class use_splitkv:
    """Context manager enabling cross-chip split-KV decode (long-context,
    small-batch shapes).  Used by the launcher/dry-run around lowering."""

    def __init__(self, mesh, axis: str = "data", *, page_affine: bool = False):
        self.mesh, self.axis = mesh, axis
        self.page_affine = page_affine

    def __enter__(self):
        self._prev = dict(_SPLITKV)
        _SPLITKV["mesh"], _SPLITKV["axis"] = self.mesh, self.axis
        _SPLITKV["page_affine"] = self.page_affine
        return self

    def __exit__(self, *exc):
        _SPLITKV.update(self._prev)
        return False


# Speculative-decode contexts (trace-time, same pattern as _SPLITKV).  Both
# take effect inside :func:`decode_append_attention` / :func:`decode_attention`
# so the model code (models/attention.py, models/mla.py, transformer stacks)
# needs no signature changes to participate in draft/verify cycles.
_SPEC: dict = {"mask": None, "draft_bits": None}


class masked_append:
    """Freeze a subset of batch lanes during cache appends (the multi-token
    *verify* scan of self-speculative decoding).

    ``mask`` is a traced ``[B]`` bool array from the enclosing jit scope:
    lanes with ``mask=False`` keep their cache bitwise unchanged while live
    lanes append exactly as an unmasked step would (``qcache`` masks with
    ``jnp.where``, which is the identity on true lanes).  Only cache appends
    are masked — the caller masks ``pos`` and recurrent side-state itself.
    """

    def __init__(self, mask):
        self.mask = mask

    def __enter__(self):
        self._prev = _SPEC["mask"]
        _SPEC["mask"] = self.mask
        return self

    def __exit__(self, *exc):
        _SPEC["mask"] = self._prev
        return False


class use_draft:
    """Switch decode attention to the speculative *draft* read path: the
    packed cache is dequantized at ``bits`` (truncated-bit read, see
    ``kernels/bitdecode/ref._dequant_blocks``) and appends are residual-only
    (``qcache.draft_append`` — no flush, pools untouched).  Draft state is
    discarded after the verify step, so the committed cache is read-only
    here.  Forces the XLA reference kernels and bypasses split-KV routing.
    """

    def __init__(self, bits: int):
        self.bits = int(bits)

    def __enter__(self):
        self._prev = _SPEC["draft_bits"]
        _SPEC["draft_bits"] = self.bits
        return self

    def __exit__(self, *exc):
        _SPEC["draft_bits"] = self._prev
        return False


def decode_attention(
    q: jax.Array,  # [B, 1, h_q, d_k]
    cache: QuantKVCache,
    *,
    sm_scale: float | None = None,
    d_v: int | None = None,
    impl: str = "auto",
    num_splits: int | str | None = "auto",
    return_lse: bool = False,
):
    """Low-bit fused decode attention against a QuantKVCache.

    Split-KV decode is two-level:

    * **in-kernel** (``num_splits``): the packed-block walk becomes an extra
      parallel grid dimension with per-split (o, lse) partials and a fused
      logsumexp merge.  ``"auto"`` applies the heuristic in
      ``kernels/bitdecode/ops.auto_num_splits``: split only when ``B x H_kv``
      underfills the chip's parallel grid slots (the single-batch
      long-context regime — e.g. B=1, H_kv=2 at 128K) AND the sequence is
      long enough that each split owns >= 2 packed blocks; batch-heavy
      serving shapes keep ``num_splits = 1`` and pay nothing.
    * **cross-chip** (:class:`use_splitkv`): the packed cache is sharded
      along a mesh axis and per-chip partials merge with the same lse math
      (repro.dist.splitkv).  Both levels compose.

    ``cache`` may be a dense :class:`QuantKVCache` or a paged
    :class:`PagedQuantKVCache` (serving engine layout): the paged route runs
    ``kernels/paged_bitdecode`` over the cache's page table, with the same
    two split-KV levels (in-kernel ``num_splits``; cross-chip page-table-walk
    sharding via ``dist.splitkv.splitkv_paged_decode_attention``).
    """
    if isinstance(cache, PagedQuantKVCache):
        return _paged_decode_attention(
            q, cache, sm_scale=sm_scale, d_v=d_v, impl=impl,
            num_splits=num_splits, return_lse=return_lse,
        )
    draft_bits = _SPEC["draft_bits"]
    if draft_bits is None and _SPLITKV["mesh"] is not None and not return_lse:
        from repro.dist import splitkv as _sk

        return _sk.splitkv_decode_attention(
            q, cache, _SPLITKV["mesh"], axis=_SPLITKV["axis"],
            sm_scale=sm_scale, d_v=d_v, impl=impl, num_splits=num_splits,
        )
    h_kv = cache.kw.shape[1]
    qt = query_transform(q, h_kv)
    out = bd_ops.bitdecode_attention(
        qt, cache.kw, cache.k_scale, cache.k_zero,
        cache.vw, cache.v_scale, cache.v_zero,
        cache.k_res, cache.v_res, cache.pack_blocks, cache.res_len,
        bits=cache.bits, block_n=cache.block_n, sm_scale=sm_scale,
        k_gran=cache.k_gran, shared_kv=cache.shared_kv, d_v=d_v,
        impl=impl, num_splits=num_splits, return_lse=return_lse,
        draft_bits=draft_bits,
    )
    if return_lse:
        o, lse = out
        return inverse_query_transform(o), lse
    return inverse_query_transform(out)


def _paged_decode_attention(
    q: jax.Array,  # [B, 1, h_q, d_k]
    cache: PagedQuantKVCache,
    *,
    sm_scale: float | None,
    d_v: int | None,
    impl: str,
    num_splits,
    return_lse: bool,
):
    """Paged decode dispatch: page-table walk through kernels/paged_bitdecode
    (or, under :class:`use_splitkv`, the table walk sharded across chips).
    ``d_v`` is required for shared_kv (MLA latent) caches — the V width is a
    channel slice of the latent, not a stored pool dimension."""
    draft_bits = _SPEC["draft_bits"]
    if draft_bits is None and _SPLITKV["mesh"] is not None and not return_lse:
        from repro.dist import splitkv as _sk

        return _sk.splitkv_paged_decode_attention(
            q, cache, _SPLITKV["mesh"], axis=_SPLITKV["axis"],
            sm_scale=sm_scale, d_v=d_v, impl=impl, num_splits=num_splits,
            page_affine=_SPLITKV["page_affine"],
        )
    h_kv = cache.kw.shape[1]
    qt = query_transform(q, h_kv)
    out = pg_ops.paged_bitdecode_attention(
        qt, cache.kw, cache.k_scale, cache.k_zero,
        cache.vw, cache.v_scale, cache.v_zero,
        cache.k_res, cache.v_res,
        cache.page_table, cache.pack_blocks, cache.res_len,
        bits=cache.bits, block_n=cache.block_n, sm_scale=sm_scale,
        k_gran=cache.k_gran, shared_kv=cache.shared_kv, d_v=d_v,
        impl=impl, num_splits=num_splits, return_lse=return_lse,
        draft_bits=draft_bits,
    )
    if return_lse:
        o, lse = out
        return inverse_query_transform(o), lse
    return inverse_query_transform(out)


def decode_append_attention(
    q: jax.Array,  # [B, 1, h_q, d_k]
    cache: QuantKVCache | PagedQuantKVCache,
    k_new: jax.Array,  # [B, H, 1, d_k]
    v_new: jax.Array | None,  # None when shared_kv
    *,
    quant_impl: str = "auto",
    **attn_kwargs,
):
    """The per-token serving hot path in one call: append the new KV token to
    the cache (residual write + gated residual-flush kernel, see
    ``qcache.append_decode`` / ``qcache.paged_append_decode``) and run fused
    low-bit decode attention over the updated cache.  Returns
    ``(out, cache)``.

    ``quant_impl`` selects the flush implementation
    ('auto' | 'pallas' | 'xla'); ``attn_kwargs`` are forwarded to
    :func:`decode_attention` (``impl``, ``num_splits``, ``sm_scale``,
    ``d_v``, ...).  Model blocks (models/attention.py, models/mla.py) route
    through here so the engine's impl switches reach both kernels, and the
    dense/paged choice follows the cache type — the serving engine swaps the
    decode state for a paged one and the model code never changes.

    The speculative contexts hook in here: under :class:`use_draft` the
    append is residual-only (``qcache.draft_append``) and the attention read
    dequantizes at the truncated draft bit-width; under :class:`masked_append`
    frozen lanes skip the append bitwise (multi-token verify).
    """
    if _SPEC["draft_bits"] is not None:
        cache = qcache.draft_append(cache, k_new, v_new)
    elif isinstance(cache, PagedQuantKVCache):
        cache = qcache.paged_append_decode(
            cache, k_new, v_new, quant_impl=quant_impl, mask=_SPEC["mask"]
        )
    else:
        cache = qcache.append_decode(
            cache, k_new, v_new, quant_impl=quant_impl, mask=_SPEC["mask"]
        )
    return decode_attention(q, cache, **attn_kwargs), cache


def prefix_suffix_attention(
    q: jax.Array,        # [B, S, h_q, d_k]  suffix queries
    k: jax.Array,        # [B, S, h_kv, d_k] suffix keys
    v: jax.Array,        # [B, S, h_kv, d_v] suffix values
    k_prior: jax.Array,  # [B, T, h_kv, d_k] shared-prefix keys (right-padded)
    v_prior: jax.Array,  # [B, T, h_kv, d_v]
    prior_len: jax.Array,  # [B] int32 — valid prior tokens per sequence
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    """Causal attention for a prompt *suffix* against a materialized prefix.

    The shared-prefix prefill path (serve engine → ``DecoderLM.prefill`` with
    ``prior=``) computes fresh Q/K/V only for the divergent suffix tokens;
    their attention must still cover the shared leading blocks, which arrive
    here as dequantized pool pages (``qcache.dequant_prior``).  Suffix query
    row ``j`` (global position ``prior_len[b] + j``) attends prior columns
    ``< prior_len[b]`` plus suffix columns ``<= j`` — exactly the rows
    ``[prior_len, prior_len + S)`` of full causal attention over the
    concatenated sequence, so with a *raw* prior this is bitwise the tail of
    :func:`blockwise_attention` (asserted in tests/test_serve_prefix.py).

    Ragged prior: rows are right-padded to a common ``T`` and masked by
    ``prior_len`` — mixed share counts batch into one call.  Pure-jnp with an
    O(S·(T+S)) score tile; prefill-rate bound at serving bucket sizes
    (a flash_prefill suffix mode is the ROADMAP residue).
    """
    b, s, h_q, d_k = q.shape
    t = k_prior.shape[1]
    h_kv = k.shape[2]
    g = h_q // h_kv
    d_v = v.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    qg = q.reshape(b, s, h_kv, g, d_k).astype(jnp.bfloat16)
    kcat = jnp.concatenate([k_prior, k], axis=1).astype(jnp.bfloat16)
    vcat = jnp.concatenate([v_prior, v], axis=1).astype(jnp.bfloat16)
    scores = (
        jnp.einsum(
            "bshgd,bthd->bhsgt", qg, kcat,
            preferred_element_type=jnp.float32,
        )
        * sm_scale
    )  # [B, h_kv, S, g, T+S]
    cols = jnp.arange(t + s, dtype=jnp.int32)
    rows = jnp.arange(s, dtype=jnp.int32)
    in_prior = (cols[None, None, :] < prior_len[:, None, None]) & (
        cols[None, None, :] < t
    )  # [B, 1, T+S]
    in_suffix = (cols[None, :] >= t) & (cols[None, :] - t <= rows[:, None])
    valid = in_prior | in_suffix[None]  # [B, S, T+S]
    scores = jnp.where(valid[:, None, :, None, :], scores, MASK_VALUE)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum(
        "bhsgt,bthd->bshgd", p.astype(jnp.bfloat16), vcat,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, h_q, d_v)


def blockwise_attention(
    q: jax.Array,  # [B, S, h_q, d_k]
    k: jax.Array,  # [B, T, h_kv, d_k]
    v: jax.Array,  # [B, T, h_kv, d_v]
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_k: int = 512,
    q_offset: int = 0,
    impl: str = "xla",
) -> jax.Array:
    """Memory-subquadratic (flash-style) attention in pure jnp.

    Scans KV blocks with online-softmax carries; never materializes the
    [S, T] score matrix.  Used for prefill/training; GQA handled by folding
    the query-group dimension (the training-time face of the paper's query
    transformation).  q_offset shifts query positions for cross-chunk decode.

    impl="pallas" routes through the fused flash_prefill kernel (forward
    only — the VMEM-resident path that removes the materialized-score HBM
    traffic measured in EXPERIMENTS §Perf cells B/C); requires q_offset=0,
    same q/kv lengths and d_k == d_v.
    """
    if impl == "pallas":
        from repro.kernels.flash_prefill import ops as fp_ops

        assert q_offset == 0 and q.shape[1] == k.shape[1]
        out = fp_ops.flash_prefill_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            sm_scale=sm_scale, causal=causal, impl="pallas",
        )
        return out.transpose(0, 2, 1, 3)
    b, s, h_q, d_k = q.shape
    _, t, h_kv, d_v = v.shape
    g = h_q // h_kv
    if sm_scale is None:
        sm_scale = 1.0 / (d_k**0.5)
    nb = -(-t // block_k)
    t_pad = nb * block_k
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))

    qg = q.reshape(b, s, h_kv, g, d_k).astype(jnp.bfloat16)
    kb = k.reshape(b, nb, block_k, h_kv, d_k).astype(jnp.bfloat16)
    vb = v.reshape(b, nb, block_k, h_kv, d_v).astype(jnp.bfloat16)
    kb = jnp.moveaxis(kb, 1, 0)  # [nb, B, block_k, h_kv, d_k]
    vb = jnp.moveaxis(vb, 1, 0)

    rows = jnp.arange(s, dtype=jnp.int32) + q_offset  # global query positions

    def step(carry, blk):
        m, l, acc, j = carry
        kj, vj = blk
        cols = j * block_k + jnp.arange(block_k, dtype=jnp.int32)
        sblk = lax.dot_general(
            qg, kj, (((4,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32,
        ) * sm_scale  # [B, h_kv, S, g, block_k]
        valid = cols[None, :] < t
        if causal:
            valid = valid & (cols[None, :] <= rows[:, None])  # [S, block_k]
        else:
            valid = jnp.broadcast_to(valid, (s, block_k))
        sblk = jnp.where(valid[None, None, :, None, :], sblk, MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(sblk, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sblk - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # (§Perf iteration C2, REFUTED: storing p in bf16 to halve the tile
        # traffic added convert materializations and *increased* bytes 19% —
        # the f32 tile stays; see EXPERIMENTS.md)
        pv = lax.dot_general(
            p.astype(jnp.bfloat16), vj, (((4,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32,
        )  # [B, h_kv, S, g, d_v]
        acc_new = acc * alpha + pv
        return (m_new, l_new, acc_new, j + 1), None

    m0 = jnp.full((b, h_kv, s, g, 1), MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, h_kv, s, g, 1), jnp.float32)
    acc0 = jnp.zeros((b, h_kv, s, g, d_v), jnp.float32)
    (m, l, acc, _), _ = lax.scan(step, (m0, l0, acc0, jnp.int32(0)), (kb, vb))
    out = acc / l
    return out.transpose(0, 2, 1, 3, 4).reshape(b, s, h_q, d_v)
