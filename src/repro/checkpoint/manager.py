"""Fault-tolerant checkpointing with resharding restore.

Design (tensorstore-free, works multi-host):
  * every leaf is saved as per-shard ``.npy`` files keyed by the *global
    slice offsets* of each addressable shard — hosts only ever write their
    own shards;
  * a manifest JSON records tree structure, global shapes/dtypes, step and
    mesh shape;
  * commits are atomic: write into ``step_K.tmp/`` then ``rename`` —
    a crash mid-save never corrupts the latest checkpoint;
  * restore assembles each requested local shard from any overlapping saved
    shard files, so a checkpoint saved on one mesh restores onto a different
    mesh/process count (**elastic scaling across restarts**);
  * ``save_async`` runs serialization on a background thread (device->host
    copy happens synchronously, disk IO in background);
  * keep-last-k garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path)
        out.append((key.replace("/", "."), leaf))
    return out


def _slice_tag(index, shape):
    parts = []
    for sl, dim in zip(index, shape):
        start = sl.start or 0
        stop = sl.stop if sl.stop is not None else dim
        parts.append(f"{start}-{stop}")
    return "_".join(parts) if parts else "scalar"


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ save

    def _serialize(self, step_dir: Path, host_arrays, manifest):
        for key, shards in host_arrays.items():
            for tag, arr in shards:
                np.save(step_dir / f"{key}__{tag}.npy", arr)
        (step_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))

    def save(self, step: int, tree, *, block: bool = True):
        """Save a pytree of jax.Arrays (or numpy arrays)."""
        self.wait()
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest = {"step": step, "leaves": {}}
        host_arrays = {}
        for key, leaf in _leaf_paths(tree):
            if leaf is None:
                manifest["leaves"][key] = {"none": True}
                continue
            arr = leaf
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(np.dtype(jax.dtypes.canonicalize_dtype(arr.dtype))),
            }
            shards = []
            if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
                seen = set()
                for sh in arr.addressable_shards:
                    tag = _slice_tag(sh.index, arr.shape)
                    if tag in seen:  # replicated shards: write once
                        continue
                    seen.add(tag)
                    shards.append((tag, np.asarray(sh.data)))
            else:
                shards.append((_slice_tag((), ()), np.asarray(arr)))
            host_arrays[key] = shards

        def commit():
            self._serialize(tmp, host_arrays, manifest)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if block:
            commit()
        else:
            self._thread = threading.Thread(target=commit, daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree):
        self.save(step, tree, block=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------ restore

    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
        ]

    def latest_step(self):
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int | None, target, *, mesh=None, shardings=None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  With ``shardings``, each local shard is assembled
        from overlapping saved files (resharding restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step_dir = self.dir / f"step_{step}"
        manifest = json.loads((step_dir / "manifest.json").read_text())

        files: dict[str, list] = {}
        for f in step_dir.glob("*.npy"):
            key, tag = f.stem.rsplit("__", 1)
            files.setdefault(key, []).append((tag, f))

        def load_region(key, shape, dtype, index):
            """Assemble the sub-array at global slices `index` from files."""
            want = tuple(
                (sl.start or 0, sl.stop if sl.stop is not None else dim)
                for sl, dim in zip(index, shape)
            )
            out = None
            for tag, f in files[key]:
                if tag == "scalar":
                    return np.load(f)
                have = tuple(
                    tuple(map(int, part.split("-"))) for part in tag.split("_")
                )
                # overlap?
                inter = [
                    (max(w0, h0), min(w1, h1)) for (w0, w1), (h0, h1) in zip(want, have)
                ]
                if any(a >= b for a, b in inter):
                    continue
                data = np.load(f, mmap_mode="r")
                src = tuple(slice(a - h0, b - h0) for (a, b), (h0, _) in zip(inter, have))
                dst = tuple(slice(a - w0, b - w0) for (a, b), (w0, _) in zip(inter, want))
                if out is None:
                    out = np.empty([b - a for a, b in want], dtype)
                out[dst] = data[src]
            if out is None:
                raise ValueError(f"no saved shard covers {key} region {want}")
            return out

        flat_target = _leaf_paths(target)
        flat_shard = _leaf_paths(shardings) if shardings is not None else None
        restored = []
        for i, (key, leaf) in enumerate(flat_target):
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"leaf {key} missing from checkpoint")
            if meta.get("none"):
                restored.append(None)
                continue
            shape = tuple(meta["shape"])
            dtype = np.dtype(meta["dtype"])
            if flat_shard is not None and flat_shard[i][1] is not None:
                sharding = flat_shard[i][1]
                arr = jax.make_array_from_callback(
                    shape, sharding, lambda idx, k=key: load_region(k, shape, dtype, idx)
                )
            else:
                full = load_region(key, shape, dtype, tuple(slice(0, d) for d in shape))
                arr = jax.numpy.asarray(full)
            restored.append(arr)
        treedef = jax.tree_util.tree_structure(target)
        return jax.tree_util.tree_unflatten(treedef, restored), step
