"""Synthetic deterministic data pipeline.

Production-shaped: batches are generated *per device shard* (host-sharded
loading — no host ever materializes the global batch), assembled into global
jax.Arrays via ``make_array_from_callback``, and prefetched on a background
thread.  Generation is a pure function of (seed, step, shard index) so any
host/pod can reproduce its shard after elastic restart.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS


def batch_dims(cfg, shape):
    """Logical element shapes for one batch of a given ShapeSpec."""
    b, s = shape.global_batch, shape.seq_len
    dims = {}
    if cfg.encdec:
        dims["frames"] = ((b, min(cfg.enc_len, s // 2), cfg.d_model), jnp.bfloat16)
        s_dec = s // 2 if shape.kind == "train" else s
        dims["tokens"] = ((b, s_dec), jnp.int32)
        dims["labels"] = ((b, s_dec), jnp.int32)
        dims["loss_mask"] = ((b, s_dec), jnp.float32)
    elif cfg.vision_stub:
        s_text = max(8, s - cfg.n_patches)
        dims["patches"] = ((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        dims["tokens"] = ((b, s_text), jnp.int32)
        dims["labels"] = ((b, s_text), jnp.int32)
        dims["loss_mask"] = ((b, s_text), jnp.float32)
    else:
        dims["tokens"] = ((b, s), jnp.int32)
        dims["labels"] = ((b, s), jnp.int32)
        dims["loss_mask"] = ((b, s), jnp.float32)
    return dims


def batch_specs(cfg, shape, mesh=None, batch_axes=("pod", "data")):
    """ShapeDtypeStructs (optionally with shardings) for the dry-run."""
    dims = batch_dims(cfg, shape)
    out = {}
    for k, (shp, dt) in dims.items():
        if mesh is not None:
            axes = tuple(a for a in batch_axes if a in mesh.axis_names)
            sh = NamedSharding(mesh, PS(axes))
            out[k] = jax.ShapeDtypeStruct(shp, dt, sharding=sh)
        else:
            out[k] = jax.ShapeDtypeStruct(shp, dt)
    return out


def _gen_shard(name, shp, dt, seed, step, index):
    """Deterministic shard content: pure function of (seed, step, shard)."""
    key = hash((name, seed, step, str(index))) % (2**31)
    rng = np.random.default_rng(key)
    if np.issubdtype(np.dtype("int32"), np.integer) and dt == jnp.int32:
        return rng.integers(0, 1024, shp, dtype=np.int32)
    if dt == jnp.float32:
        return np.ones(shp, np.float32)
    return rng.standard_normal(shp).astype(np.float32)


def make_batch(cfg, shape, *, step=0, seed=0, mesh=None, batch_axes=("pod", "data")):
    """Build one global batch.  With a mesh, each device's shard is generated
    independently (host-sharded); without, plain host arrays."""
    dims = batch_dims(cfg, shape)
    vocab = cfg.vocab
    out = {}
    for k, (shp, dt) in dims.items():
        if mesh is None:
            arr = _gen_shard(k, shp, dt, seed, step, ())
            if k in ("tokens", "labels"):
                arr = arr % vocab
            out[k] = jnp.asarray(arr, dt)
        else:
            axes = tuple(a for a in batch_axes if a in mesh.axis_names)
            sh = NamedSharding(mesh, PS(axes))

            def cb(index, _k=k, _shp=shp, _dt=dt):
                sl = tuple(index)
                loc = tuple(
                    (s.stop or d) - (s.start or 0) for s, d in zip(sl, _shp)
                )
                arr = _gen_shard(_k, loc, _dt, seed, step, tuple((s.start, s.stop) for s in sl))
                if _k in ("tokens", "labels"):
                    arr = arr % vocab
                return np.asarray(arr, jax.dtypes.canonicalize_dtype(_dt))

            out[k] = jax.make_array_from_callback(shp, sh, cb)
    return out


class Prefetcher:
    """Background-thread prefetch of the synthetic pipeline."""

    def __init__(self, cfg, shape, *, mesh=None, seed=0, depth=2, start_step=0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = make_batch(cfg, shape, step=step, seed=seed, mesh=mesh)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
