"""SeamlessM4T-medium [arXiv:2308.11596] — 12L encoder + 12L decoder
(enc-dec; the "12L" pool entry is per-stack).  Modality frontend is a stub:
input_specs() provides precomputed frame embeddings.  Decoder self-attn uses
the online quantized cache; cross-attn uses a static quantized cache built
once after encoding."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", encdec=True,
    n_layers=24, enc_layers=12, dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206,
    rope_theta=10000.0, act="gelu", norm="ln", attn_bias=True,
    enc_len=4096,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=4, enc_layers=2, dec_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=256, vocab=512, enc_len=64,
    kv_block=64, attn_block_k=64, remat="none",
)
