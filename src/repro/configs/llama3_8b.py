"""LLaMA-3.1-8B [arXiv:2407.21783] — the paper's primary end-to-end model."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    rope_theta=500000.0, act="swiglu", norm="rms",
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, kv_block=64, attn_block_k=64, remat="none",
)
