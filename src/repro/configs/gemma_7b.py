"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim 256, (1+w) RMSNorm,
scaled tied embeddings, MHA (kv=16)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    rope_theta=10000.0, act="geglu", norm="rms",
    rms_plus_one=True, embed_scale=True, tie_embeddings=True,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, kv_block=64, attn_block_k=64, remat="none",
)
