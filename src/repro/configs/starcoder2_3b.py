"""StarCoder2-3B [arXiv:2402.19173] — GQA kv=2, LayerNorm+bias, GELU MLP."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152,
    rope_theta=1.0e6, act="gelu", norm="ln", attn_bias=True,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, kv_block=64, attn_block_k=64, remat="none",
)
