"""xLSTM-1.3B [arXiv:2405.04517] — 48 blocks, super-block = 7 mLSTM + 1 sLSTM.
Attention-free: BitDecoding inapplicable (DESIGN.md §Arch-applicability);
decode state is O(1) in sequence length."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm", mixer="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    rope=False, mlstm_per_slstm=7,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    vocab=512, mlstm_per_slstm=1, remat="none",
)
