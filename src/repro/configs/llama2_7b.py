"""LLaMA-2-7B — the paper's MHA evaluation model (Fig. 11/12)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=32000,
    rope_theta=10000.0, act="swiglu", norm="rms",
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, kv_block=64, attn_block_k=64, remat="none",
)
