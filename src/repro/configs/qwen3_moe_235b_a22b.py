"""Qwen3-MoE 235B-A22B [Qwen3 report] — 94L, GQA kv=4 (g_q=16), q/k-norm,
128 experts top-8, per-expert d_ff=1536."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936,
    rope_theta=1.0e6, act="swiglu", norm="rms", qk_norm=True,
    n_experts=128, top_k=8, d_expert=1536, router_norm_topk=True,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    vocab=512, n_experts=8, top_k=2, d_expert=64,
    kv_block=64, attn_block_k=64, remat="none",
)
