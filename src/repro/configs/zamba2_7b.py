"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone (ssm_state=64) with a
SHARED attention+MLP block invoked every 6 layers (weight sharing; one KV
cache per invocation, quantized via BitDecoding)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", mixer="mamba2",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    rope_theta=10000.0, act="swiglu", norm="rms",
    ssm_state=64, mamba_d_inner=7168, mamba_heads=112, mamba_groups=2,
    mamba_chunk=256, attn_every=6,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, ssm_state=16, mamba_d_inner=256, mamba_heads=8,
    mamba_groups=2, mamba_chunk=32, attn_every=2,
    kv_block=64, attn_block_k=64, remat="none",
)
