"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA (quantized latent cache,
shared_kv decode), 1 shared + 256 routed experts top-8 (sigmoid router),
first 3 layers dense, MTP head.  Adafactor+ZeRO-3: AdamW fp32 states for
671B params exceed 256x v5e HBM (DESIGN.md §7)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", mixer="mla",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab=129280,
    rope_theta=10000.0, act="swiglu", norm="rms",
    n_experts=256, top_k=8, d_expert=2048, n_shared_experts=1,
    first_dense_layers=3, router_score="sigmoid", router_norm_topk=True,
    q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head_dim=128,
    mtp=True,
    optimizer="adafactor", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, n_experts=8, top_k=2, d_expert=64,
    first_dense_layers=1, q_lora=64, kv_lora=128, qk_nope=32, qk_rope=32,
    v_head_dim=32, kv_block=64, attn_block_k=64, remat="none",
)
