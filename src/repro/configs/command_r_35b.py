"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — parallel-residual,
no-bias GQA, tied embeddings, 8M rope theta."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab=256000,
    rope_theta=8.0e6, act="swiglu", norm="ln",
    parallel_residual=True, tie_embeddings=True,
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, kv_block=64, attn_block_k=64, remat="none",
)
