"""Architecture configuration schema + assigned input-shape registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned shapes (identical across the 10 LM-family archs).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention details
    mixer: str = "attn"  # attn | mla | mamba2 | xlstm
    rope: bool = True
    rope_theta: float = 1.0e4
    mrope_sections: tuple | None = None
    qk_norm: bool = False
    attn_bias: bool = False
    n_heads_pad: int = 0  # pad q heads to shard on the TP axis (zero-padded
    # wo rows make the extra heads mathematically inert — Megatron practice)
    parallel_residual: bool = False
    norm: str = "rms"
    act: str = "swiglu"
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: embeddings * sqrt(d)
    rms_plus_one: bool = False  # gemma: (1 + w)
    attn_block_k: int = 512

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_score: str = "softmax"
    router_norm_topk: bool = False
    aux_loss_weight: float = 1.0e-2

    # MLA (DeepSeek)
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head_dim: int = 0
    mtp: bool = False

    # SSM / hybrid
    ssm_state: int = 0
    mamba_heads: int = 0
    mamba_d_inner: int = 0
    mamba_groups: int = 1
    mamba_chunk: int = 256
    attn_every: int = 0  # zamba2: shared attention block every k mamba layers
    mlstm_per_slstm: int = 0  # xlstm: super-block = k mLSTM + 1 sLSTM
    xlstm_time_chunk: int = 64  # sqrt-remat chunk for the recurrent time scan
    xlstm_chunkwise: bool = False  # chunkwise-parallel mLSTM (perf iteration)

    # enc-dec (seamless)
    encdec: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    enc_len: int = 4096  # stub frame-embedding length for decode shapes

    # VLM stub
    vision_stub: bool = False
    n_patches: int = 1024
    patch_grid: tuple = (32, 32)

    # BitDecoding KV cache
    kv_bits: int = 4
    kv_block: int = 128
    kv_gran: str = "channel"

    # training
    optimizer: str = "adamw"
    remat: str = "full"  # none | full
    sharding_profile: str = "fsdp_tp"  # tp | fsdp_tp
    microbatches: int = 8  # grad-accum microbatches for the train shapes

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def g_q(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the unembedding shards on a
        16-way model axis (Megatron-style padding; logits for padded ids are
        masked to -inf).  SeamlessM4T's 256206 is the motivating case."""
        return -(-self.vocab // 256) * 256


_REGISTRY = [
    "qwen3_moe_235b_a22b",
    "deepseek_v3_671b",
    "command_r_35b",
    "gemma_7b",
    "llama3_8b",
    "starcoder2_3b",
    "xlstm_1_3b",
    "seamless_m4t_medium",
    "zamba2_7b",
    "qwen2_vl_7b",
    "llama2_7b",  # the paper's own MHA eval model
]


def _mod_name(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def list_configs() -> list[str]:
    return [importlib.import_module(f"repro.configs.{n}").CONFIG.name for n in _REGISTRY]


def get_config(name: str) -> ArchConfig:
    mod_name = _mod_name(name)
    if mod_name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {_REGISTRY}")
    return importlib.import_module(f"repro.configs.{mod_name}").CONFIG


def smoke_config(name: str) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests."""
    return importlib.import_module(f"repro.configs.{_mod_name(name)}").SMOKE
