"""Qwen2-VL-7B [arXiv:2409.12191] — M-RoPE (sections 16/24/24), GQA kv=4,
QKV bias.  Vision tower is a stub: input_specs() provides precomputed patch
embeddings on a 32x32 grid."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm", vision_stub=True,
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064,
    rope_theta=1.0e6, act="swiglu", norm="rms", attn_bias=True,
    mrope_sections=(16, 24, 24), n_patches=1024, patch_grid=(32, 32),
    optimizer="adamw", sharding_profile="fsdp_tp",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, mrope_sections=(4, 6, 6), n_patches=16,
    patch_grid=(4, 4), kv_block=64, attn_block_k=64, remat="none",
)
