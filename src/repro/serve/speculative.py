"""Self-speculative decoding on the hierarchical quantized cache (QuantSpec).

The paper's cache *is* a draft/verify hierarchy: a low-bit committed cache
plus a bf16 residual window, behind one page table and one weight set.  This
module adds the two device-side passes that exploit it:

* **draft** (:func:`make_draft_fn`): decode ``spec_k - 1`` tokens greedily
  against an aggressive read path — the same packed pools dequantized at a
  truncated ``spec_bits`` bit-width (``core.attention.use_draft``), appends
  residual-only into a throwaway copy of the decode state.  No second model,
  no second page table, no pool writes.
* **verify** (:func:`make_verify_fn`): one jitted scan of full-fidelity
  decode steps over the whole ``[B, spec_k]`` feed matrix (the committed +
  residual path every non-speculative cycle uses), with per-lane alive masks
  (``core.attention.masked_append``) freezing a lane's cache, ``pos``, and
  recurrent side-state the moment its draft diverges.

Acceptance rule (host side, serve/engine.py): the engine is greedy, so a
draft token is accepted iff it *equals* the verify argmax at its position —
the longest matching prefix is accepted and the first divergence is replaced
by the verify token (which is always kept: the cycle emits >= 1 token per
live lane).  Because accepted tokens are exact matches and masked appends on
live lanes are bitwise identical to sequential appends, the emitted stream
and the cache contents equal non-speculative decode bit for bit — asserted
across cache families in tests/test_serve_spec.py.

Counters the engine maintains per cycle (see docs/SERVING.md §11):
``spec_cycles``, ``spec_draft_tokens``, ``spec_accepted_tokens``,
``spec_rejected_tokens`` — and per request ``spec_accepted`` /
``spec_rejected``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import attention as catt
from repro.core import qcache
from repro.models.family import get_path, set_path


def _mask_leaf(alive, new, old, bdim: int):
    """Select per-lane between new/old on the leaf's batch axis ``bdim``."""
    sel = alive.reshape((1,) * bdim + (-1,) + (1,) * (new.ndim - bdim - 1))
    return jnp.where(sel, new, old)


def _freeze_dead_lanes(st_new: dict, st_old: dict, alive, side_state) -> dict:
    """Return ``st_new`` with ``pos`` and every declared recurrent side-state
    path masked back to ``st_old`` on dead lanes.  Cache appends are already
    masked in-line by ``masked_append``; this covers the state the model
    updates unconditionally (position counter, SSM/xLSTM recurrent states)."""
    st_new = dict(st_new)
    st_new["pos"] = jnp.where(alive, st_new["pos"], st_old["pos"])
    for path, bdim in side_state:
        merged = jax.tree.map(
            lambda n, o: _mask_leaf(alive, n, o, bdim),
            get_path(st_new, path), get_path(st_old, path),
        )
        set_path(st_new, path, merged)
    return st_new


def make_draft_fn(model, *, spec_k: int, spec_bits: int,
                  quant_impl: str = "auto"):
    """Build the jitted draft pass.

    Returns ``draft(params, state, tok0)`` with ``tok0`` int32 ``[B]`` (the
    token each lane is about to feed this cycle) producing int32
    ``[B, spec_k - 1]`` candidate continuations.  The state is widened
    (``qcache.widen_residual``) so up to ``spec_k - 1`` residual-only appends
    stay in bounds, then discarded — the committed pools are never written.
    Lanes that aren't decoding produce garbage drafts the engine ignores.
    """
    steps = spec_k - 1
    if steps < 1:
        raise ValueError(f"spec_k={spec_k} needs no draft pass (k >= 2)")

    def draft(params, state, tok0):
        st = dict(state)
        if "caches" in st:
            st["caches"] = [qcache.widen_residual(c, steps) for c in st["caches"]]

        def body(carry, _):
            st, tok = carry
            with catt.use_draft(spec_bits):
                logits, st = model.decode_step(
                    params, st, tok[:, None], impl="auto",
                    quant_impl=quant_impl,
                )
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (st, nxt), nxt

        _, toks = lax.scan(body, (st, tok0), None, length=steps)
        return jnp.moveaxis(toks, 0, 1)  # [B, steps]

    return jax.jit(draft)


def make_verify_fn(model, spec, *, impl: str = "auto",
                   quant_impl: str = "auto"):
    """Build the jitted multi-token verify pass for one cache family.

    ``spec`` is the model's :class:`~repro.models.family.PagedSpec` (or
    ``None``) — only its ``side_state`` declaration is used, so every family
    the engine serves (attn, MLA latent, hybrid SSM, recurrent shim) verifies
    through this one function.

    Returns ``verify(params, state, feeds, limit, forced)``:

    * ``feeds`` int32 ``[B, K]`` — token to feed at each scan step (column 0
      is the cycle's committed feed; columns ``1..`` are draft candidates or,
      on replay lanes, teacher-forced history);
    * ``limit`` int32 ``[B]`` — feeds available per lane (0 = idle slot);
    * ``forced`` bool ``[B]`` — replay lanes accept unconditionally
      (preemption-by-rematerialization teacher forcing, SERVING.md §10).

    Producing ``(v, applied, finite, new_state)``: ``v[b, i]`` is the verify
    argmax after feeding ``feeds[b, i]``; ``applied[b, i]`` whether that feed
    actually ran (lane still alive); ``finite[b, i]`` whether the logits row
    was fully finite (step-level fault isolation joins the acceptance rule
    host-side).  A lane dies at step ``i + 1`` unless it is forced or
    ``v[b, i] == feeds[b, i + 1]`` — the greedy exact-match acceptance rule.
    Dead lanes touch nothing: cache appends are masked, ``pos`` and recurrent
    side-state restored, so the surviving state is bitwise the sequential one.
    """
    side = tuple(spec.side_state) if spec is not None else ()

    def verify(params, state, feeds, limit, forced):
        k = feeds.shape[1]
        feeds_t = jnp.moveaxis(feeds, 0, 1)  # [K, B]
        nxt_t = jnp.moveaxis(
            jnp.concatenate([feeds[:, 1:], feeds[:, :1]], axis=1), 0, 1
        )
        idx = jnp.arange(k, dtype=jnp.int32)
        alive0 = limit > 0

        def body(carry, xs):
            st, alive = carry
            tok, nxt, i = xs
            with catt.masked_append(alive):
                logits, st2 = model.decode_step(
                    params, st, tok[:, None], impl=impl, quant_impl=quant_impl
                )
            row = logits[:, 0].astype(jnp.float32)
            v = jnp.argmax(row, axis=-1).astype(jnp.int32)
            finite = jnp.all(jnp.isfinite(row), axis=-1)
            st2 = _freeze_dead_lanes(st2, st, alive, side)
            alive_next = alive & ((i + 1) < limit) & (forced | (v == nxt))
            return (st2, alive_next), (v, alive, finite)

        (st, _), (v, applied, finite) = lax.scan(
            body, (state, alive0), (feeds_t, nxt_t, idx)
        )
        return (
            jnp.moveaxis(v, 0, 1),        # [B, K]
            jnp.moveaxis(applied, 0, 1),  # [B, K]
            jnp.moveaxis(finite, 0, 1),   # [B, K]
            st,
        )

    return jax.jit(verify)
