"""Async overlapped serving runtime (docs/SERVING.md §13).

The synchronous :class:`~repro.serve.engine.ServeEngine` cycle stops the
world once per decoded token: dispatch the jitted step, ``block_until_ready``,
pull the logits row, argmax on host, do all the scheduling bookkeeping, then
dispatch again — the device idles through every host phase, which is exactly
the ``host_stall_fraction`` PR 8's phase breakdown measures.  This module
restructures that loop around three ideas (the MaxText/JetStream offline
inference pattern):

* **Device-resident token feed, bounded in-flight window.**  The overlapped
  decode step computes the next-token argmax (and a per-row finite flag) *on
  device* and feeds it straight back into the next dispatch — no host round
  trip on the critical path.  Dispatched steps enter a FIFO of at most
  ``window`` in-flight records; the host consumes the *oldest* record (one
  small ``np.asarray`` transfer — the only blocking sync) while up to
  ``window - 1`` younger steps are still computing.  All per-token
  bookkeeping (EOS/budget retirement, replay accounting, poisoned-step
  isolation) runs at this **consumption boundary**, through the same
  ``ServeEngine._advance_one`` body the sync cycle uses — which is why the
  token stream is bitwise identical to the sync oracle by construction.

* **Dispatch-frontier control state.**  Host decisions that must precede a
  dispatch — flush-destination allocation, COW, page-table pushes, prefill
  admission — run against a *dispatch-side* position mirror that leads
  ``req.pos`` (consumption truth) by the in-flight depth.  Retirement is
  discovered late by up to ``window`` steps: the lagging steps decode
  garbage into the request's still-private pages (never shared ones — flush
  destinations are fresh or COW'd), their results are recognized by an
  ``admit_seq`` snapshot mismatch at consumption and discarded
  (``discarded_steps``), and device-order execution guarantees a freed page
  is re-written by its next owner *after* any lagging garbage flush.
  Preemption parks the consumption-frontier feed token (``engine.tokens``),
  so rematerialization replays exactly the sync stream.

* **Background completion thread.**  Terminal requests are handed to a
  :class:`CompletionWorker` through a bounded queue; the worker detokenizes
  and runs the completion callback off the dispatch thread, recording every
  completion exactly once (the no-lost/no-double-completed ledger the
  stress suite asserts).  Every blocking queue operation carries a
  ``watchdog_s`` timeout that raises :class:`DeadlockError` instead of
  wedging — a hung thread fails fast, in tests and in CI.

Admission never syncs either: the bucketed prefill's first-token argmax
stays a device array (``defer_first=True``), scattered into the device feed
buffer and resolved on host lazily — at the slot's first consumption
boundary, or eagerly if the request is preempted before that.

The decode executable is AOT-compiled at construction against the engine's
real decode-state avals with the state and token buffers donated
(``donate_argnums``), so the steady-state loop never retraces and recycles
its buffers in place where the backend supports donation.

Caveat: with ``guard_logits=False`` a ``poison_logits`` fault cannot
reproduce the sync engine's NaN-row argmax on device (the device argmax
sees the unpoisoned row), so bitwise fault parity requires the default
``guard_logits=True`` — the poisoned request retires ERRORED before its
next token is ever used, identically in both runtimes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as catt
from repro.serve import pages as pg


class DeadlockError(RuntimeError):
    """A bounded queue operation or the liveness watchdog timed out: the
    overlapped runtime would otherwise deadlock/livelock silently."""


#: feed-plan marker: this dispatch's feed is a not-yet-resolved device-side
#: prefill first-token (see ``AsyncRunner._lazy_first``)
_LAZY = object()

#: completion-queue shutdown sentinel
_SENTINEL = object()


@dataclasses.dataclass(frozen=True)
class CompletionRecord:
    """What the background thread produces per finished request."""

    uid: int
    phase: str          # terminal Phase value ("done", "errored", ...)
    tokens: tuple       # the request's final output token ids
    text: str           # detokenizer output
    error: str | None   # req.error at retirement


class CompletionWorker:
    """Bounded-queue background detokenize/completion thread.

    The engine's single retirement path enqueues every terminal request
    (``ServeEngine._retire``); this thread detokenizes, fires the
    ``on_complete`` callback, and records the completion in a thread-safe
    ledger (``records``: uid -> :class:`CompletionRecord`).  A uid enqueued
    twice increments ``duplicates`` instead of overwriting — the stress
    suite asserts it stays 0.  Callback/detokenizer exceptions are captured
    in ``errors`` and re-raised at :meth:`drain` (the worker itself never
    dies).  ``put`` blocks at most ``watchdog_s`` on a full queue and
    ``drain`` waits at most ``watchdog_s`` for the queue to empty; both
    raise :class:`DeadlockError` on timeout."""

    def __init__(self, *, queue_size: int = 64, watchdog_s: float = 30.0,
                 detokenizer=None, on_complete=None):
        self.watchdog_s = float(watchdog_s)
        self.detokenizer = (
            detokenizer if detokenizer is not None
            else (lambda toks: " ".join(str(t) for t in toks))
        )
        self.on_complete = on_complete
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(queue_size)))
        self._lock = threading.Lock()
        self.records: dict[int, CompletionRecord] = {}
        self.duplicates = 0
        self.errors: list[Exception] = []
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, name="serve-completions", daemon=True
        )
        self._thread.start()

    @property
    def processed(self) -> int:
        """Completions recorded so far (thread-safe)."""
        with self._lock:
            return len(self.records)

    def put(self, req) -> None:
        """Enqueue a just-retired request (main thread).  The payload is
        snapshotted here — the worker never touches live Request state."""
        item = (req.uid, req.phase.value, tuple(req.out_tokens), req.error)
        try:
            self._q.put(item, timeout=self.watchdog_s)
        except queue.Full:
            raise DeadlockError(
                f"completion queue full for {self.watchdog_s:.1f}s "
                f"(maxsize {self._q.maxsize}): detokenize thread wedged"
            ) from None

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                self._q.task_done()
                return
            uid, phase, tokens, error = item
            try:
                rec = CompletionRecord(
                    uid=uid, phase=phase, tokens=tokens,
                    text=self.detokenizer(tokens), error=error,
                )
                with self._lock:
                    if uid in self.records:
                        self.duplicates += 1
                    else:
                        self.records[uid] = rec
                if self.on_complete is not None:
                    self.on_complete(rec)
            except Exception as exc:  # surfaced at drain, thread survives
                with self._lock:
                    self.errors.append(exc)
            finally:
                self._q.task_done()

    def drain(self) -> None:
        """Block until every enqueued completion was processed; re-raise the
        first captured worker exception; DeadlockError past watchdog_s."""
        deadline = time.perf_counter() + self.watchdog_s
        while self._q.unfinished_tasks:
            if time.perf_counter() > deadline:
                raise DeadlockError(
                    f"completion queue failed to drain within "
                    f"{self.watchdog_s:.1f}s "
                    f"({self._q.unfinished_tasks} item(s) outstanding)"
                )
            time.sleep(0.001)
        with self._lock:
            if self.errors:
                raise self.errors[0]

    def close(self, timeout: float | None = None) -> None:
        """Stop the worker thread (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_SENTINEL)
        self._thread.join(self.watchdog_s if timeout is None else timeout)


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unconsumed decode step."""

    cycle: int      # engine cycle that dispatched it (error attribution)
    nxt: object     # device [slots] int32: per-slot next-token argmax
    finite: object  # device [slots] bool: per-slot logits-row finiteness
    snap: list      # [(slot, req, admit_seq)] active set at dispatch
    lazy: dict      # slot -> (dev, row, admit_seq): firsts to resolve here
    t0: float       # dispatch wall time (pipeline token latency)


class AsyncRunner:
    """The overlapped decode loop behind ``ServeEngine(async_runtime=True)``.

    One :meth:`step` = consume the oldest in-flight record if the window is
    full, run the scheduling skeleton (deferred releases, expiry, faults,
    admission — prefill dispatches overlap in-flight decode), pre-allocate
    dispatch-frontier flush destinations, then dispatch one more decode step
    without waiting for any of it.  See the module docstring for the
    parity argument."""

    def __init__(self, engine, *, window: int = 2, watchdog_s: float = 30.0):
        if window < 1:
            raise ValueError(f"async window {window} must be >= 1")
        self.eng = engine
        self.window = int(window)
        self.watchdog_s = float(watchdog_s)
        self.inflight: deque[_InFlight] = deque()
        self.dispatched = 0
        self.last_progress = time.perf_counter()
        # dispatch-frontier mirrors (consumption truth lives on the Request)
        self._dispatch_pos: dict[int, int] = {}
        self._feed_plan: dict[int, deque] = {}
        # slot -> (dev_array, row|None, admit_seq): unresolved admission
        # first-tokens; resolved at first consumption or at preemption
        self._lazy_first: dict[int, tuple] = {}
        # entries not yet attached to a dispatch record (exactly one each)
        self._pending_lazy: dict[int, tuple] = {}
        # set when a consumption empties the pipeline, cleared (and observed
        # as device_starved_s) at the next dispatch; None before the first
        # dispatch — filling the pipeline at startup is prefill-bound, not
        # starvation, in both runtimes
        self._idle_since: float | None = None

        model = engine.model
        impl, quant_impl = engine._impl, engine._quant_impl

        def _astep(p, s, t):
            logits, st = model.decode_step(
                p, s, t, impl=impl, quant_impl=quant_impl
            )
            row = logits[:, 0]
            nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            finite = jnp.isfinite(row).all(axis=-1)
            return nxt, finite, nxt[:, None], st

        self._tokens_dev = jnp.zeros((engine.slots, 1), jnp.int32)
        self._astep = jax.jit(_astep, donate_argnums=(1, 2))
        # AOT compile against the engine's real decode-state avals: the
        # executable is warm before the first request arrives, and the
        # steady-state loop never retraces
        self._astep_exe = self._astep.lower(
            engine.params, engine.state, self._tokens_dev
        ).compile()
        self._astep_sk = None  # lazily built cross-chip split-KV variant
        # feed-override helpers (fixed shapes -> one compile each):
        # host-known token overrides merge by mask; admission firsts scatter
        # by row->slot index, padded rows pointing out of bounds (dropped)
        self._merge = jax.jit(
            lambda t, mask, vals: jnp.where(mask[:, None], vals[:, None], t)
        )
        self._scatter_rows = jax.jit(
            lambda t, sidx, vals: t.at[sidx, 0].set(vals)
        )
        self._scatter_one = jax.jit(
            lambda t, slot, val: t.at[slot, 0].set(val)
        )

    # ----------------------------------------------------------- liveness

    @property
    def pending(self) -> bool:
        """True while dispatched steps await consumption (drain gate)."""
        return bool(self.inflight)

    def check_liveness(self) -> None:
        """Raise :class:`DeadlockError` when the runtime has work but made
        no progress (dispatch, consumption, retirement) for watchdog_s."""
        if not self.eng._has_work():
            return
        stalled = time.perf_counter() - self.last_progress
        if stalled > self.watchdog_s:
            raise DeadlockError(
                f"async runtime made no progress for {stalled:.1f}s "
                f"(> watchdog_s={self.watchdog_s}): "
                f"{len(self.inflight)} in flight, "
                f"{len(self.eng.sched.active)} active, "
                f"{len(self.eng.sched.waiting)} waiting"
            )

    # ----------------------------------------------------- engine hooks

    def on_slot_cleared(self, slot: int) -> None:
        """Retirement hook: drop the slot's dispatch-frontier mirrors; its
        lagging in-flight steps are discarded at consumption."""
        self._dispatch_pos.pop(slot, None)
        self._feed_plan.pop(slot, None)
        self._lazy_first.pop(slot, None)
        self._pending_lazy.pop(slot, None)
        self.last_progress = time.perf_counter()

    def on_preempt(self, req) -> None:
        """Preemption hook, called before the engine reads the parked token
        from ``engine.tokens``: if the slot's admission first-token is still
        device-side (no consumption reached it yet), resolve it into the
        host mirror now — the parked token must be a concrete value."""
        slot = req.slot
        lazy = self._lazy_first.pop(slot, None)
        if lazy is not None and req.replay_left == 0:
            dev, row, seq = lazy
            if seq == req.admit_seq:
                arr = np.asarray(dev)
                self.eng.tokens[slot, 0] = (
                    int(arr[row]) if row is not None else int(arr)
                )
        self._dispatch_pos.pop(slot, None)
        self._feed_plan.pop(slot, None)
        self._pending_lazy.pop(slot, None)

    # ------------------------------------------------------- the cycle

    def step(self) -> bool:
        eng = self.eng
        t0 = time.perf_counter()
        eng._cycle += 1
        eng._cycle_worked = False
        try:
            return self._step_once(t0)
        finally:
            eng._finish_cycle(t0)

    def _step_once(self, t0: float) -> bool:
        eng = self.eng
        if len(self.inflight) >= self.window:
            self._consume_one()
        with eng._phase("schedule"):
            eng._service_deferred()
            eng._expire()
            if (eng.paged and eng.faults is not None
                    and eng.faults.fires(
                        "forced_preempt", cycle=eng._cycle)):
                victim = eng._pick_victim()
                if victim is not None:
                    eng._preempt(victim)
            if (eng.paged and eng.faults is not None
                    and eng.faults.fires(
                        "evict_storm", cycle=eng._cycle)):
                eng.pool.reclaim_retained(eng.faults.storm_pages)
        # prefill admission overlaps the in-flight decode steps: the bucketed
        # prefill is dispatched (device-ordered behind them) and its first
        # tokens stay on device (defer_first)
        if eng.paged:
            lazy = eng._admit_and_prefill(defer_first=True)
        else:
            lazy = eng._admit_exact(defer_first=True)
        self._register_admissions(lazy)
        if not eng.sched.active:
            return self._drain_progress()
        if eng.paged:
            with eng._phase("schedule"):
                eng._ensure_flush_pages(pos_of=self._frontier_pos)
                if eng.sched.active and eng._table_dirty:
                    eng.state["caches"] = pg.set_page_tables(
                        eng.state["caches"], eng._table
                    )
                    eng._table_dirty = False
            if not eng.sched.active:  # everyone self-preempted under faults
                return self._drain_progress()

        eng._cycle_worked = True
        if eng.paged:
            # occupancy at the cycle peak (post-admission, pre-release)
            eng._occupancy.append(eng.pool.occupancy)
        with eng._phase("decode_dispatch"):
            self._apply_overrides()
            if eng._use_splitkv_now():
                step_fn = self._splitkv_step()
                eng.metrics.inc("splitkv_steps")
            else:
                step_fn = self._astep_exe
            nxt, finite, toks2d, eng.state = step_fn(
                eng.params, eng.state, self._tokens_dev
            )
            self._tokens_dev = toks2d
        now = time.perf_counter()
        if self._idle_since is not None:
            # the dispatch pipeline was empty until now: starved time is the
            # overlap-aware host-stall numerator (docs/OBSERVABILITY.md)
            eng.metrics.observe(
                "device_starved_s", max(0.0, now - self._idle_since)
            )
            self._idle_since = None
        snap = [
            (slot, req, req.admit_seq)
            for slot, req in sorted(eng.sched.active.items())
        ]
        taken, self._pending_lazy = self._pending_lazy, {}
        self.inflight.append(_InFlight(
            cycle=eng._cycle, nxt=nxt, finite=finite, snap=snap,
            lazy=taken, t0=t0,
        ))
        for slot, req, _seq in snap:
            self._dispatch_pos[slot] = (
                self._dispatch_pos.get(slot, req.pos) + 1
            )
        self.dispatched += 1
        self.last_progress = now
        return True

    def _drain_progress(self) -> bool:
        """Nothing to dispatch: consume one in-flight record if any."""
        if self.inflight:
            self._consume_one()
            return True
        return False

    def _frontier_pos(self, req) -> int:
        return self._dispatch_pos.get(req.slot, req.pos)

    def _register_admissions(self, lazy: dict) -> None:
        """Set up dispatch-frontier mirrors for slots admitted this cycle:
        the dispatch position starts at the prompt length and the feed plan
        holds every host-known feed the slot consumes before switching to
        the device next-token chain — the whole teacher-forced replay stream
        plus the parked token for a rematerializing victim, the parked token
        alone for a pre-decode preemptee, the lazy device first otherwise."""
        eng = self.eng
        for slot, req in eng.sched.active.items():
            if slot in self._dispatch_pos:
                continue
            self._dispatch_pos[slot] = req.pos
            plan: deque = deque()
            if req.replay_left > 0:
                plan.extend(req.out_tokens)
                plan.append(req.pending_token)
            elif slot in lazy:
                dev, row = lazy[slot]
                entry = (dev, row, req.admit_seq)
                self._lazy_first[slot] = entry
                self._pending_lazy[slot] = entry
                plan.append(_LAZY)
            else:
                plan.append(int(eng.tokens[slot, 0]))
            self._feed_plan[slot] = plan

    def _apply_overrides(self) -> None:
        """Fold this dispatch's feed overrides into the device token buffer:
        one entry pops off each planned slot's feed queue (host-known values
        merge by mask; unresolved admission firsts scatter device-to-device,
        padded scatter rows point out of bounds and drop)."""
        eng = self.eng
        host_mask = np.zeros((eng.slots,), bool)
        host_vals = np.zeros((eng.slots,), np.int32)
        any_host = False
        groups: dict[int, tuple] = {}  # id(dev) -> (dev, [(slot, row)])
        scalars: list[tuple] = []
        for slot in list(self._feed_plan):
            if eng.sched.active.get(slot) is None:
                continue
            plan = self._feed_plan[slot]
            if not plan:
                self._feed_plan.pop(slot, None)
                continue
            val = plan.popleft()
            if not plan:
                self._feed_plan.pop(slot, None)
            if val is _LAZY:
                entry = self._lazy_first.get(slot)
                if entry is None:
                    continue
                dev, row, _seq = entry
                if row is None:
                    scalars.append((slot, dev))
                else:
                    key = id(dev)
                    groups.setdefault(key, (dev, []))[1].append((slot, row))
            else:
                host_mask[slot] = True
                host_vals[slot] = int(val)
                any_host = True
        if any_host:
            self._tokens_dev = self._merge(
                self._tokens_dev, jnp.asarray(host_mask),
                jnp.asarray(host_vals),
            )
        for dev, pairs in groups.values():
            sidx = np.full((eng.slots,), eng.slots, np.int32)  # OOB: dropped
            for slot, row in pairs:
                sidx[row] = slot
            self._tokens_dev = self._scatter_rows(
                self._tokens_dev, jnp.asarray(sidx), dev
            )
        for slot, dev in scalars:
            self._tokens_dev = self._scatter_one(
                self._tokens_dev, jnp.asarray(slot, jnp.int32), dev
            )

    def _splitkv_step(self):
        if self._astep_sk is None:
            eng = self.eng
            model, impl, quant_impl = eng.model, eng._impl, eng._quant_impl
            mesh, axis = eng.mesh, eng.splitkv_axis

            affine = getattr(eng, "page_affine", False)

            def _astep_sk(p, s, t):
                with catt.use_splitkv(mesh, axis, page_affine=affine):
                    logits, st = model.decode_step(
                        p, s, t, impl=impl, quant_impl=quant_impl
                    )
                row = logits[:, 0]
                nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
                return nxt, jnp.isfinite(row).all(axis=-1), nxt[:, None], st

            self._astep_sk = jax.jit(_astep_sk, donate_argnums=(1, 2))
        return self._astep_sk

    # -------------------------------------------------- consumption side

    def _consume_one(self) -> None:
        """Consume the oldest in-flight step: one blocking device->host
        transfer (the async runtime's only sync, attributed to
        ``device_wait``), then the sync engine's own per-slot advance body
        against the dispatch-time snapshot.  Snapshot entries whose slot was
        retired or preempted since dispatch are discarded — their results
        belong to a request that already left."""
        eng = self.eng
        rec = self.inflight.popleft()
        with eng._phase("device_wait"):
            nxt = np.asarray(rec.nxt)
            finite = np.asarray(rec.finite)
            for slot, (dev, row, seq) in rec.lazy.items():
                req = eng.sched.active.get(slot)
                if req is not None and req.admit_seq == seq:
                    arr = np.asarray(dev)
                    eng.tokens[slot, 0] = (
                        int(arr[row]) if row is not None else int(arr)
                    )
                cur = self._lazy_first.get(slot)
                if cur is not None and cur[2] == seq:
                    self._lazy_first.pop(slot, None)
        if not self.inflight:
            self._idle_since = time.perf_counter()
        now = time.perf_counter()
        dt = now - rec.t0  # pipeline latency of this token
        with eng._phase("advance"):
            for slot, req, seq in rec.snap:
                cur = eng.sched.active.get(slot)
                if cur is not req or req.admit_seq != seq:
                    eng.metrics.inc("discarded_steps")
                    continue
                poisoned = (
                    eng.faults is not None
                    and eng.faults.fires(
                        "poison_logits", cycle=rec.cycle, uid=req.uid,
                        progress=len(req.out_tokens),
                    )
                )
                bad = None
                if eng.guard_logits and (poisoned or not bool(finite[slot])):
                    bad = "non-finite logits row"
                eng._advance_one(
                    slot, req, int(nxt[slot]), bad, dt, now, cycle=rec.cycle
                )
            eng.metrics.inc("steps")
        self.last_progress = now
        if (eng.paged and eng.audit_every
                and rec.cycle % eng.audit_every == 0):
            eng.audit().raise_if_violations()
