"""Serving subsystem: continuous-batching scheduler, page-pool allocator,
the paged-first ServeEngine, and its pressure/self-checking layer (invariant
auditor, deterministic fault injection).  See docs/ARCHITECTURE.md §7 and
docs/SERVING.md §10."""
from repro.serve.audit import AuditError, AuditReport, audit_engine  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.faults import FaultPlan  # noqa: F401
from repro.serve.pages import PagePool  # noqa: F401
from repro.serve.scheduler import Phase, Request, Scheduler  # noqa: F401
