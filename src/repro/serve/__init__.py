"""Serving subsystem: continuous-batching scheduler, page-pool allocator,
the paged-first ServeEngine, its pressure/self-checking layer (invariant
auditor, deterministic fault injection), and the telemetry layer (metrics
registry, structured event tracer).  See docs/ARCHITECTURE.md §7,
docs/SERVING.md §10, and docs/OBSERVABILITY.md."""
from repro.serve.audit import AuditError, AuditReport, audit_engine  # noqa: F401
from repro.serve.async_runtime import (  # noqa: F401
    CompletionRecord,
    DeadlockError,
)
from repro.serve.engine import (  # noqa: F401
    TIMING_SUMMARY_KEYS,
    ServeEngine,
)
from repro.serve.faults import FaultPlan  # noqa: F401
from repro.serve.pages import PagePool  # noqa: F401
from repro.serve.scheduler import Phase, Request, Scheduler  # noqa: F401
from repro.serve.telemetry import (  # noqa: F401
    MetricsRegistry,
    Tracer,
    validate_events,
)
