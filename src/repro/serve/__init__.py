"""Serving subsystem: continuous-batching scheduler, page-pool allocator,
and the paged-first ServeEngine.  See docs/ARCHITECTURE.md §7."""
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.pages import PagePool  # noqa: F401
from repro.serve.scheduler import Phase, Request, Scheduler  # noqa: F401
