"""Serving telemetry: metrics registry + structured per-request event tracer.

Two cooperating pieces, both pure host-side (no device work, no effect on
any computed value — the bitwise-parity suites run with telemetry enabled):

**MetricsRegistry** — named :class:`Counter` / :class:`Gauge` /
:class:`Histogram` instruments replacing the engine's ad-hoc ``stats``
dict.  Histograms are *log-bucketed*: bucket ``i`` covers
``(lo * growth**(i-1), lo * growth**i]`` so a fixed relative error
(``growth - 1``, ~9% at the default ``growth = 2**0.125``) holds across
nine decades of latency without preallocating buckets — sub-microsecond
host hops and minute-long request lifetimes share one instrument.
Percentiles interpolate inside the resolved bucket and clamp to the
observed min/max (exact at the extremes).  The registry exports a plain
``snapshot()`` dict and a Prometheus text exposition
(:meth:`MetricsRegistry.to_prometheus`).

**Tracer** — an append-only structured event log of the serving engine's
execution:

* *request lifecycle spans*: ``queue`` (submit -> admit), ``prefill``
  (admit -> adoption), ``decode`` (adoption -> retirement), re-opened
  ``queue`` after a preemption requeue — every span carries the request
  uid;
* *engine phase spans*: one complete event per cycle phase (``schedule``,
  ``prefill``, ``decode_dispatch``, ``device_wait``, ``advance`` —
  serve/engine.py's phase-timing breakdown);
* *point events*: ``submit``, ``cow``, ``preempt``, ``replay_done``,
  ``spec_verify``, ``audit``, ``fault``, ``rejected`` and the terminal
  phase markers (``done`` / ``preempted`` / ``expired`` / ``cancelled`` /
  ``errored``).

Events export as JSONL (one event dict per line, schema documented in
docs/OBSERVABILITY.md) and as Chrome ``trace_event`` JSON
(:meth:`Tracer.chrome_trace`) that opens directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``: pid 0 is the engine
(phase track), pid 1 holds one track per request uid.

:func:`validate_events` is the schema checker the tests (and the invariant
auditor, when a tracer is attached) run over a finished trace: every span
closed, per-request span sequences alternating and time-ordered, and every
referenced request uid resolving to a submitted request.

The tracer costs one dict append per event when enabled and **nothing when
disabled**: the engine holds ``tracer = None`` and every call site is
guarded, so a production run pays only the perf_counter reads of the
always-on phase-timing breakdown.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

#: terminal request events a trace may contain without a preceding span
#: (a REJECTED submission never opens a lifecycle span)
_UNSPANNED_EVENTS = frozenset({"rejected"})


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically increasing named value (float so second-sums fit)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += n


class Gauge:
    """Last-set value plus the high/low water marks since creation."""

    __slots__ = ("name", "help", "value", "hi", "lo", "_seen")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0
        self.hi = 0.0
        self.lo = 0.0
        self._seen = False

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        if not self._seen:
            self.hi = self.lo = v
            self._seen = True
        else:
            self.hi = max(self.hi, v)
            self.lo = min(self.lo, v)


class Histogram:
    """Log-bucketed histogram with bounded relative error.

    Bucket 0 covers ``[0, lo]`` (and any non-positive sample); bucket
    ``i >= 1`` covers ``(lo * growth**(i-1), lo * growth**i]``.  Buckets are
    a sparse dict, so the instrument is O(observed decades), not O(range).
    :meth:`percentile` resolves the bucket holding the requested rank
    (numpy's ``linear`` rank convention), interpolates linearly inside it,
    and clamps to the exact observed min/max — the estimate is within one
    bucket width (relative error ``growth - 1``) of the numpy oracle,
    asserted in tests/test_serve_telemetry.py.
    """

    __slots__ = ("name", "help", "lo", "growth", "_log_g", "counts", "n",
                 "total", "vmin", "vmax")

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-7,
                 growth: float = 2 ** 0.125):
        if lo <= 0 or growth <= 1.0:
            raise ValueError(f"histogram {name}: need lo > 0, growth > 1")
        self.name = name
        self.help = help
        self.lo = lo
        self.growth = growth
        self._log_g = math.log(growth)
        self.counts: dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def bucket_edge(self, i: int) -> float:
        """Upper (inclusive) edge of bucket ``i``."""
        return self.lo * self.growth ** i

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = max(1, math.ceil(math.log(v / self.lo) / self._log_g))
        if self.bucket_edge(i) < v:  # float fuzz at an exact edge
            i += 1
        return i

    def record(self, v: float) -> None:
        v = float(v)
        i = self._bucket(v)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def percentile(self, q: float) -> float:
        """Estimate of the ``q``-th percentile (``q`` in [0, 100])."""
        if self.n == 0:
            return 0.0
        rank = (q / 100.0) * (self.n - 1)
        if rank <= 0:
            return self.vmin
        if rank >= self.n - 1:
            return self.vmax
        cum = 0
        for i in sorted(self.counts):
            c = self.counts[i]
            if cum + c > rank:
                low = 0.0 if i == 0 else self.bucket_edge(i - 1)
                high = self.bucket_edge(i)
                frac = min(max((rank - cum + 0.5) / c, 0.0), 1.0)
                val = low + frac * (high - low)
                return min(max(val, self.vmin), self.vmax)
            cum += c
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def summary(self) -> dict:
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.vmin if self.n else 0.0,
            "max": self.vmax if self.n else 0.0,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class MetricsRegistry:
    """Named instrument store with get-or-create semantics.

    One registry serves the whole engine stack (engine + scheduler + pool);
    names are flat strings (the scheduler prefixes its own with ``sched_``).
    A name registered as one instrument kind cannot be re-registered as
    another — the drift that silently zeroes a dashboard.
    """

    def __init__(self, namespace: str = "repro_serve"):
        self.namespace = namespace
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # -- get-or-create ----------------------------------------------------

    def _claim(self, name: str, kind: str) -> None:
        others = {
            "counter": (self._gauges, self._hists),
            "gauge": (self._counters, self._hists),
            "histogram": (self._counters, self._gauges),
        }[kind]
        if any(name in d for d in others):
            raise ValueError(
                f"metric {name!r} already registered as a different kind"
            )

    def counter(self, name: str, help: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._claim(name, "counter")
            c = self._counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._claim(name, "gauge")
            g = self._gauges[name] = Gauge(name, help)
        return g

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            self._claim(name, "histogram")
            h = self._hists[name] = Histogram(name, help, **kw)
        return h

    # -- convenience write paths ------------------------------------------

    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    def value(self, name: str, default: float = 0.0) -> float:
        """Current value of a counter or gauge (0/default when absent)."""
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        return default

    def hist(self, name: str) -> Histogram | None:
        return self._hists.get(name)

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict view: counters, gauges (value/hi/lo), histogram
        summaries (count/sum/min/max/mean/p50/p90/p99)."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {
                n: {"value": g.value, "hi": g.hi, "lo": g.lo}
                for n, g in self._gauges.items()
            },
            "histograms": {n: h.summary() for n, h in self._hists.items()},
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition (one fully-qualified family per
        instrument; histograms expose cumulative ``_bucket`` series plus
        ``_sum`` / ``_count``)."""
        ns = self.namespace
        lines: list[str] = []
        for n, c in sorted(self._counters.items()):
            lines.append(f"# TYPE {ns}_{n} counter")
            lines.append(f"{ns}_{n} {_fmt(c.value)}")
        for n, g in sorted(self._gauges.items()):
            lines.append(f"# TYPE {ns}_{n} gauge")
            lines.append(f"{ns}_{n} {_fmt(g.value)}")
        for n, h in sorted(self._hists.items()):
            lines.append(f"# TYPE {ns}_{n} histogram")
            cum = 0
            for i in sorted(h.counts):
                cum += h.counts[i]
                lines.append(
                    f'{ns}_{n}_bucket{{le="{h.bucket_edge(i):.6g}"}} {cum}'
                )
            lines.append(f'{ns}_{n}_bucket{{le="+Inf"}} {h.n}')
            lines.append(f"{ns}_{n}_sum {_fmt(h.total)}")
            lines.append(f"{ns}_{n}_count {h.n}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class Tracer:
    """Append-only structured event log with span tracking.

    Event record (the JSONL schema — see docs/OBSERVABILITY.md):

    ``{"ph": "B"|"E"|"i"|"X", "name": str, "cat": str, "ts_us": int,
    "dur_us": int (X only), "uid": int|None, "args": dict|None}``

    ``ph`` follows the Chrome trace_event phase letters: span begin/end,
    instant, and complete (begin + duration in one record).  ``ts_us`` is
    microseconds since tracer construction on ``clock`` (default
    ``time.perf_counter`` — always the real wall clock, independent of any
    fake engine clock injected for TTL tests).
    """

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else time.perf_counter
        self._t0 = self.clock()
        self.events: list[dict] = []
        self._open: dict[tuple, int] = {}  # (cat, name, uid) -> event index

    # -- time --------------------------------------------------------------

    def now_us(self, ts: float | None = None) -> int:
        """Microseconds since tracer start (``ts``: a raw clock reading)."""
        t = self.clock() if ts is None else ts
        return max(0, int(round((t - self._t0) * 1e6)))

    # -- spans -------------------------------------------------------------

    def begin(self, name: str, *, uid=None, cat: str = "request",
              args: dict | None = None, ts: float | None = None) -> None:
        key = (cat, name, uid)
        if key in self._open:
            raise ValueError(f"span {key} begun twice without an end")
        ev = {"ph": "B", "name": name, "cat": cat,
              "ts_us": self.now_us(ts), "uid": uid, "args": args}
        self._open[key] = len(self.events)
        self.events.append(ev)

    def end(self, name: str, *, uid=None, cat: str = "request",
            args: dict | None = None, ts: float | None = None) -> None:
        key = (cat, name, uid)
        if key not in self._open:
            raise ValueError(f"end of span {key} that was never begun")
        del self._open[key]
        self.events.append(
            {"ph": "E", "name": name, "cat": cat, "ts_us": self.now_us(ts),
             "uid": uid, "args": args}
        )

    def end_open(self, *, uid, cat: str = "request",
                 args: dict | None = None) -> list[str]:
        """End every open span of ``uid`` under ``cat`` (a retirement does
        not need to know which lifecycle span is current).  Returns the
        names ended."""
        names = [k[1] for k in self._open if k[0] == cat and k[2] == uid]
        for name in names:
            self.end(name, uid=uid, cat=cat, args=args)
        return names

    def open_spans(self) -> list[tuple]:
        """Currently open ``(cat, name, uid)`` keys (audit hook)."""
        return list(self._open)

    # -- points ------------------------------------------------------------

    def instant(self, name: str, *, uid=None, cat: str = "event",
                args: dict | None = None, ts: float | None = None) -> None:
        self.events.append(
            {"ph": "i", "name": name, "cat": cat, "ts_us": self.now_us(ts),
             "uid": uid, "args": args}
        )

    def complete(self, name: str, *, t0: float, dur_s: float,
                 cat: str = "engine", uid=None,
                 args: dict | None = None) -> None:
        """One finished span with explicit start (raw clock reading ``t0``)
        and duration — the engine's per-cycle phase records."""
        self.events.append(
            {"ph": "X", "name": name, "cat": cat, "ts_us": self.now_us(t0),
             "dur_us": max(0, int(round(dur_s * 1e6))), "uid": uid,
             "args": args}
        )

    # -- export ------------------------------------------------------------

    def write_jsonl(self, path) -> Path:
        path = Path(path)
        with path.open("w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (dict form): pid 0 = the engine
        (phase spans + engine instants), pid 1 = requests, one tid per
        request uid.  Opens directly in Perfetto / chrome://tracing."""
        out = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        for ev in self.events:
            uid = ev.get("uid")
            rec = {
                "ph": ev["ph"],
                "name": (ev["name"] if uid is None
                         else f"{ev['name']} (req {uid})"),
                "cat": ev["cat"],
                "ts": ev["ts_us"],
                "pid": 0 if uid is None else 1,
                "tid": 0 if uid is None else uid,
            }
            if ev["ph"] == "X":
                rec["dur"] = ev.get("dur_us", 0)
            if ev["ph"] == "i":
                rec["s"] = "t"  # thread-scoped instant
            if ev.get("args"):
                rec["args"] = ev["args"]
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome(self, path) -> Path:
        path = Path(path)
        path.write_text(json.dumps(self.chrome_trace()) + "\n")
        return path


def validate_events(events: list[dict]) -> list[str]:
    """Schema check over a finished trace; returns human-readable
    violations (empty == valid).

    * every ``B`` has a matching ``E`` (same cat/name/uid), none dangling,
      no double-begin, no end-without-begin;
    * per request uid, lifecycle span events alternate B/E with
      non-decreasing timestamps (a request is in at most one phase at a
      time, and its phases are time-ordered);
    * ``X`` events carry a non-negative ``dur_us``;
    * every uid referenced anywhere resolves to a request the trace saw
      submitted (a ``queue`` span begin) — except the explicitly unspanned
      terminal events (``rejected``).
    """
    out: list[str] = []
    open_spans: dict[tuple, dict] = {}
    per_uid: dict[object, list[dict]] = {}
    submitted: set = set()
    for ev in events:
        for field in ("ph", "name", "cat", "ts_us"):
            if field not in ev:
                out.append(f"event missing field {field!r}: {ev}")
                break
        else:
            ph, uid = ev["ph"], ev.get("uid")
            key = (ev["cat"], ev["name"], uid)
            if ph == "B":
                if key in open_spans:
                    out.append(f"double begin of span {key}")
                open_spans[key] = ev
                if ev["name"] == "queue" and uid is not None:
                    submitted.add(uid)
            elif ph == "E":
                start = open_spans.pop(key, None)
                if start is None:
                    out.append(f"end of never-begun span {key}")
                elif ev["ts_us"] < start["ts_us"]:
                    out.append(
                        f"span {key} ends at {ev['ts_us']}us before its "
                        f"begin at {start['ts_us']}us"
                    )
            elif ph == "X":
                if ev.get("dur_us", 0) < 0:
                    out.append(f"negative duration on {ev['name']}")
            elif ph != "i":
                out.append(f"unknown phase {ph!r} on {ev['name']}")
            if uid is not None and ph in ("B", "E"):
                per_uid.setdefault(uid, []).append(ev)
    for key in open_spans:
        out.append(f"span {key} never ended")
    for uid, evs in per_uid.items():
        last_ts = -1
        expect_begin = True
        for ev in evs:
            if (ev["ph"] == "B") != expect_begin:
                out.append(
                    f"request {uid}: lifecycle events do not alternate "
                    f"(saw {ev['ph']} {ev['name']} at {ev['ts_us']}us)"
                )
                break
            if ev["ts_us"] < last_ts:
                out.append(
                    f"request {uid}: timestamps regress at {ev['name']} "
                    f"({ev['ts_us']}us after {last_ts}us)"
                )
                break
            last_ts = ev["ts_us"]
            expect_begin = not expect_begin
    for ev in events:
        uid = ev.get("uid")
        if (uid is not None and uid not in submitted
                and ev["name"] not in _UNSPANNED_EVENTS):
            out.append(
                f"event {ev['name']} references unknown request uid {uid}"
            )
            break
    return out
