"""Paged continuous-batching serving engine — single decode path, driven by
the model's declared cache family (``model.paged_spec()``).

The engine composes the serving-layer pieces into one per-cycle loop:

* :class:`~repro.serve.scheduler.Scheduler` — request lifecycle
  (WAITING → PREFILL → DECODE → DONE), strict-FIFO admission gated on slot
  *and* page availability, length-bucketed prefill grouping;
* :class:`~repro.serve.pages.PagePool` — free-list page allocator with
  admission reservations (preempt-free steady state) and refcounts;
* the paged decode state (``model.init_paged_decode_state``): per-layer
  page pools + per-slot page tables, decoded through
  ``kernels/paged_bitdecode`` with the fused paged residual flush on the
  append path (``qcache.paged_append_decode``).

**Every cache family decodes through the page table.**  What differs per
family is declared, not forked (`repro.models.family.PagedSpec`):

* plain/GQA attention — split K/V pools, pow2-bucketed ragged prefill,
  prefix sharing + speculative-tail COW;
* MLA — a single ``shared_kv`` latent pool set per stack (V is a channel
  slice of the dequantized latent, in-kernel), same prefix sharing: the
  suffix prefill expands dequantized latent prior pages through each
  layer's up-projections;
* hybrid (Mamba2 + shared attention) — the attention caches page; the
  constant-size SSM recurrent states are ``side_state`` the engine splices
  per slot at admission and that never touch the page table.  Recurrent
  state cannot absorb right-padding, so admission groups are *exact-length*
  (``exact_prefill``) and prefix sharing stays off (``supports_prior``);
* no-KV recurrent models (xLSTM) — ``PagedSpec(paged=False)``: served by a
  thin exact-length shim (per-request prefill spliced into the batched
  dense state) that shares this engine's scheduler and decode cycle;
  ``paged_spec() is None`` (enc-dec, VLM stub) means the engine cannot feed
  the model's prefill at all and refuses at construction.

One cycle (:meth:`ServeEngine.step`):

1. admit waiting requests into free slots; paged families run **one jitted
   prefill per suffix-length bucket** (the scheduler's prefix index maps
   shared leading blocks onto resident pool pages, and suffix tokens attend
   the dequantized shared prefix via ``model.prefill(prior=...)``), adopt
   the resulting blocks into freshly allocated pages behind the shared ones
   (``adopt_prefill(base_blocks=...)``), and splice any declared dense
   side-state; the shim prefills per request at exact length;
2. (paged) allocate the destination page for any sequence whose residual
   fills on this step; a destination holding a refcount>1 page (speculative
   shared tail) is **copy-on-written** first (``qcache.copy_pages``);
3. push the page table if it changed, then run one jitted batched decode
   step over all slots — through the cross-chip split-KV path when a mesh
   is attached and the cycle is long-context/low-occupancy;
4. advance per-token accounting (one shared code path: ``req.pos``
   increments every decoded token, budget-capped retirement counts
   ``budget_retired`` exactly once), retire finished requests, record
   latency/occupancy.

Idle slots keep decoding garbage into their private scratch pages (their
page-table rows point at scratch, see serve/pages.py) — wasted lanes, never
corruption.

**Pressure handling** (docs/SERVING.md §10).  Under
``reserve_policy="expected"`` the scheduler under-reserves and a request
that outlives its expected decode length extends its reservation one page
at a time in ``_alloc_page``; when the pool cannot grant the unit the
engine **preempts** a victim (``preempt_policy``: ``"youngest"`` /
``"fewest_pages"``) — its pages are freed through the refcounted pool (so
shared prefixes survive via their other holders) and it requeues at the
FIFO head.  Re-admission re-prefills its prompt through the ordinary
suffix path, then **replays** its already-decoded tokens teacher-forced
through the decode path — the same computation that built them, so the
quantized cache (and every future token) is reconstructed bitwise; the
parked decoded-but-unfed token is restored after the replay, continuing
the *exact* token stream of a never-preempted run.

**Lifecycle guards**: per-request ``deadline_s`` TTLs retire to EXPIRED at
the top of each cycle, :meth:`ServeEngine.cancel` retires to CANCELLED, and
a poisoned step (non-finite logits row / out-of-vocab token) retires just
that request ERRORED — the engine loop and every other slot continue.

**Self-checking**: ``audit_every=N`` cross-checks pool refcounts vs page
tables vs prefix index vs per-request page lists every N cycles
(`repro.serve.audit`); ``faults=FaultPlan(...)`` injects deterministic
failures at the named sites (`repro.serve.faults`) for chaos tests.

**Telemetry** (docs/OBSERVABILITY.md, `repro.serve.telemetry`): every
lifecycle counter lives in a shared :class:`MetricsRegistry` (the ``stats``
property keeps the historical dict view), each cycle is decomposed into
timed phases — ``schedule``, ``prefill``, ``decode_dispatch``,
``device_wait`` (an explicit ``jax.block_until_ready`` boundary), and
``advance`` — feeding per-phase histograms plus the derived
``host_stall_fraction`` / ``device_idle_gap_s`` metrics, and token
latencies split into TTFT (submission → first token, queue wait included)
and TPOT (inter-token) series.  ``trace=True`` additionally records a
structured event log (request lifecycle spans, COW / preemption /
speculative / audit / fault instants, per-phase complete events) that
exports as JSONL or Chrome ``trace_event`` JSON for Perfetto.  All of it is
host-side observation only — enabling telemetry never changes a computed
token (the bitwise-parity suites run with tracing on).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as catt
from repro.core import qcache
from repro.kernels.bitdecode import ops as bd_ops
from repro.models.family import get_path, set_path
from repro.serve import pages as pg
from repro.serve.audit import audit_engine
from repro.serve.scheduler import (  # noqa: F401 (Phase/Request re-exported)
    Phase,
    Request,
    Scheduler,
    bucket_for,
)
from repro.serve.telemetry import MetricsRegistry, Tracer

#: cycle phases in execution order -> the registry histogram each feeds
#: (explicit literals so docs/OBSERVABILITY.md's metric catalog can be
#: drift-checked against the source — scripts/check_docs.py)
PHASE_METRICS = {
    "schedule": "phase_schedule_s",
    "prefill": "phase_prefill_s",
    "decode_dispatch": "phase_decode_dispatch_s",
    "device_wait": "phase_device_wait_s",
    "advance": "phase_advance_s",
}

#: timing-derived ``summary()`` keys — everything a determinism comparison
#: must strip before asserting two runs equal (tests/test_serve_pressure.py)
TIMING_SUMMARY_KEYS = frozenset({
    "wall_s", "tokens_per_s", "latency_p50_ms", "latency_p99_ms",
    "ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "tpot_p99_ms",
    "queue_wait_p50_ms", "queue_wait_p99_ms", "e2e_p50_ms", "e2e_p99_ms",
    "host_stall_fraction", "phase_s",
})

#: the engine's lifecycle counters (one registry entry each; the ``stats``
#: property and ``summary()`` expose exactly these, preserving the
#: pre-registry dict interface)
STAT_COUNTERS = (
    "decoded_tokens", "steps", "prefill_calls", "splitkv_steps",
    "prefill_tokens", "prefill_tokens_saved", "cow_copies",
    # retirement breakdown (each request counts in at most one):
    # budget_retired = hit max_new_tokens without EOS
    "budget_retired", "preempted", "preempt_remat_tokens",
    "expired", "cancelled", "errored", "audits", "faults_injected",
    # prefix-retention tier (docs/SERVING.md §14): retained pages evicted
    # back to the free list (LRU reclaim under pressure or evict_storm)
    "retained_reclaims",
    # self-speculative decoding (docs/SERVING.md §11)
    "spec_cycles", "spec_draft_tokens",
    "spec_accepted_tokens", "spec_rejected_tokens",
    # async overlapped runtime (docs/SERVING.md §13):
    # completions_enqueued = terminal retirements handed to the background
    # completion thread; discarded_steps = in-flight decode results consumed
    # after their request already left the slot (retirement/preemption lag)
    "completions_enqueued", "discarded_steps",
)


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class _PhaseTimer:
    """Accumulating timer for one named cycle phase: elapsed wall time adds
    into the engine's per-cycle accumulator (several with-blocks of the same
    phase within a cycle sum), and with tracing on, each block additionally
    emits one Chrome complete event on the engine track."""

    __slots__ = ("engine", "name", "t0")

    def __init__(self, engine, name: str):
        self.engine = engine
        self.name = name

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        acc = self.engine._phase_acc
        acc[self.name] = acc.get(self.name, 0.0) + dt
        tracer = self.engine.tracer
        if tracer is not None:
            tracer.complete(self.name, t0=self.t0, dur_s=dt, cat="engine")
        return False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 8, max_seq: int = 2048,
                 eos_id: int | None = None, impl: str = "auto",
                 quant_impl: str = "auto", paged: bool | None = None,
                 n_pages: int | None = None, min_bucket: int = 16,
                 mesh=None, splitkv_axis: str = "data",
                 splitkv: str = "auto", share_prefix: bool = True,
                 spec_tail: bool = True, retain_prefix: bool = False,
                 page_affine: bool = False,
                 reserve_policy: str = "worst_case",
                 expected_quantile: float = 0.5,
                 preempt_policy: str = "youngest", audit_every: int = 0,
                 faults=None, strict: bool = False,
                 guard_logits: bool = True, clock=None,
                 spec_k: int = 1, spec_bits: int | None = None,
                 trace: bool | Tracer = False,
                 metrics: MetricsRegistry | None = None,
                 metrics_every: int = 0, metrics_sink=None,
                 async_runtime: bool = False, async_window: int = 2,
                 completion_queue: int = 64, watchdog_s: float = 30.0,
                 detokenizer=None, on_complete=None):
        """``paged=None`` follows the model's ``paged_spec()`` (paged when it
        declares a paged family); ``paged=False`` forces the exact-length
        shim for any token-prefill model (debug/baseline path); ``paged=True``
        raises if the model declares no paged family.  ``n_pages`` bounds the
        KV pool (default: full provisioning, ``slots * nb_max`` + scratch —
        lower it to oversubscribe and exercise admission backpressure).
        ``mesh``/``splitkv_axis`` attach the cross-chip split-KV decode path;
        ``splitkv`` is the routing policy: 'auto' (engage on long-context
        low-occupancy cycles), 'always', 'never'.  ``share_prefix`` enables
        the scheduler's prompt-prefix index for families that support suffix
        prefill (``PagedSpec.supports_prior``); ``spec_tail`` additionally
        adopts a matching donor block as the speculative flush destination
        when a prompt ends mid-block — the copy-on-write candidate (see
        docs/SERVING.md).

        Prefix retention + page affinity (docs/SERVING.md §14):
        ``retain_prefix=True`` keeps prefix-registered pages in the pool's
        evictable RETAINED tier after their last holder departs, so a later
        admission over the same prompt re-adopts them at zero prefill cost;
        reclaim (LRU) happens only when the free list runs dry, *before*
        any preemption fires.  ``page_affine=True`` (requires ``mesh`` and
        a paged family) shards the page pool's free list per mesh-axis
        shard and pins every page to the shard owning its page-table
        column, matching a leading-axis device sharding of the pools
        (`repro.dist.state_specs.decode_state_specs` with
        ``page_affine=True``) — aggregate pool capacity then scales with
        the mesh instead of being replicated per chip.

        Pressure handling (docs/SERVING.md §10): ``reserve_policy`` /
        ``expected_quantile`` select the admission reservation (worst-case
        lifetime vs expected decode length — serve/scheduler.py);
        ``preempt_policy`` picks the victim when a reservation extension
        cannot be granted: ``"youngest"`` (latest admission) or
        ``"fewest_pages"`` (cheapest rematerialization).  ``audit_every=N``
        runs the invariant auditor every N cycles (0 disables);
        ``faults`` attaches a `repro.serve.faults.FaultPlan`;
        ``strict=True`` makes never-admittable submissions raise instead of
        retiring REJECTED; ``guard_logits=False`` disables the per-row
        poisoned-step isolation (benchmarking); ``clock`` (default
        ``time.monotonic``) drives ``deadline_s`` TTL enforcement.

        Self-speculative decoding (docs/SERVING.md §11): ``spec_k > 1``
        decodes up to ``spec_k`` tokens per cycle — a draft pass against the
        truncated ``spec_bits``-bit read of the *same* pools proposes
        ``spec_k - 1`` continuations, one batched full-fidelity verify scan
        accepts the longest exactly-matching prefix (greedy engine, so
        acceptance is exact token equality and the output stream is bitwise
        identical to ``spec_k = 1``).  ``spec_bits`` defaults to
        ``min(2, kv_bits)``.  Speculative cycles never route through the
        cross-chip split-KV step (the per-cycle heuristic stays off).

        Telemetry (docs/OBSERVABILITY.md): ``trace=True`` (or an existing
        `repro.serve.telemetry.Tracer`) records the structured event log —
        request lifecycle spans, COW/preempt/spec/audit/fault instants,
        per-phase complete events — exportable as JSONL or Chrome trace
        JSON; tracing off costs nothing (every call site is guarded).
        ``metrics`` shares an external
        `repro.serve.telemetry.MetricsRegistry` (default: a private one);
        ``metrics_every=N`` emits a snapshot every N cycles to
        ``metrics_sink`` (a callable receiving the snapshot dict; default
        prints the Prometheus text exposition).

        Async overlapped runtime (docs/SERVING.md §13):
        ``async_runtime=True`` replaces the stop-the-world cycle with the
        overlapped runtime (`repro.serve.async_runtime.AsyncRunner`) —
        decode steps dispatch without a per-cycle ``block_until_ready``
        (next-token argmax stays on device), the host syncs only at
        token-consumption boundaries lagging the dispatch frontier by at
        most ``async_window`` steps, prefill admission overlaps in-flight
        decode, and terminal requests flow to a background
        detokenize/completion thread through a bounded queue of
        ``completion_queue`` entries (a blocking put/drain that exceeds
        ``watchdog_s`` raises `repro.serve.async_runtime.DeadlockError`
        instead of wedging).  ``detokenizer`` (tokens -> text) and
        ``on_complete`` (called with each CompletionRecord) run on that
        thread.  Output token streams are bitwise identical to
        ``async_runtime=False`` — the sync cycle stays available as the
        oracle (tests/test_serve_async.py).  With ``spec_k > 1`` the
        speculative cycle itself runs unoverlapped (it already amortizes
        host syncs — two per up-to-``spec_k`` tokens) but completions
        still route through the background thread."""
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.mesh = mesh
        self.splitkv_axis = splitkv_axis
        self.splitkv = splitkv
        if preempt_policy not in ("youngest", "fewest_pages"):
            raise ValueError(f"unknown preempt_policy {preempt_policy!r}")
        self.preempt_policy = preempt_policy
        self.audit_every = audit_every
        self.faults = faults
        self.guard_logits = guard_logits
        self.clock = clock if clock is not None else time.monotonic
        self._cycle = 0

        # --- telemetry (docs/OBSERVABILITY.md) ---------------------------
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = (
            trace if isinstance(trace, Tracer)
            else (Tracer() if trace else None)
        )
        self.metrics_every = int(metrics_every)
        self.metrics_sink = metrics_sink
        for name in STAT_COUNTERS:
            self.metrics.counter(name)
        for hist in PHASE_METRICS.values():
            self.metrics.histogram(hist)
        self.metrics.histogram("cycle_s")
        self.metrics.histogram("device_idle_gap_s")
        # async runtime: wall time the dispatch pipeline sat empty while
        # work remained (the overlap-aware host-stall numerator, §13)
        self.metrics.histogram("device_starved_s")
        self.metrics.histogram("ttft_s")
        self.metrics.histogram("tpot_s")
        self.metrics.histogram("queue_wait_s")
        self.metrics.histogram("e2e_latency_s")
        self._phase_acc: dict[str, float] = {}
        self._cycle_worked = False
        # explicit first-work -> last-work window: the honest wall_s
        # fallback for callers driving step() themselves
        self._work_t0: float | None = None
        self._work_t1: float | None = None
        self._ttft_s: list[float] = []
        self._tpot_s: list[float] = []
        self._queue_wait_s: list[float] = []
        self._e2e_s: list[float] = []
        if faults is not None and getattr(faults, "on_fire", None) is None:
            faults.on_fire = self._on_fault
        # delayed-release fault parking lot: (ready_cycle, uid, pages)
        self._deferred: list[tuple[int, int, list[int]]] = []
        cfg = getattr(model, "cfg", None)

        spec = model.paged_spec() if hasattr(model, "paged_spec") else None
        if spec is None:
            raise ValueError(
                "model declares no serveable cache family (paged_spec() is "
                "None): its prefill needs inputs beyond tokens"
            )
        if paged and not spec.paged:
            raise ValueError(
                "model declares no paged decode capability "
                "(see repro.models.family.PagedSpec)"
            )
        self.spec = spec
        self.paged = (spec is not None and spec.paged) if paged is None else bool(paged)
        self.block_n = spec.block_n if spec is not None else getattr(cfg, "kv_block", 128)
        self._h_kv = spec.n_kv_heads if spec is not None else getattr(cfg, "n_kv_heads", 1)

        # self-speculative decoding (draft against the truncated-bit read of
        # the same pools; one batched verify scan; docs/SERVING.md §11)
        self.spec_k = int(spec_k)
        if self.spec_k < 1:
            raise ValueError(f"spec_k={spec_k} must be >= 1")
        kv_bits = getattr(cfg, "kv_bits", 4)
        self.spec_bits = int(spec_bits) if spec_bits is not None else min(2, kv_bits)
        if not 1 <= self.spec_bits <= kv_bits:
            raise ValueError(
                f"spec_bits={self.spec_bits} outside [1, kv_bits={kv_bits}]"
            )
        self._draft = self._verify = None
        if self.spec_k > 1:
            from repro.serve import speculative as _spec_mod

            self._draft = _spec_mod.make_draft_fn(
                model, spec_k=self.spec_k, spec_bits=self.spec_bits,
                quant_impl=quant_impl,
            )
            self._verify = _spec_mod.make_verify_fn(
                model, spec, impl=impl, quant_impl=quant_impl
            )

        self._impl = impl
        self._quant_impl = quant_impl
        # one jitted decode step (static shapes) shared by every family, and
        # the host-side next-token buffer (one device->host pull per cycle)
        self._step = jax.jit(
            lambda p, s, t: model.decode_step(
                p, s, t, impl=impl, quant_impl=quant_impl
            )
        )
        self._step_splitkv = None
        if mesh is not None and splitkv_axis not in getattr(mesh, "axis_names", ()):
            raise ValueError(
                f"mesh has no axis {splitkv_axis!r}; available: "
                f"{tuple(getattr(mesh, 'axis_names', ()))}"
            )
        if mesh is not None and splitkv != "never":
            _affine = bool(page_affine)

            def _split_step(p, s, t):
                with catt.use_splitkv(mesh, splitkv_axis,
                                      page_affine=_affine):
                    return model.decode_step(
                        p, s, t, impl=impl, quant_impl=quant_impl
                    )
            self._step_splitkv = jax.jit(_split_step)

        self.tokens = np.zeros((slots, 1), np.int32)
        self._occupancy: list[float] = []

        self.page_affine = bool(page_affine)
        if self.page_affine and mesh is None:
            raise ValueError("page_affine=True requires a mesh")
        if self.page_affine and not self.paged:
            raise ValueError("page_affine=True requires a paged family")
        if self.page_affine and splitkv == "never":
            raise ValueError(
                "page_affine=True needs the sharded split-KV walk "
                "(splitkv='auto' or 'always')"
            )
        if self.paged:
            nb_max = -(-max_seq // self.block_n)
            if mesh is not None:
                n = int(mesh.shape[splitkv_axis])  # pad-free sharded table walk
                nb_max = -(-nb_max // n) * n
            self.nb_max = nb_max
            shards = int(mesh.shape[splitkv_axis]) if self.page_affine else 1
            self._pool_shards = shards
            self._nb_local = nb_max // shards
            if n_pages is not None:
                self.n_pages = n_pages
            else:
                # full provisioning; page-affine adds one slot-page per
                # shard so shard 0's scratch range doesn't eat into its
                # allocatable share (n_pages stays a multiple of shards)
                self.n_pages = slots * nb_max + slots * shards
            self.state = model.init_paged_decode_state(
                slots, n_pages=self.n_pages, nb_max=nb_max
            )
            # the allocated pools must match the declared family — catches a
            # model whose spec and init_paged_decode_state drift apart
            first = self.state["caches"][0]
            if (first.shared_kv != spec.shared_kv
                    or first.kw.shape[-1] != spec.d_k
                    or (not spec.shared_kv
                        and first.vw.shape[-1] != spec.d_v)):
                raise ValueError(
                    "paged_spec() disagrees with init_paged_decode_state: "
                    f"declared (shared_kv={spec.shared_kv}, d_k={spec.d_k}, "
                    f"d_v={spec.d_v}) vs allocated (shared_kv="
                    f"{first.shared_kv}, d_k={first.kw.shape[-1]})"
                )
            # per-family page size in bytes: one table column spans every
            # paged layer-cache (spec.page_layers of them), measured exactly
            # from the allocated pools
            self.kv_page_bytes = sum(
                getattr(pc, f).nbytes
                for pc in self.state["caches"]
                for f in qcache._PAGED_POOL_FIELDS
                if getattr(pc, f) is not None
            ) // self.n_pages
            if self.page_affine:
                # place the pools page-sharded at rest: each chip holds
                # n_pages/shards pages (plus its table-column slice), so
                # per-chip pool bytes stay constant as the mesh grows
                from jax.sharding import NamedSharding
                from repro.dist.state_specs import decode_state_specs
                if self.n_pages % shards:
                    raise ValueError(
                        f"page_affine needs n_pages ({self.n_pages}) "
                        f"divisible by the {splitkv_axis!r} axis size "
                        f"({shards})"
                    )
                specs = decode_state_specs(
                    model, mesh, global_batch=slots, seq_ax=splitkv_axis,
                    paged=True, n_pages=self.n_pages, nb_max=nb_max,
                    page_affine=True,
                )
                self.state = jax.device_put(
                    self.state,
                    jax.tree.map(
                        lambda s: None if s is None else NamedSharding(mesh, s),
                        specs, is_leaf=lambda x: x is None,
                    ),
                )
            self.pool = pg.PagePool(
                self.n_pages, n_scratch=slots, page_bytes=self.kv_page_bytes,
                metrics=self.metrics, shards=self._pool_shards,
            )
            share = share_prefix and spec.supports_prior
            self.retain_prefix = retain_prefix and share
            self.sched = Scheduler(
                slots=slots, pool=self.pool, block_n=self.block_n,
                max_seq=max_seq, min_bucket=min_bucket,
                share_prefix=share, spec_tail=spec_tail and share,
                retain_prefix=self.retain_prefix,
                exact_buckets=spec.exact_prefill,
                reserve_policy=reserve_policy,
                expected_quantile=expected_quantile,
                strict=strict, clock=self.clock, metrics=self.metrics,
                namespace=(
                    f"{getattr(cfg, 'name', 'model')}/b{getattr(cfg, 'kv_bits', 4)}"
                    f"/n{self.block_n}/{getattr(cfg, 'kv_gran', 'channel')}"
                ),
            )
            # host mirror of the device page table; unassigned entries point
            # at the slot's scratch page (flush-destination injectivity)
            self._table = np.broadcast_to(
                np.arange(slots, dtype=np.int32)[:, None], (slots, nb_max)
            ).copy()
            self._table_dirty = False
            # one jitted bucketed prefill; jit cache keys on the padded
            # token shape = (slots, bucket_len) -> one compile per bucket
            # (per exact length for exact_prefill families)
            if spec.exact_prefill:
                self._prefill = jax.jit(
                    lambda p, toks: model.prefill(p, {"tokens": toks},
                                                  toks.shape[1])
                )
            else:
                self._prefill = jax.jit(
                    lambda p, toks, lengths: model.prefill(
                        p, {"tokens": toks}, toks.shape[1], lengths=lengths
                    )
                )
            # shared-prefix suffix prefill: dequantizes the prior pages from
            # the pools and attends them from the divergent suffix; the jit
            # cache keys on (bucket_len, padded prior blocks) — prior width
            # is bucketed to powers of two to bound compile count
            def _suffix_prefill(p, caches, toks, lengths, pages, prior_len):
                prior = [qcache.dequant_prior(c, pages) for c in caches]
                return model.prefill(
                    p, {"tokens": toks}, toks.shape[1],
                    lengths=lengths, prior=prior, prior_len=prior_len,
                )

            self._prefill_shared = jax.jit(_suffix_prefill)
        else:
            # exact-length shim: dense state, per-request prefill, no pool
            self.pool = None
            self.retain_prefix = False
            self._pool_shards = 1
            self.sched = Scheduler(
                slots=slots, pool=None, block_n=self.block_n, max_seq=max_seq,
                share_prefix=False, spec_tail=False, exact_buckets=True,
                strict=strict, clock=self.clock, metrics=self.metrics,
            )
            self.state = model.init_decode_state(slots, max_seq)
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, self.max_seq)
            )

        # --- async overlapped runtime (docs/SERVING.md §13) ---------------
        self.async_runtime = bool(async_runtime)
        self._runner = None
        self._completions = None
        if self.async_runtime:
            from repro.serve.async_runtime import AsyncRunner, CompletionWorker

            self._completions = CompletionWorker(
                queue_size=completion_queue, watchdog_s=watchdog_s,
                detokenizer=detokenizer, on_complete=on_complete,
            )
            if self.spec_k == 1:
                self._runner = AsyncRunner(
                    self, window=async_window, watchdog_s=watchdog_s
                )

    # ------------------------------------------------------------ public

    @property
    def stats(self) -> dict:
        """Lifecycle counters as a plain dict (the pre-telemetry ``stats``
        interface, now a read-only view of the metrics registry)."""
        return {k: int(self.metrics.value(k)) for k in STAT_COUNTERS}

    def _phase(self, name: str) -> _PhaseTimer:
        """Timer for one cycle phase (``with self._phase("schedule"): ...``)."""
        return _PhaseTimer(self, name)

    def _on_fault(self, site: str, cycle: int, uid) -> None:
        """``FaultPlan.on_fire`` hook: count and trace every injected fault."""
        self.metrics.inc("faults_injected")
        if self.tracer is not None:
            self.tracer.instant(
                "fault", args={"site": site, "cycle": cycle, "uid": uid}
            )

    def submit(self, req: Request) -> bool:
        """Queue ``req``; False when it was retired REJECTED at submission
        (``req.error`` names the reason; raises instead under ``strict``)."""
        ok = self.sched.submit(req)
        if self.tracer is not None:
            if ok:
                self.tracer.begin("queue", uid=req.uid, cat="request")
            else:
                self.tracer.instant("rejected", uid=req.uid, cat="request")
        return ok

    def cancel(self, uid: int) -> Request | None:
        """Cancel a waiting or active request by uid; returns the retired
        request (phase CANCELLED, resources released, page-table row reset)
        or None when no live request has that uid."""
        for req in list(self.sched.waiting):
            if req.uid == uid:
                self.sched.waiting.remove(req)
                self._retire(req, Phase.CANCELLED, reason="cancelled")
                return req
        for req in list(self.sched.active.values()):
            if req.uid == uid:
                self._retire(req, Phase.CANCELLED, reason="cancelled")
                return req
        return None

    def audit(self):
        """Run the invariant auditor now (`repro.serve.audit.audit_engine`)."""
        self.metrics.inc("audits")
        report = audit_engine(self)
        if self.tracer is not None:
            self.tracer.instant(
                "audit", args={"violations": len(report.violations)}
            )
        return report

    def run(self, max_cycles: int = 10_000):
        t0 = time.perf_counter()
        cycles = 0
        while self._has_work() and cycles < max_cycles:
            self.step()
            cycles += 1
            if self._runner is not None:
                self._runner.check_liveness()
        if self._completions is not None:
            # every enqueued completion processed before the drain audit
            self._completions.drain()
        if self.paged and self.audit_every:
            self.audit().raise_if_violations()  # clean at drain
        return self.summary(wall_s=time.perf_counter() - t0)

    def close(self) -> None:
        """Stop the background completion thread (async runtime); idempotent
        and a no-op for the synchronous engine."""
        if self._completions is not None:
            self._completions.close()

    def summary(self, *, wall_s: float | None = None) -> dict:
        """Engine statistics; callers driving :meth:`step` themselves (the
        offered-load bench) pass their own wall-clock window.  Every
        timing-derived key is listed in `TIMING_SUMMARY_KEYS` so determinism
        comparisons know exactly what to strip."""
        if wall_s is None:
            # explicit first-work -> last-work window (never fabricated from
            # latency sums): an engine that did no decode work reports 0
            if self._work_t0 is not None and self._work_t1 is not None:
                wall_s = self._work_t1 - self._work_t0
            else:
                wall_s = 0.0
        stats = self.stats
        cycle_total = self.metrics.histogram("cycle_s").total
        wait_total = self.metrics.histogram("phase_device_wait_s").total
        # legacy latency_* keys alias TPOT (steady-state inter-token
        # latency); they fall back to TTFT when every request emitted a
        # single token and no inter-token gap was ever observed
        lat = self._tpot_s if self._tpot_s else self._ttft_s
        out = {
            **stats,
            "wall_s": wall_s,
            "tokens_per_s": (
                stats["decoded_tokens"] / wall_s if wall_s > 0 else 0.0
            ),
            **{f"sched_{k}": v for k, v in self.sched.stats.items()},
            "latency_p50_ms": 1e3 * _percentile(lat, 50),
            "latency_p99_ms": 1e3 * _percentile(lat, 99),
            "ttft_p50_ms": 1e3 * _percentile(self._ttft_s, 50),
            "ttft_p99_ms": 1e3 * _percentile(self._ttft_s, 99),
            "tpot_p50_ms": 1e3 * _percentile(self._tpot_s, 50),
            "tpot_p99_ms": 1e3 * _percentile(self._tpot_s, 99),
            "queue_wait_p50_ms": 1e3 * _percentile(self._queue_wait_s, 50),
            "queue_wait_p99_ms": 1e3 * _percentile(self._queue_wait_s, 99),
            "e2e_p50_ms": 1e3 * _percentile(self._e2e_s, 50),
            "e2e_p99_ms": 1e3 * _percentile(self._e2e_s, 99),
            # fraction of cycle time the host was NOT waiting on the device
            # — the async-runtime ROADMAP item exists to shrink this.  The
            # overlapped runtime measures it directly as dispatch-pipeline
            # starvation (below); the sync cycle infers it from device_wait
            # (host working == device idle holds only without overlap)
            "host_stall_fraction": (
                1.0 - min(1.0, wait_total / cycle_total)
                if cycle_total > 0 else 0.0
            ),
            "phase_s": {
                **{
                    name: self.metrics.histogram(h).total
                    for name, h in PHASE_METRICS.items()
                },
                "cycle": cycle_total,
            },
        }
        if self._runner is not None and self._runner.dispatched > 0:
            # overlap-aware attribution: time the dispatch pipeline sat
            # empty (in-flight window drained while work remained), not
            # time-not-in-device_wait — under overlap the host working no
            # longer implies the device is idle (docs/OBSERVABILITY.md)
            starved = self.metrics.histogram("device_starved_s").total
            out["host_stall_fraction"] = (
                min(1.0, starved / cycle_total) if cycle_total > 0 else 0.0
            )
        if self.spec_k > 1:
            out["spec_accept_rate"] = (
                stats["spec_accepted_tokens"]
                / max(1, stats["spec_draft_tokens"])
            )
        if self.paged:
            out.update(
                occupancy_mean=float(np.mean(self._occupancy)) if self._occupancy else 0.0,
                occupancy_max=float(np.max(self._occupancy)) if self._occupancy else 0.0,
                # per-family page accounting (repro.models.family.PagedSpec):
                # one table column spans spec.page_layers layer-caches
                kv_page_bytes=self.kv_page_bytes,
                kv_bytes_in_use=self.pool.bytes_in_use,
                kv_page_layers=self.spec.page_layers,
                pages_per_token=self.spec.pages_per_token,
                # fraction of admitted full prompt blocks served from
                # resident pages instead of prefill compute
                prefix_hit_rate=(
                    self.sched.stats["prefix_hit_blocks"]
                    / max(1, self.sched.stats["prefix_lookup_blocks"])
                ),
                # prefix-retention tier (docs/SERVING.md §14)
                pool_pages_retained=self.pool.n_retained,
                pool_shards=self._pool_shards,
            )
        return out

    def _has_work(self) -> bool:
        return (self.sched.has_work or bool(self._deferred)
                or (self._runner is not None and self._runner.pending))

    # ------------------------------------------------ the one decode cycle

    def step(self) -> bool:
        if self._runner is not None:
            return self._runner.step()
        if self.spec_k > 1:
            return self._step_spec()
        t0 = time.perf_counter()
        self._cycle += 1
        self._cycle_worked = False
        try:
            return self._step_once(t0)
        finally:
            self._finish_cycle(t0)

    def _step_once(self, t0: float) -> bool:
        with self._phase("schedule"):
            self._service_deferred()
            self._expire()
            if (self.paged and self.faults is not None
                    and self.faults.fires(
                        "forced_preempt", cycle=self._cycle)):
                victim = self._pick_victim()
                if victim is not None:
                    self._preempt(victim)
            if (self.paged and self.faults is not None
                    and self.faults.fires(
                        "evict_storm", cycle=self._cycle)):
                self.pool.reclaim_retained(self.faults.storm_pages)
        if self.paged:
            self._admit_and_prefill()
        else:
            self._admit_exact()
        if not self.sched.active:
            return False
        if self.paged:
            with self._phase("schedule"):
                self._ensure_flush_pages()
                if self.sched.active and self._table_dirty:
                    self.state["caches"] = pg.set_page_tables(
                        self.state["caches"], self._table
                    )
                    self._table_dirty = False
            if not self.sched.active:  # everyone self-preempted under faults
                return False

        if self._use_splitkv_now():
            step_fn = self._step_splitkv
            self.metrics.inc("splitkv_steps")
        else:
            step_fn = self._step
        self._cycle_worked = True
        with self._phase("decode_dispatch"):
            logits, self.state = step_fn(
                self.params, self.state, jnp.asarray(self.tokens)
            )
        # one host sync per cycle: the explicit block_until_ready boundary
        # separates waiting on device compute from the host work around it
        # (the phase breakdown is how host-stall fraction gets measured)
        with self._phase("device_wait"):
            logits = jax.block_until_ready(logits)
            rows = np.array(np.asarray(logits)[:, 0])
        with self._phase("advance"):
            if self.faults is not None:
                for slot, req in list(self.sched.active.items()):
                    if self.faults.fires(
                        "poison_logits", cycle=self._cycle, uid=req.uid,
                        progress=len(req.out_tokens),
                    ):
                        rows[slot] = np.nan
            nxt = np.argmax(rows, axis=-1)
            bad: dict[int, str] = {}
            if self.guard_logits:
                finite = np.isfinite(rows).all(axis=-1)
                for slot in self.sched.active:
                    if not finite[slot]:
                        bad[slot] = "non-finite logits row"
                    elif not 0 <= int(nxt[slot]) < rows.shape[-1]:
                        bad[slot] = f"invalid next token id {int(nxt[slot])}"
            self.metrics.inc("steps")
            if self.paged:
                # occupancy at the cycle peak — post-admission, pre-release:
                # sampling after _advance would miss every request that
                # retires the same cycle it decoded (short workloads read 0)
                self._occupancy.append(self.pool.occupancy)
            self._advance(nxt, time.perf_counter() - t0, bad=bad)
        if (self.paged and self.audit_every
                and self._cycle % self.audit_every == 0):
            self.audit().raise_if_violations()
        return True

    def _finish_cycle(self, t0: float) -> None:
        """Cycle-boundary bookkeeping, run on every exit path of
        :meth:`step` / :meth:`_step_spec`: fold the per-phase accumulator
        into the registry histograms, derive the device-idle gap, advance
        the first-work -> last-work window behind the ``wall_s`` fallback,
        and service the periodic metrics sink."""
        now = time.perf_counter()
        cycle_s = now - t0
        acc, self._phase_acc = self._phase_acc, {}
        m = self.metrics
        m.observe("cycle_s", cycle_s)
        for name, hist in PHASE_METRICS.items():
            if name in acc:
                m.observe(hist, acc[name])
        # the device is busy (at most) while the host waits on it or runs a
        # prefill; the rest of the cycle is host-side gap the async runtime
        # (ROADMAP) exists to overlap away
        busy = acc.get("device_wait", 0.0) + acc.get("prefill", 0.0)
        m.observe("device_idle_gap_s", max(0.0, cycle_s - busy))
        if self._cycle_worked:
            if self._work_t0 is None:
                self._work_t0 = t0
            self._work_t1 = now
        if self.tracer is not None:
            self.tracer.complete("cycle", t0=t0, dur_s=cycle_s, cat="engine",
                                 args={"cycle": self._cycle})
        if self.metrics_every and self._cycle % self.metrics_every == 0:
            if self.metrics_sink is not None:
                self.metrics_sink(m.snapshot())
            else:
                print(m.to_prometheus(), end="")

    # ------------------------------------------- the speculative decode cycle

    def _step_spec(self) -> bool:
        """One self-speculative cycle (``spec_k > 1``, docs/SERVING.md §11):
        the same lifecycle skeleton as :meth:`step` (deferred releases,
        expiry, forced-preempt fault, admission), then

        1. build the ``[slots, spec_k]`` feed matrix: column 0 is each lane's
           committed next token; replay lanes (teacher forcing) take their
           recorded history, normal lanes leave room for draft candidates;
        2. pre-allocate every flush destination the cycle can reach
           (``_ensure_flush_pages`` with per-lane lookahead — COW and
           preemption semantics unchanged, just applied over a window);
        3. draft pass (one device call): ``spec_k - 1`` greedy steps against
           the truncated ``spec_bits`` read of the same pools, state
           discarded;
        4. verify pass (one device call): a full-fidelity masked scan over
           all feeds — a lane freezes the moment its draft diverges from the
           verify argmax;
        5. host accounting (:meth:`_advance_spec`): accept the longest
           matching prefix, fall back to the verify token at the first
           divergence, preserve the sequential EOS / budget / poisoned-step
           retirement semantics token by token.

        Two host syncs per cycle regardless of ``spec_k`` — the latency win
        on the memory-bound decode this paper targets."""
        t0 = time.perf_counter()
        self._cycle += 1
        self._cycle_worked = False
        try:
            return self._step_spec_once(t0)
        finally:
            self._finish_cycle(t0)

    def _step_spec_once(self, t0: float) -> bool:
        with self._phase("schedule"):
            self._service_deferred()
            self._expire()
            if (self.paged and self.faults is not None
                    and self.faults.fires(
                        "forced_preempt", cycle=self._cycle)):
                victim = self._pick_victim()
                if victim is not None:
                    self._preempt(victim)
            if (self.paged and self.faults is not None
                    and self.faults.fires(
                        "evict_storm", cycle=self._cycle)):
                self.pool.reclaim_retained(self.faults.storm_pages)
        if self.paged:
            self._admit_and_prefill()
        else:
            self._admit_exact()
        if not self.sched.active:
            return False

        k = self.spec_k
        feeds = np.zeros((self.slots, k), np.int32)
        limit = np.zeros((self.slots,), np.int32)
        forced = np.zeros((self.slots,), bool)
        with self._phase("schedule"):
            lookahead: dict[int, int] = {}
            for slot, req in self.sched.active.items():
                feeds[slot, 0] = self.tokens[slot, 0]
                if req.replay_left > 0:
                    # teacher-forced replay: feed recorded history, accept all
                    n = min(k, req.replay_left)
                    start = len(req.out_tokens) - req.replay_left
                    for j in range(1, n):
                        feeds[slot, j] = req.out_tokens[start + j]
                    limit[slot] = n
                    forced[slot] = True
                else:
                    limit[slot] = min(
                        k, req.max_new_tokens - len(req.out_tokens)
                    )
                lookahead[slot] = int(limit[slot])

            if self.paged:
                self._ensure_flush_pages(lookahead=lookahead)
                if self.sched.active:
                    for slot in range(self.slots):
                        if self.sched.active.get(slot) is None:
                            limit[slot] = 0  # preempted mid-ensure: feed nothing
                    if self._table_dirty:
                        self.state["caches"] = pg.set_page_tables(
                            self.state["caches"], self._table
                        )
                        self._table_dirty = False
        if self.paged and not self.sched.active:
            return False  # everyone self-preempted under faults

        self._cycle_worked = True
        if any(limit[s] > 1 and not forced[s]
               for s, _ in self.sched.active.items()):
            with self._phase("decode_dispatch"):
                draft_dev = self._draft(
                    self.params, self.state, jnp.asarray(feeds[:, 0])
                )
            with self._phase("device_wait"):
                drafts = np.asarray(jax.block_until_ready(draft_dev))
            if self.tracer is not None:
                self.tracer.instant("spec_draft", args={"cycle": self._cycle})
            for slot, req in self.sched.active.items():
                n = int(limit[slot])
                if forced[slot] or n <= 1:
                    continue
                feeds[slot, 1:n] = drafts[slot, : n - 1]

        with self._phase("decode_dispatch"):
            v, applied, finite, self.state = self._verify(
                self.params, self.state, jnp.asarray(feeds),
                jnp.asarray(limit), jnp.asarray(forced),
            )
        # host sync: the verify results pull (the only other sync is the
        # draft pull above — 2 per cycle for up to spec_k tokens per lane)
        with self._phase("device_wait"):
            v, applied, finite = jax.block_until_ready((v, applied, finite))
            v = np.asarray(v)
            applied = np.asarray(applied)
            finite = np.asarray(finite)
        with self._phase("advance"):
            poison: set[int] = set()
            if self.faults is not None:
                for slot, req in list(self.sched.active.items()):
                    if self.faults.fires(
                        "poison_logits", cycle=self._cycle, uid=req.uid,
                        progress=len(req.out_tokens),
                    ):
                        poison.add(slot)
            self.metrics.inc("steps")
            self.metrics.inc("spec_cycles")
            if self.paged:
                # occupancy at the cycle peak (post-admission, pre-release)
                self._occupancy.append(self.pool.occupancy)
            self._advance_spec(
                feeds, v, applied, finite, limit, forced,
                time.perf_counter() - t0, poison,
            )
        if (self.paged and self.audit_every
                and self._cycle % self.audit_every == 0):
            self.audit().raise_if_violations()
        return True

    def _advance_spec(self, feeds, v, applied, finite, limit, forced,
                      dt: float, poison: set[int]) -> None:
        """Per-lane accounting for a speculative cycle.  ``applied[slot]``
        marks the feeds the verify scan actually ran (the lane was alive),
        so ``n_ap`` applied feeds mean: feed 0 (committed) plus ``n_ap - 1``
        accepted draft tokens.  Every applied feed is recorded exactly as
        ``spec_k`` sequential cycles would record it; the lane's next
        committed token is the verify argmax after its last applied feed —
        the verify token at first divergence, or the continuation after full
        acceptance.  Emission stops early (and retires ERRORED) at the first
        non-finite verify row, matching the sequential poisoned-step
        semantics: the token that *produced* the bad row is still recorded.
        """
        now = time.perf_counter()
        cyc_drafted = cyc_accepted = 0
        for slot, req in list(self.sched.active.items()):
            n_ap = int(applied[slot].sum())
            if n_ap == 0:
                continue
            if req.replay_left > 0:
                # replay lanes ignore logits entirely (teacher forcing)
                req.pos += n_ap
                req.replay_left -= n_ap
                if req.replay_left > 0:
                    idx = len(req.out_tokens) - req.replay_left
                    self.tokens[slot, 0] = req.out_tokens[idx]
                else:
                    # replay complete: resume the parked unpreempted stream
                    self.tokens[slot, 0] = req.pending_token
                    req.pending_token = None
                    if self.tracer is not None:
                        self.tracer.instant(
                            "replay_done", uid=req.uid, cat="request"
                        )
                continue
            drafted = max(0, int(limit[slot]) - 1)
            accepted = n_ap - 1
            cyc_drafted += drafted
            cyc_accepted += accepted
            self.metrics.inc("spec_draft_tokens", drafted)
            self.metrics.inc("spec_accepted_tokens", accepted)
            self.metrics.inc("spec_rejected_tokens", drafted - accepted)
            req.spec_accepted += accepted
            req.spec_rejected += drafted - accepted

            n_emit = n_ap
            err_reason = None
            if slot in poison:
                # injected fault poisons the cycle's logits: sequential
                # semantics record the fed token, then retire ERRORED
                n_emit = 1
                err_reason = "non-finite logits row"
            elif self.guard_logits:
                bad_idx = np.flatnonzero(~finite[slot, :n_ap])
                if bad_idx.size:
                    n_emit = int(bad_idx[0]) + 1
                    err_reason = "non-finite logits row"
            per_tok = dt / max(1, n_emit)
            retired = False
            for j in range(n_emit):
                tok = int(feeds[slot, j])
                req.out_tokens.append(tok)
                req.pos += 1
                req.token_latencies_s.append(per_tok)
                self._observe_token(req, per_tok, now)
                self.metrics.inc("decoded_tokens")
                if err_reason is not None and j == n_emit - 1:
                    self._retire(
                        req, Phase.ERRORED,
                        reason=(
                            f"request {req.uid} step {self._cycle}: "
                            f"{err_reason}"
                        ),
                    )
                    retired = True
                    break
                hit_eos = self.eos_id is not None and tok == self.eos_id
                if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                    if not hit_eos:
                        self.metrics.inc("budget_retired")
                    self._retire(req, Phase.DONE)
                    retired = True
                    break
            if not retired:
                self.tokens[slot, 0] = int(v[slot, n_emit - 1])
        if self.tracer is not None:
            self.tracer.instant(
                "spec_verify",
                args={"drafted": cyc_drafted, "accepted": cyc_accepted},
            )

    def _advance(self, nxt: np.ndarray, dt: float,
                 bad: dict[int, str] | None = None) -> None:
        """Shared per-token accounting for every family: record the decoded
        token, advance ``req.pos`` (this step appended its KV), retire on
        EOS or the token budget — budget-capped retirement counts
        ``budget_retired`` exactly once.  Slots in ``bad`` (poisoned step:
        non-finite logits row, invalid token id) retire ERRORED instead —
        isolation, not propagation: every other slot advances normally.

        A rematerializing request (``replay_left > 0``) is teacher-forced:
        the step's KV append is the point (``pos`` advances), its logits are
        ignored (the next token is recorded, not sampled), and nothing is
        re-counted as decoded output."""
        now = time.perf_counter()
        for slot, req in list(self.sched.active.items()):
            self._advance_one(
                slot, req, int(nxt[slot]), (bad or {}).get(slot), dt, now
            )

    def _advance_one(self, slot: int, req: Request, nxt_tok: int,
                     bad: str | None, dt: float, now: float,
                     *, cycle: int | None = None) -> None:
        """One slot's share of :meth:`_advance` — the single per-token
        accounting path both runtimes share: the sync cycle calls it per
        active slot right after its host sync, the async runtime calls it at
        the consumption boundary with the step's dispatch ``cycle`` (for
        error attribution) and its device-computed next token/finite flag.
        Keeping one body is what makes the async token stream bitwise
        identical to the oracle by construction."""
        if req.replay_left > 0:
            req.pos += 1
            req.replay_left -= 1
            if req.replay_left > 0:
                idx = len(req.out_tokens) - req.replay_left
                self.tokens[slot, 0] = req.out_tokens[idx]
            else:
                # replay complete: resume the parked unpreempted stream
                self.tokens[slot, 0] = req.pending_token
                req.pending_token = None
                if self.tracer is not None:
                    self.tracer.instant(
                        "replay_done", uid=req.uid, cat="request"
                    )
            return
        tok = int(self.tokens[slot, 0])
        req.out_tokens.append(tok)
        req.pos += 1
        req.token_latencies_s.append(dt)
        self._observe_token(req, dt, now)
        self.metrics.inc("decoded_tokens")
        if bad is not None:
            step_no = self._cycle if cycle is None else cycle
            self._retire(
                req, Phase.ERRORED,
                reason=f"request {req.uid} step {step_no}: {bad}",
            )
            return
        hit_eos = self.eos_id is not None and tok == self.eos_id
        if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
            if not hit_eos:
                self.metrics.inc("budget_retired")
            self._retire(req, Phase.DONE)
        else:
            self.tokens[slot, 0] = int(nxt_tok)

    def _observe_token(self, req: Request, per_tok_s: float,
                       now: float) -> None:
        """TTFT/TPOT split: a request's first-ever emitted token observes
        submission-to-first-token latency (TTFT, queue wait included, on the
        real clock — never the injectable TTL ``clock``); every later token
        observes the amortized inter-token latency of its cycle (TPOT)."""
        if req.t_first_token_s is None:
            req.t_first_token_s = now
            base = req.t_submit_s
            ttft = (now - base) if base is not None else per_tok_s
            self._ttft_s.append(ttft)
            self.metrics.observe("ttft_s", ttft)
        else:
            self._tpot_s.append(per_tok_s)
            self.metrics.observe("tpot_s", per_tok_s)

    # ---------------------------------------- retirement, expiry, preemption

    def _retire(self, req: Request, phase: Phase,
                reason: str | None = None) -> None:
        """Single retirement path for every terminal phase: reset the
        page-table row to scratch, honor an injected delayed-release fault
        (the pages stay held by the retired uid until serviced), release
        through the scheduler, bump the per-phase stat, and — async runtime
        — hand the finished request to the background completion thread."""
        if self._runner is not None and req.slot is not None:
            # drop dispatch-frontier mirrors; lagging in-flight steps for
            # this slot are discarded at consumption (admit_seq mismatch)
            self._runner.on_slot_cleared(req.slot)
        if self.paged and req.slot is not None:
            self._table[req.slot, :] = req.slot  # stale entries -> scratch
            self._table_dirty = True
        if (self.paged and self.faults is not None and req.pages
                and self.faults.fires(
                    "delayed_release", cycle=self._cycle, uid=req.uid
                )):
            self._deferred.append(
                (self._cycle + self.faults.delay_cycles, req.uid,
                 list(req.pages))
            )
            req.pages = []  # scheduler releases reservation + slot only
        self.sched.retire(req, phase, reason=reason)
        stat = {
            Phase.EXPIRED: "expired", Phase.CANCELLED: "cancelled",
            Phase.ERRORED: "errored",
        }.get(phase)
        if stat is not None:
            self.metrics.inc(stat)
        if phase is Phase.DONE and req.t_submit_s is not None:
            e2e = time.perf_counter() - req.t_submit_s
            self._e2e_s.append(e2e)
            self.metrics.observe("e2e_latency_s", e2e)
        if self.tracer is not None:
            self.tracer.end_open(uid=req.uid, cat="request")
            self.tracer.instant(
                phase.value, uid=req.uid, cat="request",
                args={"reason": reason} if reason is not None else None,
            )
        if self._completions is not None:
            self.metrics.inc("completions_enqueued")
            self._completions.put(req)

    def _service_deferred(self) -> None:
        """Free pages whose injected release delay has elapsed."""
        if not self._deferred:
            return
        due = [d for d in self._deferred if d[0] <= self._cycle]
        self._deferred = [d for d in self._deferred if d[0] > self._cycle]
        for _ready, uid, pages in due:
            for page in pages:
                self.pool.free(page, owner=uid)

    def _expire(self) -> None:
        """Retire every live request whose ``deadline_s`` TTL has passed."""
        now = self.clock()
        for req in self.sched.expired(now):
            if req.phase == Phase.WAITING:
                self.sched.waiting.remove(req)
            self._retire(
                req, Phase.EXPIRED,
                reason=(
                    f"request {req.uid}: deadline_s={req.deadline_s} "
                    "exceeded before completion"
                ),
            )

    def _pick_victim(self, exclude: Request | None = None) -> Request | None:
        """Victim for preemption: an active DECODE-phase request admitted in
        an *earlier* cycle (same-cycle admissions are mid-adoption — their
        prefill splice must not be torn down underneath them).  Policy
        ``"youngest"`` preempts the latest admission (FIFO fairness: the
        last one in yields first); ``"fewest_pages"`` the cheapest
        rematerialization, ties to the youngest."""
        cands = [
            r for r in self.sched.active.values()
            if r is not exclude and r.phase == Phase.DECODE
            and r.admit_cycle < self._cycle
        ]
        if not cands:
            return None
        if self.preempt_policy == "fewest_pages":
            return min(cands, key=lambda r: (len(r.pages), -r.admit_seq))
        return max(cands, key=lambda r: r.admit_seq)

    def _preempt(self, req: Request) -> None:
        """Preempt-by-rematerialization (docs/SERVING.md §10): park the
        decoded-but-unfed next token, reset the table row, and hand the
        request to the scheduler, which queues its decoded tokens for
        teacher-forced replay and requeues it at the FIFO head.

        A victim caught *mid-replay* (preempted again before its previous
        rematerialization finished) keeps its originally parked token — the
        token currently in the feed buffer is a replayed one, already in
        ``out_tokens``."""
        slot = req.slot
        if self._runner is not None:
            # resolve a still-lazy admission feed into the host mirror (the
            # parked token must be a real value) and drop dispatch mirrors
            self._runner.on_preempt(req)
        if req.replay_left > 0:
            pending = req.pending_token
        else:
            pending = int(self.tokens[slot, 0])
        self._table[slot, :] = slot
        self._table_dirty = True
        self.metrics.inc("preempted")
        self.metrics.inc("preempt_remat_tokens", len(req.out_tokens))
        if self.tracer is not None:
            self.tracer.end_open(uid=req.uid, cat="request")
            self.tracer.instant(
                "preempt", uid=req.uid, cat="request",
                args={"tokens_to_replay": len(req.out_tokens)},
            )
            self.tracer.begin("queue", uid=req.uid, cat="request")
        self.sched.preempt(req, pending_token=pending)

    def _use_splitkv_now(self) -> bool:
        if self._step_splitkv is None or self.splitkv == "never":
            return False
        if self.splitkv == "always":
            return True
        if self.page_affine:
            # sharded pool storage: the plain step would gather every
            # shard's pages to every chip — the sharded walk is the point
            return True
        axis_size = int(self.mesh.shape[self.splitkv_axis])
        if axis_size <= 1:
            return False
        active = self.sched.active.values()
        max_blocks = max((r.pos // self.block_n for r in active), default=0)
        cores = bd_ops.default_splitkv_cores()
        return (
            len(self.sched.active) * self._h_kv < cores
            and max_blocks >= 2 * axis_size
        )

    # ----------------------------------------------------- paged admission

    def _alloc_page(self, req: Request, *, admission: bool = False,
                    block: int | None = None) -> int | None:
        """Pool alloc charged to ``req``: converts one of its reservation
        units and joins its page list.

        Under ``reserve_policy="worst_case"`` the reservation always covers
        the alloc (the preempt-free guarantee, unchanged).  Under
        ``"expected"`` a request that outlives its expectation arrives here
        with ``reserved_pages == 0`` and must *extend* one unit — when the
        commitment budget is full, a victim is preempted per
        ``preempt_policy``; with no eligible victim the requester preempts
        *itself* (returns None; the caller skips — the request is already
        requeued).  Admission-time allocs never extend: ``reserve_need``
        floors the reservation at the prompt's own block count, so
        preemption can only fire on the decode flush path.

        Retention ordering: ``pool.reserve``/``pool.alloc`` drain the
        RETAINED tier (LRU) before reporting pressure, so every retained
        page is reclaimed before any victim is preempted here.

        ``block`` (page-affine mode) pins the page to the shard owning
        that table column; when the shard is dry — free list empty *and*
        no retained page in the shard — victims are preempted until one
        of their pages refills it (or the requester self-preempts).

        An injected ``alloc_fail`` fault exercises the same victim path
        deterministically (the alloc itself then proceeds — recovery, not
        crash, is what the fault probes)."""
        if (self.faults is not None
                and self.faults.fires(
                    "alloc_fail", cycle=self._cycle, uid=req.uid
                )):
            victim = self._pick_victim(exclude=req)
            if victim is not None:
                self._preempt(victim)
            elif not admission and req.reserved_pages <= 0:
                self._preempt(req)
                return None
        if req.reserved_pages <= 0:
            while not self.pool.reserve(1, owner=req.uid):
                victim = self._pick_victim(exclude=req)
                if victim is None:
                    self._preempt(req)
                    return None
                self._preempt(victim)
            req.reserved_pages += 1
        shard = None
        if self.page_affine and block is not None:
            shard = block // self._nb_local
            while not self.pool.shard_available(shard):
                victim = self._pick_victim(exclude=req)
                if victim is None:
                    if admission:
                        # mid-splice: the bucket adoption cannot be torn
                        # down cleanly — full per-shard provisioning (the
                        # affine default) makes this unreachable
                        raise RuntimeError(
                            f"page-affine shard {shard} exhausted at "
                            f"admission of request {req.uid} with no "
                            "preemptible victim"
                        )
                    self._preempt(req)
                    return None
                self._preempt(victim)
        page = self.pool.alloc(owner=req.uid, shard=shard)
        req.reserved_pages -= 1
        req.pages.append(page)
        return page

    def _splice_side_state(self, dstate, slot_ids) -> list[str]:
        """Copy the declared dense side-state (``PagedSpec.side_state`` —
        e.g. HybridLM's SSM recurrent states) of just-prefilled rows into
        their decode slots (prefill row ``r`` -> slot ``slot_ids[r]``); the
        page table never sees these pytrees.  Returns the top-level state
        keys handled (the shim skips them in its generic splice)."""
        if not self.spec.side_state:
            return []
        sidx = jnp.asarray(slot_ids, jnp.int32)
        rows = jnp.arange(len(slot_ids), dtype=jnp.int32)
        handled = []
        for path, bdim in self.spec.side_state:
            dst = get_path(self.state, path)
            src = get_path(dstate, path)

            def put(d, s):
                idx = [slice(None)] * d.ndim
                idx[bdim] = sidx
                src_idx = [slice(None)] * s.ndim
                src_idx[bdim] = rows
                return d.at[tuple(idx)].set(
                    s[tuple(src_idx)].astype(d.dtype))

            set_path(self.state, path, jax.tree.map(put, dst, src))
            handled.append(path.split("/")[0])
        return handled

    def _admit_and_prefill(self, *, defer_first: bool = False) -> dict:
        with self._phase("schedule"):
            groups = self.sched.admit()
            if groups:
                self._note_admissions(groups)
        lazy: dict[int, tuple] = {}
        for bucket_len, reqs in groups.items():
            with self._phase("prefill"):
                lazy.update(self._prefill_bucket(
                    bucket_len, reqs, defer_first=defer_first
                ))
        return lazy

    def _note_admissions(self, groups: dict[int, list[Request]]) -> None:
        """Per-request admission telemetry: close the queue span, open the
        prefill span, and observe queue wait — first admission only, so a
        preemption re-admission never double-counts the same request."""
        now = time.perf_counter()
        for reqs in groups.values():
            for req in reqs:
                first_admit = req.t_admit_s is None
                req.t_admit_s = now
                if first_admit and req.t_submit_s is not None:
                    qw = now - req.t_submit_s
                    self._queue_wait_s.append(qw)
                    self.metrics.observe("queue_wait_s", qw)
                if self.tracer is not None:
                    self.tracer.end_open(uid=req.uid, cat="request")
                    self.tracer.begin("prefill", uid=req.uid, cat="request")

    def _prefill_bucket(self, bucket_len: int, reqs: list[Request],
                        *, defer_first: bool = False) -> dict:
        # divergent-suffix prefill: row r holds request r's unshared tail
        toks = np.zeros((self.slots, bucket_len), np.int32)
        lens = np.ones((self.slots,), np.int32)  # pad rows: length 1
        shared_blocks = [len(r.shared_pages) for r in reqs]
        p_max = max(shared_blocks)
        for r, req in enumerate(reqs):
            sl = req.suffix_len(self.block_n)
            toks[r, :sl] = req.prompt[len(req.shared_pages) * self.block_n :]
            lens[r] = sl
            self.metrics.inc("prefill_tokens", sl)
            self.metrics.inc("prefill_tokens_saved", req.prompt_len - sl)
        if self.spec.exact_prefill:
            # all admitted rows carry exactly bucket_len real tokens —
            # recurrent side-state tolerates no right-padding, and the
            # model's prefill returns last-token logits directly
            logits, dstate = self._prefill(self.params, jnp.asarray(toks))
        elif p_max == 0:
            logits, dstate = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens)
            )
        else:
            # pad the prior-page walk to a power-of-two block count so
            # the jit cache keys on (bucket_len, prior bucket) only
            p_pad = bucket_for(p_max, min_bucket=1)
            pages = np.zeros((self.slots, p_pad), np.int32)
            plens = np.zeros((self.slots,), np.int32)
            for r, req in enumerate(reqs):
                s = len(req.shared_pages)
                pages[r, :s] = req.shared_pages
                plens[r] = s * self.block_n
            logits, dstate = self._prefill_shared(
                self.params, self.state["caches"], jnp.asarray(toks),
                jnp.asarray(lens), jnp.asarray(pages), jnp.asarray(plens),
            )
        self.metrics.inc("prefill_calls")
        lazy: dict[int, tuple] = {}
        if defer_first:
            # async runtime: the first token stays a device array — no host
            # sync at admission; the scalar is resolved lazily at the slot's
            # first consumption boundary (or at preemption)
            first_dev = jnp.argmax(logits[:, 0], axis=-1)
            first = None
        else:
            first = np.argmax(np.asarray(logits)[:, 0], axis=-1)

        slot_ids, lengths, pages_per_req = [], [], []
        for r, req in enumerate(reqs):
            s = len(req.shared_pages)
            sl = req.suffix_len(self.block_n)
            n_blocks = sl // self.block_n
            # covered by the reservation floor — never preempts here;
            # page-affine: fresh block j lands at table column s + j
            pgs = [
                self._alloc_page(req, admission=True, block=s + j)
                for j in range(n_blocks)
            ]
            self._table[req.slot, :] = req.slot  # fresh scratch row
            self._table[req.slot, :s] = req.shared_pages
            if req.spec_page is not None:
                # speculative flush destination (COW candidate)
                self._table[req.slot, s] = req.spec_page
            self._table[req.slot, s : s + n_blocks] = pgs
            slot_ids.append(req.slot)
            lengths.append(sl)
            pages_per_req.append(pgs)
            req.phase = Phase.DECODE
            req.pos = req.prompt_len
            req.admit_cycle = self._cycle
            if self.tracer is not None:
                self.tracer.end("prefill", uid=req.uid, cat="request")
                self.tracer.begin("decode", uid=req.uid, cat="request")
            if req.replay_left > 0:
                # rematerializing victim: teacher-force its recorded
                # decode stream (first replayed token now, the rest in
                # `_advance`) — rebuilding the decode-built cache blocks
                # through the decode path keeps them bitwise identical
                self.tokens[req.slot, 0] = req.out_tokens[0]
            elif req.pending_token is not None:
                # preempted before any decode: resume from the parked
                # decoded-but-unfed token, not the re-prefill's argmax
                self.tokens[req.slot, 0] = req.pending_token
                req.pending_token = None
            elif defer_first:
                lazy[req.slot] = (first_dev, r)
            else:
                self.tokens[req.slot, 0] = int(first[r])
        self._table_dirty = True
        self.state["caches"] = pg.adopt_prefill(
            self.state["caches"], dstate["caches"],
            slot_ids=slot_ids, lengths=lengths,
            pages_per_req=pages_per_req, block_n=self.block_n,
            base_blocks=shared_blocks,
        )
        self._splice_side_state(dstate, slot_ids)
        sidx = jnp.asarray(slot_ids, jnp.int32)
        self.state["pos"] = self.state["pos"].at[sidx].set(
            jnp.asarray([r.prompt_len for r in reqs], jnp.int32)
        )
        # full prompt blocks (shared + fresh) become discoverable for
        # later admissions — content is committed by the adoption above
        for r, req in enumerate(reqs):
            self.sched.register_prefix(
                req, req.shared_pages + pages_per_req[r]
            )
        return lazy

    def _ensure_flush_pages(
        self, lookahead: dict[int, int] | None = None, pos_of=None
    ) -> None:
        """Allocate the destination page for every sequence whose residual
        fills on the upcoming step (pos % block_n == block_n - 1): the flush
        will commit packed block pos // block_n through the page table.

        ``lookahead`` (slot -> feed count, speculative cycles) widens the
        check to every position the cycle can reach — a ``spec_k``-token
        verify scan may cross multiple block boundaries, and each needs its
        destination (fresh page / COW replica) resolved before the table is
        pushed.  ``None`` keeps the sequential single-step window.

        Copy-on-write: when the destination column already holds a pool page
        with refcount > 1 (a speculative shared tail — serve/scheduler.py),
        the flush must not be visible to the other holders.  The request
        gets a private page (covered by its reservation: spec-tail pages are
        never discounted at admission), the packed block is replicated
        device-side (``pages.cow_pages``), and only this request's table
        column is repointed before the flush commits over the replica.

        This is the one place preemption can fire (``_alloc_page`` under the
        expected reservation policy), so the iteration snapshots the active
        set and re-checks each slot: a request preempted by an earlier
        allocation this cycle (or that preempted *itself* — alloc returned
        None) is skipped, its table row already reset to scratch.

        ``pos_of`` (request -> position) overrides the position the check
        runs at: the async runtime passes its dispatch-frontier position,
        which runs ahead of ``req.pos`` (consumption truth) by the in-flight
        window — destinations must exist before the step that flushes them
        is *dispatched*, not consumed."""
        cow_src, cow_dst = [], []
        for req in list(self.sched.active.values()):
            pos = req.pos if pos_of is None else pos_of(req)
            window = 1 if lookahead is None else lookahead.get(req.slot, 1)
            for j in range(max(1, window)):
                if self.sched.active.get(req.slot) is not req:
                    break  # preempted by an earlier alloc this cycle
                if (pos + j) % self.block_n != self.block_n - 1:
                    continue
                blk = (pos + j) // self.block_n
                entry = int(self._table[req.slot, blk])
                if entry < self.slots:  # still scratch -> fresh private page
                    page = self._alloc_page(req, block=blk)
                    if page is None:
                        continue  # self-preempted: requeued, row reset
                    self._table[req.slot, blk] = page
                    self._table_dirty = True
                elif self.pool.refcount(entry) > 1:  # shared -> copy-on-write
                    # page-affine: src and dst both back column blk, so the
                    # replica stays in the shard that owns the column
                    page = self._alloc_page(req, block=blk)
                    if page is None:
                        continue  # self-preempted: requeued, row reset
                    cow_src.append(entry)
                    cow_dst.append(page)
                    req.pages.remove(entry)
                    if req.spec_page == entry:
                        req.spec_page = None
                    self.pool.free(entry, owner=req.uid)
                    self._table[req.slot, blk] = page
                    self._table_dirty = True
                    self.metrics.inc("cow_copies")
                    if self.tracer is not None:
                        self.tracer.instant(
                            "cow", uid=req.uid, cat="request",
                            args={"src": entry, "dst": page},
                        )
                else:
                    # privately held page (last sharer left): the flush will
                    # overwrite it in place — drop any stale index node first
                    self.sched.forget_page(entry)
        if cow_src:
            self.state["caches"] = pg.cow_pages(
                self.state["caches"], cow_src, cow_dst
            )

    # ------------------------------------------------- exact-length shim

    def _admit_exact(self, *, defer_first: bool = False) -> dict:
        """Shim admission for dense-state models: the same scheduler (pool-
        less, exact-length groups), one per-request exact-length prefill
        spliced into the batched state."""
        with self._phase("schedule"):
            groups = self.sched.admit()
            if groups:
                self._note_admissions(groups)
        lazy: dict[int, tuple] = {}
        for reqs in groups.values():
            for req in reqs:
                with self._phase("prefill"):
                    lazy.update(
                        self._fill_slot(req, defer_first=defer_first)
                    )
        return lazy

    def _fill_slot(self, req: Request, *, defer_first: bool = False) -> dict:
        i = req.slot
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, st = self._prefill(self.params, batch)

        # declared recurrent side-state splices on its true batch axis (the
        # same routine the paged admission uses, with one row -> one slot)
        handled = self._splice_side_state(st, [i])

        def splice(dst, src):
            if dst is None:
                return None
            if not isinstance(dst, jax.Array) and not hasattr(dst, "ndim"):
                return dst
            # batch dim: caches are stacked (L, B, ...) -> dim 1; pos -> dim 0
            bdim = 0 if dst.ndim == 1 else 1
            idx = [slice(None)] * dst.ndim
            idx[bdim] = i
            src_idx = [slice(None)] * src.ndim
            src_idx[bdim] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))

        for key in self.state:
            if key in handled:
                continue
            self.state[key] = jax.tree.map(splice, self.state[key], st[key])
        lazy: dict[int, tuple] = {}
        if defer_first:
            # scalar device argmax, resolved at the consumption boundary
            lazy[i] = (jnp.argmax(logits[0, -1]), None)
        else:
            self.tokens[i, 0] = int(np.argmax(np.asarray(logits)[0, -1]))
        self.metrics.inc("prefill_calls")
        self.metrics.inc("prefill_tokens", req.prompt_len)
        req.phase = Phase.DECODE
        req.pos = req.prompt_len
        req.admit_cycle = self._cycle
        if self.tracer is not None:
            self.tracer.end("prefill", uid=req.uid, cat="request")
            self.tracer.begin("decode", uid=req.uid, cat="request")
        return lazy
