"""Slot-based batched serving engine (continuous-batching-lite).

A fixed number of decode slots share one jitted decode_step (static shapes);
finished sequences free their slot, which is refilled from the request queue
on the next cycle.  Per-slot KV-cache occupancy lives in the QuantKVCache's
per-sequence pack_blocks/res_len, so refilling a slot is just resetting its
row — no reallocation.  Dead-slot eviction (straggler/failure mitigation):
slots whose request exceeded max_new_tokens are forcibly retired each cycle.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 8, max_seq: int = 2048,
                 eos_id: int | None = None, impl: str = "auto",
                 quant_impl: str = "auto"):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.state = model.init_decode_state(slots, max_seq)
        # host-side next-token buffer: the decode loop reads/writes it with
        # plain numpy (one device->host pull per cycle, one upload per step)
        # instead of per-slot int()/.at[].set() round-trips
        self.tokens = np.zeros((slots, 1), np.int32)
        # impl: attention kernel; quant_impl: residual-flush kernel (the
        # cache-append path) — both baked into the one jitted decode step
        self._step = jax.jit(
            lambda p, s, t: model.decode_step(
                p, s, t, impl=impl, quant_impl=quant_impl
            ),
            static_argnames=(),
        )
        # one jitted prefill for the engine lifetime (max_seq is baked in):
        # XLA's jit cache then keys on prompt length only, instead of the
        # fresh-jit-per-request retrace the old _fill_slot paid
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.max_seq)
        )
        self.stats = {"decoded_tokens": 0, "steps": 0, "evicted": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slot(self, i: int, req: Request):
        """Prefill one request into slot i (single-sequence prefill, then the
        per-slot cache rows are spliced into the batched state)."""
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, st = self._prefill(self.params, batch)
        # splice slot-0 rows of st into row i of the batched state
        def splice(dst, src):
            if dst is None:
                return None
            if not isinstance(dst, jax.Array) and not hasattr(dst, "ndim"):
                return dst
            # batch dim: caches are stacked (L, B, ...) -> dim 1; pos -> dim 0
            bdim = 0 if dst.ndim == 1 else 1
            idx = [slice(None)] * dst.ndim
            idx[bdim] = i
            src_idx = [slice(None)] * src.ndim
            src_idx[bdim] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))

        self.state = jax.tree.map(splice, self.state, st)
        self.tokens[i, 0] = int(np.argmax(np.asarray(logits)[0, -1]))
        self.active[i] = req

    def step(self):
        """One engine cycle: refill free slots, one batched decode step,
        collect outputs, retire finished/evicted requests."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self._fill_slot(i, self.queue.popleft())

        if all(r is None for r in self.active):
            return False

        logits, self.state = self._step(
            self.params, self.state, jnp.asarray(self.tokens)
        )
        # one host sync per cycle: the logits pull; current tokens already
        # live host-side, and the write-back below is plain numpy
        nxt = np.argmax(np.asarray(logits)[:, 0], axis=-1)
        self.stats["steps"] += 1
        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(self.tokens[i, 0])
            req.out_tokens.append(tok)
            self.stats["decoded_tokens"] += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                if not hit_eos and len(req.out_tokens) >= req.max_new_tokens:
                    self.stats["evicted"] += 1  # forced retirement
                req.done = True
                self.active[i] = None
            else:
                self.tokens[i, 0] = int(nxt[i])
        return True

    def run(self, max_cycles: int = 10_000):
        t0 = time.time()
        cycles = 0
        while (self.queue or any(self.active)) and cycles < max_cycles:
            self.step()
            cycles += 1
        dt = time.time() - t0
        return {
            **self.stats,
            "wall_s": dt,
            "tokens_per_s": self.stats["decoded_tokens"] / max(dt, 1e-9),
        }
