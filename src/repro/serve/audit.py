"""Invariant auditor for the paged serving engine.

Four views of page ownership must agree at every cycle boundary, and each
is maintained by different code:

1. the **pool** (`repro.serve.pages.PagePool`) — refcounts, holder tags,
   the free list, the RETAINED tier (refcount-0 pages kept for prefix
   re-admission), and the commitment budget (``n_used + reserved``);
2. the **page tables** (the engine's host mirror ``_table``) — which pool
   page each slot's block column resolves to on device;
3. the **prefix index** (`repro.serve.scheduler.PrefixIndex`) — which
   resident pages are discoverable as shared prompt prefixes;
4. the **per-request page lists** (``Request.pages``) — what each live
   request believes it holds.

:func:`audit_engine` cross-checks all four and returns an
:class:`AuditReport` naming every violation (leaked pages, dangling index
nodes, table columns aimed at freed pages, refcount/holder drift,
reservation-ledger desync).  The engine runs it every ``audit_every``
cycles and at drain; tests also call it after seeded corruptions to prove
the auditor itself catches each breach class (tests/test_serve_pressure.py).

The audit reads only host-side state — no device sync — so it is cheap
enough for continuous background use.
"""
from __future__ import annotations

import dataclasses

from repro.serve.scheduler import Phase


class AuditError(RuntimeError):
    """An invariant audit found violations (the report text is the message)."""


@dataclasses.dataclass
class AuditReport:
    """Outcome of one :func:`audit_engine` pass."""

    violations: list
    pages_checked: int = 0
    table_entries_checked: int = 0
    index_nodes_checked: int = 0
    requests_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_violations(self) -> None:
        if self.violations:
            raise AuditError(
                f"{len(self.violations)} invariant violation(s):\n  "
                + "\n  ".join(self.violations)
            )

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        if self.ok:
            return (
                f"audit ok ({self.pages_checked} pages, "
                f"{self.table_entries_checked} table entries, "
                f"{self.index_nodes_checked} index nodes)"
            )
        return "audit FAILED:\n  " + "\n  ".join(self.violations)


def _audit_pool(pool, out: list) -> int:
    """Pool-internal accounting: free list vs refcounts vs holders vs the
    retained tier vs the commitment budget."""
    free = pool.free_pages()
    if len(set(free)) != len(free):
        dups = sorted({p for p in free if free.count(p) > 1})
        out.append(f"free list holds duplicate page(s) {dups}")
    free_set = set(free)
    retained_set = set(pool.retained_pages())
    for page in free_set:
        if page < pool.n_scratch:
            out.append(f"scratch page {page} on the free list")
        if pool.refcount(page) != 0:
            out.append(
                f"page {page} is on the free list with refcount "
                f"{pool.refcount(page)}"
            )
        if page in retained_set:
            out.append(f"page {page} is both free and retained")
    for page in retained_set:
        if page < pool.n_scratch:
            out.append(f"scratch page {page} in the retained tier")
        if pool.refcount(page) != 0:
            out.append(
                f"retained page {page} has refcount {pool.refcount(page)} "
                "(the tier holds only refcount-0 pages)"
            )
        if pool.holders(page):
            out.append(
                f"retained page {page} still lists holders "
                f"{pool.holders(page)}"
            )
    for page in range(pool.n_scratch, pool.n_pages):
        rc = pool.refcount(page)
        if rc < 0:
            out.append(f"page {page} has negative refcount {rc}")
        if rc > 0 and (page in free_set or page in retained_set):
            continue  # already reported above
        if rc == 0 and page not in free_set and page not in retained_set:
            out.append(
                f"leaked page {page}: refcount 0 but on neither the free "
                "list nor the retained tier"
            )
        holders = pool.holders(page)
        if rc > 0 and len(holders) != rc:
            out.append(
                f"page {page}: refcount {rc} but {len(holders)} holder "
                f"tag(s) {holders}"
            )
        if rc == 0 and holders:
            out.append(f"freed page {page} still lists holders {holders}")
    if pool.n_used != pool.capacity - pool.n_free:
        out.append(
            f"n_used={pool.n_used} disagrees with capacity-n_free="
            f"{pool.capacity - pool.n_free}"
        )
    if pool.reserved < 0:
        out.append(f"negative reservation count {pool.reserved}")
    tracked = sum(pool._owner_reserved.values())
    if tracked > pool.reserved:
        out.append(
            f"owner reservation ledger sums to {tracked} > pool.reserved="
            f"{pool.reserved}"
        )
    if pool.committed > pool.capacity:
        out.append(
            f"over-committed pool: committed={pool.committed} > capacity="
            f"{pool.capacity}"
        )
    return pool.n_pages - pool.n_scratch


def audit_engine(engine) -> AuditReport:
    """Cross-check the four ownership views of a (paged) ServeEngine.

    Non-paged engines (the exact-length shim has no pool) audit trivially
    clean — there is no page state to drift.
    """
    out: list = []
    report = AuditReport(out)
    pool = getattr(engine, "pool", None)
    if pool is None:
        return report
    sched = engine.sched
    report.pages_checked = _audit_pool(pool, out)

    # pages parked by a delayed-release fault are legitimately held by their
    # (already retired) owner until the engine services the deferral
    deferred_pages: dict[int, object] = {}
    for _ready, uid, pages in getattr(engine, "_deferred", ()):
        for page in pages:
            deferred_pages[page] = uid

    # --- per-request page lists vs pool holders -------------------------
    live_uids = set()
    for req in sched.active.values():
        live_uids.add(req.uid)
        report.requests_checked += 1
        for page in req.pages:
            if page < pool.n_scratch:
                out.append(
                    f"request {req.uid} lists scratch page {page} as held"
                )
            elif pool.refcount(page) <= 0:
                out.append(
                    f"request {req.uid} lists freed page {page} as held"
                )
            elif req.uid not in pool.holders(page):
                out.append(
                    f"request {req.uid} lists page {page} but is not among "
                    f"its holders {pool.holders(page)}"
                )
        if pool.owner_reserved(req.uid) != req.reserved_pages:
            out.append(
                f"request {req.uid}: reserved_pages={req.reserved_pages} "
                f"but the pool ledger holds "
                f"{pool.owner_reserved(req.uid)} unit(s)"
            )
    for req in sched.waiting:
        live_uids.add(req.uid)
        if req.pages:
            out.append(
                f"waiting request {req.uid} still lists pages {req.pages}"
            )

    # --- allocated pages must be held by someone accounted for ----------
    for page in range(pool.n_scratch, pool.n_pages):
        if pool.refcount(page) <= 0:
            continue
        holders = pool.holders(page)
        accounted = (
            any(h in live_uids or h is None for h in holders)
            or page in deferred_pages
        )
        if not accounted:
            out.append(
                f"leaked page {page}: refcount {pool.refcount(page)} held "
                f"by retired owner(s) {holders}"
            )

    # --- page-table columns ---------------------------------------------
    table = getattr(engine, "_table", None)
    if table is not None:
        n_slots, nb_max = table.shape
        report.table_entries_checked = n_slots * nb_max
        for slot in range(n_slots):
            req = sched.active.get(slot)
            held = set(req.pages) if req is not None else set()
            for blk in range(nb_max):
                entry = int(table[slot, blk])
                if entry < pool.n_scratch:
                    if entry != slot:
                        out.append(
                            f"table[{slot},{blk}] points at scratch page "
                            f"{entry} of another slot (injectivity breach)"
                        )
                    continue
                if pool.refcount(entry) <= 0:
                    out.append(
                        f"table[{slot},{blk}] points at freed page {entry}"
                    )
                elif req is None:
                    out.append(
                        f"table[{slot},{blk}] of idle slot still points at "
                        f"pool page {entry}"
                    )
                elif entry not in held:
                    out.append(
                        f"table[{slot},{blk}] points at page {entry} not in "
                        f"request {req.uid}'s page list"
                    )

    # --- prefix-index registrations --------------------------------------
    index = sched.index
    if index is not None:
        report.index_nodes_checked = len(index._meta)
        for page, (digest, parent, _toks) in index._meta.items():
            if pool.refcount(page) <= 0 and not pool.is_retained(page):
                out.append(
                    f"dangling prefix-index node: page {page} is registered "
                    "but free"
                )
            if index._page_of.get(digest) != page:
                out.append(
                    f"prefix-index node for page {page}: digest does not map "
                    "back to it"
                )
            if page not in index._children.get(parent, ()):
                out.append(
                    f"prefix-index node for page {page}: missing from its "
                    "parent's child list"
                )
        for digest, page in index._page_of.items():
            if page not in index._meta:
                out.append(
                    f"prefix-index digest entry maps to unregistered page "
                    f"{page}"
                )
        # retained pages exist only to be re-discovered: one with no index
        # node is dead weight the reclaim path can never justify keeping
        for page in pool.retained_pages():
            if page not in index._meta:
                out.append(
                    f"retained page {page} is not registered in the prefix "
                    "index"
                )

    _audit_spec(engine, out)
    _audit_telemetry(engine, out)
    return report


def _audit_telemetry(engine, out: list) -> None:
    """Telemetry consistency (docs/OBSERVABILITY.md).

    * lifecycle counters are non-negative (the registry enforces monotone
      counters, so a negative here means the view layer drifted);
    * with a tracer attached, span discipline holds: every live request has
      exactly one open lifecycle span (``queue`` while waiting, ``prefill``
      or ``decode`` while active) and no span stays open for a uid that has
      already retired.
    """
    stats = getattr(engine, "stats", {})
    for name, value in stats.items():
        if isinstance(value, (int, float)) and value < 0:
            out.append(f"negative lifecycle counter {name}={value}")
    tracer = getattr(engine, "tracer", None)
    sched = getattr(engine, "sched", None)
    if tracer is None or sched is None:
        return
    live = {r.uid for r in sched.active.values()}
    live |= {r.uid for r in sched.waiting}
    open_by_uid: dict = {}
    for cat, name, uid in tracer.open_spans():
        if cat == "request" and uid is not None:
            open_by_uid.setdefault(uid, []).append(name)
    for uid, names in open_by_uid.items():
        if uid not in live:
            out.append(
                f"tracer span(s) {names} still open for retired request {uid}"
            )
        elif len(names) > 1:
            out.append(
                f"request {uid} holds {len(names)} lifecycle spans open "
                f"simultaneously: {names}"
            )
    for uid in sorted(live - set(open_by_uid)):
        out.append(f"live request {uid} has no open lifecycle span")


def _audit_spec(engine, out: list) -> None:
    """Self-speculative decoding state (SERVING.md §11).

    * config sanity: ``spec_k >= 1``; with speculation on, ``spec_bits``
      must sit in ``[1, kv_bits]`` and the draft/verify callables exist;
    * token conservation: every drafted token is either accepted or
      rejected — ``spec_draft_tokens == spec_accepted + spec_rejected``;
    * position bookkeeping: an active DECODE request's host ``pos`` mirror
      must equal ``prompt_len + len(out_tokens) - replay_left`` — the
      multi-token verify append and the per-token sequential path maintain
      the same ledger, so drift here means a lost or double-counted append.
    """
    spec_k = getattr(engine, "spec_k", 1)
    stats = getattr(engine, "stats", {})
    if spec_k < 1:
        out.append(f"spec_k={spec_k} out of range (must be >= 1)")
    if spec_k > 1:
        bits = getattr(
            getattr(getattr(engine, "model", None), "cfg", None),
            "kv_bits", None,
        )
        sb = getattr(engine, "spec_bits", None)
        if sb is not None and bits is not None and not (1 <= sb <= bits):
            out.append(
                f"spec_bits={sb} outside [1, kv_bits={bits}]"
            )
        if getattr(engine, "_draft", None) is None:
            out.append("spec_k > 1 but no draft pass was built")
        if getattr(engine, "_verify", None) is None:
            out.append("spec_k > 1 but no verify pass was built")
    drafted = stats.get("spec_draft_tokens", 0)
    accepted = stats.get("spec_accepted_tokens", 0)
    rejected = stats.get("spec_rejected_tokens", 0)
    if min(drafted, accepted, rejected) < 0:
        out.append(
            f"negative speculative counter(s): drafted={drafted} "
            f"accepted={accepted} rejected={rejected}"
        )
    if drafted != accepted + rejected:
        out.append(
            f"speculative token conservation breach: drafted={drafted} != "
            f"accepted={accepted} + rejected={rejected}"
        )
    sched = getattr(engine, "sched", None)
    if sched is None:
        return
    for req in sched.active.values():
        if req.spec_accepted < 0 or req.spec_rejected < 0:
            out.append(
                f"request {req.uid}: negative per-request speculative "
                f"counter(s) ({req.spec_accepted}/{req.spec_rejected})"
            )
        if req.phase is Phase.DECODE:
            want = req.prompt_len + len(req.out_tokens) - req.replay_left
            if req.pos != want:
                out.append(
                    f"request {req.uid}: pos={req.pos} but prompt_len + "
                    f"out_tokens - replay_left = {want} (append ledger "
                    "drift)"
                )
