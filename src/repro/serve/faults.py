"""Deterministic fault injection for the serving engine.

Chaos testing is only useful when a failure found once can be found again:
a :class:`FaultPlan` is a *seeded* schedule of failures that the engine
consults at named sites, so every injected fault — and therefore every
recovery path it exercises — replays bit-for-bit from ``(seed, workload)``.

Sites (``FaultPlan.SITES``), each consulted by `repro.serve.engine.ServeEngine`
at exactly one place in the cycle:

* ``alloc_fail`` — consulted in ``_alloc_page`` before every pool
  allocation; a firing simulates a failed allocation, which the engine
  recovers from by preempting a victim (the same path real commitment-budget
  exhaustion takes under ``reserve_policy="expected"``);
* ``forced_preempt`` — consulted once per cycle; a firing preempts the
  victim the engine's ``preempt_policy`` would choose, unprovoked;
* ``delayed_release`` — consulted at retirement; a firing holds the
  retiring request's pages out of the free list for ``delay_cycles`` engine
  cycles (modelling asynchronous device-side release) before freeing them;
* ``poison_logits`` — consulted per active request per cycle; a firing
  overwrites that request's logits row with NaN *after* the decode step,
  exercising the engine's step-level error isolation (the request retires
  ``ERRORED``; the engine loop and every other request are unaffected);
* ``evict_storm`` — consulted once per cycle (schedule phase); a firing
  force-reclaims up to ``storm_pages`` pages from the pool's RETAINED tier
  (LRU order, prefix index invalidated atomically —
  ``PagePool.reclaim_retained``), exercising retention-tier invalidation:
  a post-storm admission must fall back to a cold prefill with outputs
  bitwise unchanged.

Determinism: each site draws from its own ``numpy`` Generator seeded from
``(seed, site)``, and decisions depend only on the site's consultation
count — never on wall clock, interleaving with other sites, or dict order.
Two runs of the same workload with equal-seed plans take identical
decisions; ``FaultPlan.log`` records every firing (site, cycle, uid,
consultation index) so tests can assert the replay.

Targeted (non-random) injection: ``fire_at={"alloc_fail": (3,)}`` fires a
site at exact consultation indices, composable with rates.  ``max_fires``
caps firings per site (e.g. poison exactly one row over a whole run).

Schedule-invariant targeting: ``fire_at_token={"poison_logits":
{(uid, k)}}`` fires when the site is consulted for request ``uid`` at
decode progress ``k`` (the engine passes ``progress=len(req.out_tokens)``).
Unlike consultation indices — which depend on how many cycles ran and how
many requests were active in each — a ``(uid, progress)`` key names a point
on the *request's own* token stream, so the firing replays identically
under any scheduling: sync vs async runtime, preempted vs unpressured,
different admission interleavings.  The async-vs-sync differential suite
(tests/test_serve_async.py) relies on this to make poisoned-step outputs
bitwise comparable across runtimes.
"""
from __future__ import annotations

import numpy as np

#: the named engine sites, in consultation-stream order
SITES = ("alloc_fail", "forced_preempt", "delayed_release", "poison_logits",
         "evict_storm")


class FaultPlan:
    """A seeded, replayable schedule of injected serving faults."""

    def __init__(self, seed: int = 0, *, alloc_fail: float = 0.0,
                 forced_preempt: float = 0.0, delayed_release: float = 0.0,
                 poison_logits: float = 0.0, evict_storm: float = 0.0,
                 delay_cycles: int = 2, storm_pages: int = 4,
                 max_fires: dict | None = None, fire_at: dict | None = None,
                 fire_at_token: dict | None = None):
        """``alloc_fail``/``forced_preempt``/``delayed_release``/
        ``poison_logits``/``evict_storm`` are per-consultation firing
        probabilities in ``[0, 1]``.  ``delay_cycles`` is how long a delayed
        release parks pages; ``storm_pages`` is how many retained pages one
        ``evict_storm`` firing reclaims (LRU-first; fewer when the tier is
        shallower).  ``max_fires`` maps site → max total firings; ``fire_at``
        maps site → iterable of 0-based consultation indices that fire
        unconditionally (deterministic targeting); ``fire_at_token`` maps
        site → iterable of ``(uid, progress)`` pairs that fire when the
        site is consulted for that request at that decode progress
        (schedule-invariant targeting — see module docstring)."""
        rates = {
            "alloc_fail": alloc_fail,
            "forced_preempt": forced_preempt,
            "delayed_release": delayed_release,
            "poison_logits": poison_logits,
            "evict_storm": evict_storm,
        }
        for site, rate in rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{site} rate {rate} outside [0, 1]")
        for site in (dict(max_fires or {}) | dict(fire_at or {})
                     | dict(fire_at_token or {})):
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}")
        self.seed = seed
        self.rates = rates
        self.delay_cycles = delay_cycles
        self.storm_pages = storm_pages
        self.max_fires = dict(max_fires or {})
        self.fire_at = {
            site: frozenset(idx) for site, idx in (fire_at or {}).items()
        }
        self.fire_at_token = {
            site: frozenset((uid, int(k)) for uid, k in pairs)
            for site, pairs in (fire_at_token or {}).items()
        }
        # one independent stream per site: the decision sequence of a site
        # depends only on how many times IT was consulted
        self._rng = {
            site: np.random.default_rng(
                np.random.SeedSequence(entropy=seed, spawn_key=(i,))
            )
            for i, site in enumerate(SITES)
        }
        self._consults = {site: 0 for site in SITES}
        self._fired = {site: 0 for site in SITES}
        #: every firing, in order: {"site", "cycle", "uid", "consult"}
        self.log: list[dict] = []
        #: optional observer called as ``on_fire(site, cycle, uid)`` at each
        #: firing — the engine attaches its telemetry hook here (counting
        #: and tracing injected faults never influences the decisions)
        self.on_fire = None

    def fires(self, site: str, *, cycle: int, uid=None,
              progress: int | None = None) -> bool:
        """Consult ``site``; True when the plan injects a fault here.
        ``cycle``/``uid`` only annotate the log — they never influence a
        rate or ``fire_at`` decision (determinism by consultation count).
        ``progress`` (with ``uid``) additionally keys the schedule-invariant
        ``fire_at_token`` targets."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}")
        n = self._consults[site]
        self._consults[site] += 1
        rate = self.rates[site]
        hit = n in self.fire_at.get(site, ())
        if (not hit and progress is not None
                and (uid, progress) in self.fire_at_token.get(site, ())):
            hit = True
        if not hit and rate > 0.0:
            hit = bool(self._rng[site].random() < rate)
        if hit and self._fired[site] >= self.max_fires.get(site, np.inf):
            hit = False
        if hit:
            self._fired[site] += 1
            self.log.append(
                {"site": site, "cycle": cycle, "uid": uid, "consult": n}
            )
            if self.on_fire is not None:
                self.on_fire(site, cycle, uid)
        return hit

    def fired(self, site: str) -> int:
        """Total firings of ``site`` so far."""
        return self._fired[site]

    def consulted(self, site: str) -> int:
        """Total consultations of ``site`` so far."""
        return self._consults[site]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        active = {s: r for s, r in self.rates.items() if r} or dict(self.fire_at)
        return (f"FaultPlan(seed={self.seed}, sites={active}, "
                f"fired={sum(self._fired.values())})")
