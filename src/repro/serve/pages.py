"""Page-pool management for the paged serving engine.

Host-side twin of :class:`repro.core.qcache.PagedQuantKVCache`: the device
holds the pools + page tables, this module decides *which* pool page holds
which request's block.

Two-level accounting:

* **reservations** (admission control): when the scheduler admits a request
  it reserves the request's worst-case page count
  ``(prompt_len + max_new_tokens) // block_n`` up front.  Reservations are
  logical — no physical page moves — but they guarantee that every later
  :meth:`PagePool.alloc` during that request's decode succeeds, so steady
  state is preempt-free by construction; a request that cannot reserve stays
  WAITING (admission backpressure).
* **physical pages** (free-list + refcounts): pages are popped from the free
  list lazily — prompt blocks at prefill adoption, one page per ``block_n``
  decoded tokens just before the flush step that commits it.  ``free``
  decrements a refcount and returns the page at zero (refcounts > 1 are the
  hook for future prefix sharing via :meth:`PagePool.retain`).

Scratch-page invariant (shared with the paged residual-flush kernel): pool
pages ``[0, n_scratch)`` — one per decode slot — are never allocated.  Page
tables point unassigned entries at the owning slot's scratch page, so a
flush through an idle or not-yet-allocated entry lands in private scratch
and the kernel's per-sequence destinations stay pairwise distinct.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax.numpy as jnp
import numpy as np


class PagePool:
    """Free-list page allocator with admission reservations and refcounts."""

    def __init__(self, n_pages: int, *, n_scratch: int):
        if n_pages <= n_scratch:
            raise ValueError(
                f"n_pages={n_pages} must exceed n_scratch={n_scratch}"
            )
        self.n_pages = n_pages
        self.n_scratch = n_scratch
        self._free: deque[int] = deque(range(n_scratch, n_pages))
        self._refcount = np.zeros(n_pages, np.int32)
        self.reserved = 0  # logical admission reservations, in pages

    # ------------------------------------------------------------ capacity

    @property
    def capacity(self) -> int:
        """Allocatable pages (scratch excluded)."""
        return self.n_pages - self.n_scratch

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.capacity - self.n_free

    @property
    def occupancy(self) -> float:
        """Physically allocated fraction of the allocatable pool."""
        return self.n_used / max(1, self.capacity)

    # -------------------------------------------------------- reservations

    def reserve(self, n: int) -> bool:
        """Logically reserve ``n`` pages for an admitted request; False (and
        no state change) when the pool cannot guarantee them — the
        scheduler's backpressure signal."""
        if self.reserved + n > self.capacity:
            return False
        self.reserved += n
        return True

    def release(self, n: int) -> None:
        """Return a request's reservation (on completion/eviction)."""
        if n > self.reserved:
            raise ValueError(f"release({n}) exceeds reserved={self.reserved}")
        self.reserved -= n

    # ------------------------------------------------------ physical pages

    def alloc(self) -> int:
        """Pop a free page (refcount 1).  Guaranteed to succeed for pages
        covered by a reservation; raises if the invariant was violated."""
        if not self._free:
            raise RuntimeError(
                "page pool exhausted — alloc() outside a reservation?"
            )
        page = self._free.popleft()
        self._refcount[page] = 1
        return page

    def retain(self, page: int) -> None:
        """Add a reference to an allocated page (prefix-sharing hook)."""
        if self._refcount[page] <= 0:
            raise ValueError(f"retain of unallocated page {page}")
        self._refcount[page] += 1

    def free(self, page: int) -> None:
        """Drop one reference; the page returns to the free list at zero."""
        if self._refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self._refcount[page] -= 1
        if self._refcount[page] == 0:
            self._free.append(page)


# --------------------------------------------------------------------------
# Device-side adoption: move bucket-prefill dense caches into the pools
# --------------------------------------------------------------------------

_POOL_FIELDS = ("kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero")


def adopt_prefill(
    paged_caches: list,
    dense_caches: list,
    *,
    slot_ids: list[int],
    lengths: list[int],
    pages_per_req: list[list[int]],
    block_n: int,
) -> list:
    """Splice one bucketed prefill into the paged decode state.

    ``paged_caches`` / ``dense_caches``: the per-stack layer-stacked cache
    lists (``state["caches"]``) of the engine's paged state and of the
    just-computed dense prefill (batch = the padded bucket; request ``r``
    occupies row ``r``).  Per request: its first ``lengths[r] // block_n``
    dense packed blocks scatter into pool pages ``pages_per_req[r]``, its
    residual row and occupancy counters copy into decode slot
    ``slot_ids[r]``.  Dense blocks beyond ``pack_blocks`` (right-pad
    pollution) are not copied.  Returns the updated paged cache list; page
    tables are pushed separately (:func:`set_page_tables`).
    """
    rows, blks, pages = [], [], []
    for r, pgs in enumerate(pages_per_req):
        for j, pg in enumerate(pgs):
            rows.append(r)
            blks.append(j)
            pages.append(pg)
    sidx = jnp.asarray(slot_ids, jnp.int32)
    rrow = jnp.arange(len(slot_ids), dtype=jnp.int32)
    pack = jnp.asarray([ln // block_n for ln in lengths], jnp.int32)
    res = jnp.asarray([ln % block_n for ln in lengths], jnp.int32)

    out = []
    for pc, dc in zip(paged_caches, dense_caches):
        upd = {}
        if rows:
            ridx = jnp.asarray(rows, jnp.int32)
            bidx = jnp.asarray(blks, jnp.int32)
            pidx = jnp.asarray(pages, jnp.int32)
            for f in _POOL_FIELDS:
                pool = getattr(pc, f)
                dn = getattr(dc, f)
                # dn [L, m, H, nb, ...]; advanced idx at dims (1, 3) -> [N, L, H, ...]
                blocks = dn[:, ridx, :, bidx]
                upd[f] = pool.at[:, pidx].set(
                    jnp.moveaxis(blocks, 0, 1).astype(pool.dtype)
                )
        upd["k_res"] = pc.k_res.at[:, sidx].set(
            dc.k_res[:, rrow].astype(pc.k_res.dtype))
        upd["v_res"] = pc.v_res.at[:, sidx].set(
            dc.v_res[:, rrow].astype(pc.v_res.dtype))
        upd["pack_blocks"] = pc.pack_blocks.at[:, sidx].set(pack)
        upd["res_len"] = pc.res_len.at[:, sidx].set(res)
        out.append(dataclasses.replace(pc, **upd))
    return out


def set_page_tables(paged_caches: list, table: np.ndarray) -> list:
    """Push the host page table ([B, nb_max] int32) into every stacked paged
    cache (broadcast along the layer dims — all layers share one table)."""
    t = jnp.asarray(table, jnp.int32)
    return [
        dataclasses.replace(
            pc, page_table=jnp.broadcast_to(t, pc.page_table.shape)
        )
        for pc in paged_caches
    ]
