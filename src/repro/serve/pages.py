"""Page-pool management for the paged serving engine.

Host-side twin of :class:`repro.core.qcache.PagedQuantKVCache`: the device
holds the pools + page tables, this module decides *which* pool page holds
which request's block.

Commitment accounting (admission control + physical pages in one budget):

* every page the pool has *promised* is counted exactly once, either as a
  **reservation** (``reserved`` — pages a live request may still allocate)
  or as an **allocated page** (``n_used`` — pages on the free list's
  complement, refcounted).  :meth:`PagePool.reserve` admits a request only
  when ``n_used + reserved + n <= capacity``, and :meth:`PagePool.alloc`
  moves one unit from ``reserved`` to ``n_used`` — so every alloc a
  reservation promised is guaranteed to find a free page and steady state is
  preempt-free by construction; a request that cannot reserve stays WAITING
  (admission backpressure).
* **prefix sharing** rides the same budget without double-charging: a shared
  page (refcount > 1 via :meth:`PagePool.retain`) sits in ``n_used`` once,
  no matter how many requests hold it, and a sharer's admission reserves
  only its *private* worst case (``pages_needed - shared_read_blocks`` —
  serve/scheduler.py).  When the original owner retires first, the page
  simply stays in ``n_used`` until its last holder drops it, so the
  commitment total keeps honest count with no reservation hand-off.
  A speculative tail page (the copy-on-write candidate) is *not* discounted:
  its block index can still be flushed, so the sharer keeps one reservation
  unit to cover the COW replica.

**Retention tier** (the third page state, between "committed" and "free"):
a prefix-registered page whose last holder departs does *not* return to the
free list when a ``retainable`` predicate accepts it — it moves to a
RETAINED tier: refcount 0, no holders, off the free list, its prefix-index
chain entry still live.  Retained pages stay counted in ``n_used`` (they
physically occupy pool pages), so the commitment inequality — and with it
the covered-alloc guarantee — is unchanged.  The tier is an LRU:
:meth:`reserve` and :meth:`alloc` reclaim from its oldest end *only when
the free list cannot cover the request*, firing ``on_release`` (prefix
index invalidation) atomically before the page becomes reusable; a prefix
hit on a retained chain promotes the page back to committed via
:meth:`retain` at zero copy cost.  Reclaiming-before-failing means the
engine's preemption loop drains the retained tier before any victim is
preempted — retention can only ever *add* capacity, never steal it.

**Page-affine sharding** (``shards > 1``): the free list splits into
``shards`` contiguous page ranges, matching a pool whose leading (page)
axis is sharded across a mesh axis (``repro.dist.splitkv`` with
``page_affine=True``).  ``alloc(shard=c)`` hands out pages only from range
``c`` — the shard that owns the page-table columns the page will be
referenced from — so every page physically lives on the chip that reads
it and aggregate pool capacity scales with the mesh.  Scratch pages sit in
shard 0 (they are never read as valid data, so their placement is
arbitrary).  Retained-tier reclaim honours the same shard filter.

Physical pages move lazily through the free list — prompt blocks at prefill
adoption, one page per ``block_n`` decoded tokens just before the flush step
that commits it.  ``free`` decrements a refcount and returns the page at
zero (firing ``on_release`` so the scheduler's prefix index can forget it —
unless the page is retainable, in which case the index entry survives).

**Hardening** (every accounting breach raises at the faulting call, naming
the page and its holders, instead of silently corrupting ``committed``):

* each page records its *holders* — the owner tags passed to
  :meth:`PagePool.alloc` / :meth:`PagePool.retain` (the engine passes request
  uids) — and :meth:`PagePool.free` with an owner that is not a holder raises;
* reservations carry an optional per-owner ledger: releasing more units than
  an owner reserved (a double-release) raises naming the owner;
* a covered :meth:`PagePool.alloc` with no reservation outstanding raises
  (it would silently exceed the commitment budget another request was
  promised), and an uncovered alloc refuses to push ``committed`` past
  ``capacity``.

The invariant auditor (`repro.serve.audit`) cross-checks this state against
the page tables, the prefix index, and per-request page lists — including
the retained tier (every retained page must still be registered in the
prefix index, and is exempt from the leak check).

Scratch-page invariant (shared with the paged residual-flush kernel): pool
pages ``[0, n_scratch)`` — one per decode slot — are never allocated.  Page
tables point unassigned entries at the owning slot's scratch page, so a
flush through an idle or not-yet-allocated entry lands in private scratch
and the kernel's per-sequence destinations stay pairwise distinct.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import qcache as _qc


class PagePool:
    """Free-list page allocator with commitment accounting, refcounts, an
    LRU retained tier, and optional page-affine sharding."""

    def __init__(self, n_pages: int, *, n_scratch: int, page_bytes: int = 0,
                 metrics=None, shards: int = 1,
                 gauge_mode: str = "incremental"):
        """``page_bytes`` is the per-family byte size of one page across
        every paged layer-cache (the engine measures it from the allocated
        pools), so occupancy can be reported in bytes — a hybrid page covers
        ``n_super`` layer-caches, a dense transformer's covers ``n_layers``,
        and an MLA latent page has no V stream at all.  ``metrics`` (a
        `repro.serve.telemetry.MetricsRegistry`) keeps the pool gauges —
        pages used/reserved/committed/retained and occupancy, with high/low
        water marks — current after every accounting mutation.

        ``shards`` splits the free list into that many contiguous page
        ranges for page-affine allocation (see module docstring); scratch
        pages must fit inside shard 0.  ``gauge_mode`` is ``"incremental"``
        (cached instrument handles, only changed gauges written — the hot
        path) or ``"full"`` (every gauge recomputed and re-set through the
        registry on every mutation — the pre-retention behaviour, kept for
        the bench_serve before/after comparison)."""
        if n_pages <= n_scratch:
            raise ValueError(
                f"n_pages={n_pages} must exceed n_scratch={n_scratch}"
            )
        if shards < 1 or n_pages % shards:
            raise ValueError(
                f"n_pages={n_pages} must be a positive multiple of "
                f"shards={shards}"
            )
        if shards > 1 and n_scratch >= n_pages // shards:
            raise ValueError(
                f"n_scratch={n_scratch} must fit inside shard 0 "
                f"({n_pages // shards} pages/shard)"
            )
        if gauge_mode not in ("incremental", "full"):
            raise ValueError(f"unknown gauge_mode {gauge_mode!r}")
        self.n_pages = n_pages
        self.n_scratch = n_scratch
        self.page_bytes = page_bytes
        self.shards = shards
        self.gauge_mode = gauge_mode
        pps = n_pages // shards
        self._pages_per_shard = pps
        self._shard_free: list[deque[int]] = [
            deque(range(max(n_scratch, c * pps), (c + 1) * pps))
            for c in range(shards)
        ]
        if shards == 1:
            # back-compat alias: tests and tooling mutate ``pool._free``
            self._free = self._shard_free[0]
        self._rr = 0  # round-robin shard cursor for unpinned allocs
        self._refcount = np.zeros(n_pages, np.int32)
        self.reserved = 0  # pages promised but not yet allocated
        # RETAINED tier: page -> None, insertion-ordered (oldest first =
        # LRU eviction order).  refcount 0, no holders, not on a free list,
        # still counted in n_used, prefix-index entry still live.
        self._retained: dict[int, None] = {}
        self.reclaim_count = 0  # retained pages reclaimed (registry-free view)
        # page -> owner tags (one per reference, in acquisition order);
        # owner None is the untracked/anonymous caller (unit tests, tooling)
        self._holders: dict[int, list] = {}
        # owner -> reservation units outstanding (only owners that reserve
        # with an explicit tag are tracked; the engine tags request uids)
        self._owner_reserved: dict = {}
        # fired with the page id when a page's last reference drops and it
        # returns to the free list (prefix-index invalidation hook); for a
        # retained page this fires at *reclaim* time instead of free time
        self.on_release: Callable[[int], None] | None = None
        # retention predicate: a page whose last reference drops moves to
        # the RETAINED tier iff this returns True (the scheduler wires it
        # to PrefixIndex.is_registered when retain_prefix is on)
        self.retainable: Callable[[int], bool] | None = None
        self.metrics = metrics
        self._gauges = None
        self._gauge_last: list[float | None] = [None] * 5
        if metrics is not None:
            self._gauges = (
                metrics.gauge("pool_pages_used"),
                metrics.gauge("pool_pages_reserved"),
                metrics.gauge("pool_pages_committed"),
                metrics.gauge("pool_occupancy"),
                metrics.gauge("pool_pages_retained"),
            )
        self._update_gauges()

    def _update_gauges(self) -> None:
        """Refresh the registry gauges after an accounting mutation (the
        gauges' high-water marks record peak commitment between samples).
        ``incremental`` mode writes through cached instrument handles and
        skips gauges whose value did not change; ``full`` mode re-resolves
        every instrument by name and re-sets all of them."""
        m = self.metrics
        if m is None:
            return
        if self.gauge_mode == "full":
            m.set_gauge("pool_pages_used", self.n_used)
            m.set_gauge("pool_pages_reserved", self.reserved)
            m.set_gauge("pool_pages_committed", self.committed)
            m.set_gauge("pool_occupancy", self.occupancy)
            m.set_gauge("pool_pages_retained", self.n_retained)
            return
        vals = (float(self.n_used), float(self.reserved),
                float(self.committed), self.occupancy,
                float(self.n_retained))
        last = self._gauge_last
        for i, (g, v) in enumerate(zip(self._gauges, vals)):
            if last[i] != v:
                g.set(v)
                last[i] = v

    # ------------------------------------------------------------ capacity

    @property
    def capacity(self) -> int:
        """Allocatable pages (scratch excluded)."""
        return self.n_pages - self.n_scratch

    @property
    def n_free(self) -> int:
        return sum(len(d) for d in self._shard_free)

    @property
    def n_used(self) -> int:
        """Allocated pages — includes the retained tier (retained pages
        physically occupy pool pages and are not on any free list)."""
        return self.capacity - self.n_free

    @property
    def n_retained(self) -> int:
        """Pages in the RETAINED tier (refcount 0, index entry live)."""
        return len(self._retained)

    @property
    def committed(self) -> int:
        """Pages spoken for: allocated (shared pages count once, retained
        pages included) + reserved."""
        return self.n_used + self.reserved

    @property
    def occupancy(self) -> float:
        """Physically allocated fraction of the allocatable pool."""
        return self.n_used / max(1, self.capacity)

    @property
    def bytes_in_use(self) -> int:
        """Pool bytes behind allocated pages (per-family ``page_bytes``)."""
        return self.n_used * self.page_bytes

    # -------------------------------------------------------------- shards

    def shard_of(self, page: int) -> int:
        """Shard owning ``page`` — contiguous ranges of
        ``n_pages // shards`` pages, matching a leading-axis device
        sharding of the pools."""
        return page // self._pages_per_shard

    def shard_free(self, shard: int) -> int:
        """Free pages currently in ``shard``."""
        return len(self._shard_free[shard])

    def shard_available(self, shard: int) -> bool:
        """Whether an ``alloc(shard=shard)`` can succeed without
        preemption: a free page in the shard, or a retained page that
        reclaim can convert."""
        if self._shard_free[shard]:
            return True
        return any(self.shard_of(p) == shard for p in self._retained)

    def free_pages(self) -> list[int]:
        """All free pages across shards (audit hook; order is per-shard
        FIFO, shards concatenated)."""
        return [p for d in self._shard_free for p in d]

    # ------------------------------------------------------ retained tier

    def is_retained(self, page: int) -> bool:
        return page in self._retained

    def retained_pages(self) -> list[int]:
        """Retained pages, oldest (next-to-reclaim) first (audit hook)."""
        return list(self._retained)

    def _reclaim_retained(self, n: int, *, shard: int | None = None) -> int:
        """Evict up to ``n`` pages from the LRU-oldest end of the retained
        tier (optionally only pages in ``shard``), returning them to the
        free list.  ``on_release`` fires *before* the page is reusable, so
        the prefix index forgets the chain entry atomically — no window in
        which a lookup can hand out a page that is about to be recycled."""
        done = 0
        for page in list(self._retained):
            if done >= n:
                break
            if shard is not None and self.shard_of(page) != shard:
                continue
            del self._retained[page]
            if self.on_release is not None:
                self.on_release(page)
            self._shard_free[self.shard_of(page)].append(page)
            done += 1
        if done:
            self.reclaim_count += done
            if self.metrics is not None:
                self.metrics.inc("retained_reclaims", done)
            self._update_gauges()
        return done

    def reclaim_retained(self, n: int, *, shard: int | None = None) -> int:
        """Force-reclaim up to ``n`` retained pages (LRU order) — the
        ``evict_storm`` fault site and an operator relief valve.  Returns
        the number actually reclaimed."""
        return self._reclaim_retained(n, shard=shard)

    # -------------------------------------------------------- reservations

    def reserve(self, n: int, *, owner=None) -> bool:
        """Reserve ``n`` future allocations for an admitted request; False
        (and no state change) when the commitment budget cannot guarantee
        them — the scheduler's backpressure signal.  Retained pages are
        reclaimed (LRU-first, index invalidated) exactly as far as needed
        to fit the reservation before backpressure is declared: the
        retained tier never blocks an admission the bare pool could have
        taken.  ``owner`` (the engine passes the request uid) enters the
        per-owner ledger so a later double-``release`` is caught."""
        over = self.committed + n - self.capacity
        if over > 0 and self._retained:
            self._reclaim_retained(over)
        if self.committed + n > self.capacity:
            return False
        self.reserved += n
        if owner is not None:
            self._owner_reserved[owner] = self._owner_reserved.get(owner, 0) + n
        self._update_gauges()
        return True

    def release(self, n: int, *, owner=None) -> None:
        """Return a request's *remaining* (never-allocated) reservation on
        retirement; allocations already converted their unit via
        :meth:`alloc`.  Releasing more than ``owner`` has outstanding (a
        double-release) raises immediately."""
        if n > self.reserved:
            raise ValueError(f"release({n}) exceeds reserved={self.reserved}")
        if owner is not None:
            held = self._owner_reserved.get(owner, 0)
            if n > held:
                raise ValueError(
                    f"double release: owner {owner!r} releases {n} units but "
                    f"has {held} reserved"
                )
            if held - n:
                self._owner_reserved[owner] = held - n
            else:
                self._owner_reserved.pop(owner, None)
        self.reserved -= n
        self._update_gauges()

    def owner_reserved(self, owner) -> int:
        """Outstanding tracked reservation units of ``owner`` (audit hook)."""
        return self._owner_reserved.get(owner, 0)

    # ------------------------------------------------------ physical pages

    def _pop_free(self, shard: int | None) -> int:
        """Pop a free page — from ``shard`` when pinned (page-affine mode),
        else round-robin across shards with free pages.  Reclaims from the
        retained tier only when the relevant free list(s) are dry."""
        if shard is not None:
            if not self._shard_free[shard]:
                self._reclaim_retained(1, shard=shard)
            if not self._shard_free[shard]:
                raise RuntimeError(
                    f"page pool exhausted in shard {shard} "
                    f"(free={self.n_free} elsewhere, "
                    f"retained={self.n_retained})"
                )
            return self._shard_free[shard].popleft()
        if not any(self._shard_free):
            self._reclaim_retained(1)
        for off in range(self.shards):
            c = (self._rr + off) % self.shards
            if self._shard_free[c]:
                self._rr = (c + 1) % self.shards
                return self._shard_free[c].popleft()
        raise RuntimeError("page pool exhausted")

    def alloc(self, *, covered: bool = True, owner=None,
              shard: int | None = None) -> int:
        """Pop a free page (refcount 1, held by ``owner``).

        ``covered=True`` (the serving path) converts one reserved unit into
        an allocated one — guaranteed to succeed *globally* for pages a
        reservation promised (retained pages count as used, so
        ``reserved <= n_free + n_retained`` always holds and a dry free
        list implies a reclaimable retained page); calling it with *no*
        reservation outstanding raises (it would silently spend a unit some
        other request's ``reserve()`` was promised).  ``covered=False``
        (unit tests, tooling) allocates outside any reservation: it leaves
        ``reserved`` untouched and grows ``committed``, reclaiming retained
        pages before refusing to push past ``capacity``.

        ``shard`` (page-affine mode) pins the allocation to one shard's
        page range; a pinned alloc can exhaust that shard even while the
        pool as a whole has pages — the engine's affinity-aware preemption
        loop (`_alloc_page`) guards that case."""
        if covered:
            if not self.reserved:
                raise RuntimeError(
                    "covered alloc() with no reservation outstanding — the "
                    "unit would be stolen from the commitment budget"
                )
            if owner is not None:
                held = self._owner_reserved.get(owner, 0)
                if not held:
                    raise RuntimeError(
                        f"covered alloc() by owner {owner!r} exceeds its "
                        "reservation (0 units left)"
                    )
                if held - 1:
                    self._owner_reserved[owner] = held - 1
                else:
                    self._owner_reserved.pop(owner, None)
        else:
            if self.committed >= self.capacity and self._retained:
                self._reclaim_retained(self.committed - self.capacity + 1)
            if self.committed >= self.capacity:
                raise RuntimeError(
                    f"uncovered alloc() would over-commit the pool "
                    f"(committed={self.committed}, capacity={self.capacity})"
                )
        page = self._pop_free(shard)
        self._refcount[page] = 1
        self._holders[page] = [owner]
        if covered:
            self.reserved -= 1
        self._update_gauges()
        return page

    def retain(self, page: int, *, owner=None) -> bool:
        """Add a reference to an allocated page (prefix sharing), or
        **promote** a RETAINED page back to committed — the prefix-cache
        hit path: the page leaves the LRU, gains refcount 1 and ``owner``
        as its holder, at zero data movement and zero budget change (it
        was already in ``n_used``).  Returns True iff a promotion happened
        (the scheduler counts these as ``prefix_retained_hits``).  Retain
        of a page that is neither allocated nor retained raises."""
        if self._refcount[page] <= 0:
            if page in self._retained:
                del self._retained[page]
                self._refcount[page] = 1
                self._holders[page] = [owner]
                self._update_gauges()
                return True
            raise ValueError(f"retain of unallocated page {page}")
        self._refcount[page] += 1
        self._holders[page].append(owner)
        return False

    def refcount(self, page: int) -> int:
        """Current reference count (0 == free *or* retained). The engine's
        COW trigger: a flush destination with ``refcount > 1`` must be
        replicated first."""
        return int(self._refcount[page])

    def holders(self, page: int) -> list:
        """Owner tags currently holding ``page`` (audit/error reporting)."""
        return list(self._holders.get(page, ()))

    def free(self, page: int, *, owner=None) -> None:
        """Drop one reference.  At refcount zero the page either moves to
        the RETAINED tier (``retainable`` accepts it — its prefix-index
        entry stays live and ``on_release`` does *not* fire) or returns to
        its shard's free list (firing ``on_release``).  Freeing a scratch
        page, a page that is already free or retained, or — with an
        explicit ``owner`` — a page that owner does not hold, raises naming
        the page and its holders."""
        if page < self.n_scratch:
            raise ValueError(
                f"free of scratch page {page} (pages [0, {self.n_scratch}) "
                "are per-slot scratch and are never allocated)"
            )
        if self._refcount[page] <= 0:
            raise ValueError(f"double free of page {page} (refcount 0)")
        held = self._holders[page]
        if owner is not None and owner not in held:
            raise ValueError(
                f"free of page {page} by non-holder {owner!r} "
                f"(held by {held})"
            )
        # anonymous frees drop an anonymous reference first, else the oldest
        held.remove(owner if owner in held else
                    (None if None in held else held[0]))
        self._refcount[page] -= 1
        if self._refcount[page] == 0:
            self._holders.pop(page, None)
            if self.retainable is not None and self.retainable(page):
                # most-recently-departed = most-recently-used: insert at
                # the MRU end of the LRU order
                self._retained[page] = None
                self._update_gauges()
                return
            self._shard_free[self.shard_of(page)].append(page)
            self._update_gauges()
            if self.on_release is not None:
                self.on_release(page)


# --------------------------------------------------------------------------
# Device-side adoption: move bucket-prefill dense caches into the pools
# --------------------------------------------------------------------------

_POOL_FIELDS = _qc._PAGED_POOL_FIELDS


def adopt_prefill(
    paged_caches: list,
    dense_caches: list,
    *,
    slot_ids: list[int],
    lengths: list[int],
    pages_per_req: list[list[int]],
    block_n: int,
    base_blocks: list[int] | None = None,
) -> list:
    """Splice one bucketed prefill into the paged decode state.

    ``paged_caches`` / ``dense_caches``: the per-stack layer-stacked cache
    lists (``state["caches"]``) of the engine's paged state and of the
    just-computed dense prefill (batch = the padded bucket; request ``r``
    occupies row ``r``).  Per request: its first ``lengths[r] // block_n``
    dense packed blocks scatter into pool pages ``pages_per_req[r]``, its
    residual row and occupancy counters copy into decode slot
    ``slot_ids[r]``.  Dense blocks beyond ``pack_blocks`` (right-pad
    pollution) are not copied.

    ``base_blocks`` (prefix sharing) makes the splice *suffix-aware*: the
    dense cache holds only the divergent suffix of each prompt (a
    ``prior=``-mode prefill), whose blocks land *behind* ``base_blocks[r]``
    shared leading blocks already resident in the pools — the slot's
    ``pack_blocks`` becomes ``base_blocks[r] + lengths[r] // block_n`` while
    the copied content and residual stay pure suffix.  The engine points the
    leading page-table columns at the shared (retained) pages separately.

    In page-affine mode the engine allocates ``pages_per_req[r][j]`` from
    the shard owning table column ``base_blocks[r] + j``, so this scatter
    writes each page only on its owning chip.

    Returns the updated paged cache list; page tables are pushed separately
    (:func:`set_page_tables`).
    """
    rows, blks, pages = [], [], []
    for r, pgs in enumerate(pages_per_req):
        for j, pg in enumerate(pgs):
            rows.append(r)
            blks.append(j)
            pages.append(pg)
    base = base_blocks if base_blocks is not None else [0] * len(slot_ids)
    sidx = jnp.asarray(slot_ids, jnp.int32)
    rrow = jnp.arange(len(slot_ids), dtype=jnp.int32)
    pack = jnp.asarray(
        [b + ln // block_n for b, ln in zip(base, lengths)], jnp.int32
    )
    res = jnp.asarray([ln % block_n for ln in lengths], jnp.int32)

    out = []
    for pc, dc in zip(paged_caches, dense_caches):
        upd = {}
        if rows:
            ridx = jnp.asarray(rows, jnp.int32)
            bidx = jnp.asarray(blks, jnp.int32)
            pidx = jnp.asarray(pages, jnp.int32)
            for f in _POOL_FIELDS:
                pool = getattr(pc, f)
                if pool is None:  # shared_kv latent pools have no V side
                    continue
                dn = getattr(dc, f)
                # dn [L, m, H, nb, ...]; advanced idx at dims (1, 3) -> [N, L, H, ...]
                blocks = dn[:, ridx, :, bidx]
                upd[f] = pool.at[:, pidx].set(
                    jnp.moveaxis(blocks, 0, 1).astype(pool.dtype)
                )
        upd["k_res"] = pc.k_res.at[:, sidx].set(
            dc.k_res[:, rrow].astype(pc.k_res.dtype))
        if pc.v_res is not None:
            upd["v_res"] = pc.v_res.at[:, sidx].set(
                dc.v_res[:, rrow].astype(pc.v_res.dtype))
        upd["pack_blocks"] = pc.pack_blocks.at[:, sidx].set(pack)
        upd["res_len"] = pc.res_len.at[:, sidx].set(res)
        out.append(dataclasses.replace(pc, **upd))
    return out


def cow_pages(paged_caches: list, src: list[int], dst: list[int]) -> list:
    """Copy-on-write replication across every stacked paged cache: pool pages
    ``dst[i]`` become bitwise replicas of ``src[i]`` (all six pool fields,
    all layers — ``qcache.copy_pages``).  The engine calls this just before
    a decode flush whose destination page has refcount > 1, after repointing
    the flushing request's page-table column at ``dst``.  In page-affine
    mode ``src[i]`` and ``dst[i]`` are in the same shard by construction
    (both back the same table column), so the copy is shard-local."""
    return [_qc.copy_pages(pc, src, dst) for pc in paged_caches]


def set_page_tables(paged_caches: list, table: np.ndarray) -> list:
    """Push the host page table ([B, nb_max] int32) into every stacked paged
    cache (broadcast along the layer dims — all layers share one table)."""
    t = jnp.asarray(table, jnp.int32)
    return [
        dataclasses.replace(
            pc, page_table=jnp.broadcast_to(t, pc.page_table.shape)
        )
        for pc in paged_caches
    ]
