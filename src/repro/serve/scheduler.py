"""Continuous-batching scheduler: request lifecycle + bucketed admission.

Request lifecycle (the serving subsystem's state machine):

```
 submit()            admit()               prefill adopted        retire
WAITING ──────────► PREFILL ─────────────► DECODE ──────────────► DONE
   ▲  (slot free AND pages reservable)                │
   └──────────────── backpressure ◄───────────────────┘
        (pool cannot reserve worst-case pages          (completion frees
         -> request stays queued, FIFO)                 pages + reservation)
```

Admission is strict FIFO: the head of the waiting queue is admitted when a
decode slot is free *and* the page pool can reserve its worst-case page
count ``(prompt_len + max_new_tokens) // block_n``; if the head cannot be
admitted nothing behind it is (no starvation, deterministic order).  The
reservation makes decode-time page allocation infallible — steady state
never preempts.

Prompts admitted in the same cycle are grouped into *length buckets*
(powers of two ≥ ``min_bucket``) and right-padded to the bucket length so
each bucket is one jitted prefill call; the jit cache then keys on the
bucket length alone, so a serving lifetime compiles one prefill per bucket
instead of one per distinct prompt length.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque

import numpy as np

from repro.serve.pages import PagePool


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    # ---- lifecycle, managed by the scheduler/engine ----
    phase: Phase = Phase.WAITING
    slot: int | None = None
    pages: list = dataclasses.field(default_factory=list)
    pos: int = 0                 # cached tokens so far (host mirror)
    reserved_pages: int = 0
    arrival_s: float = 0.0       # virtual arrival time (bench offered-load)
    token_latencies_s: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        """Derived from the lifecycle phase (single source of truth)."""
        return self.phase == Phase.DONE

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    def pages_needed(self, block_n: int) -> int:
        """Worst-case committed blocks over the request's lifetime: the cache
        holds ``prompt_len + max_new_tokens`` tokens when it retires."""
        return (self.prompt_len + self.max_new_tokens) // block_n


def bucket_for(n: int, *, min_bucket: int = 16) -> int:
    """Smallest power-of-two bucket >= max(n, min_bucket)."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


class Scheduler:
    """Continuous-batching admission over a fixed slot set and a PagePool."""

    def __init__(self, *, slots: int, pool: PagePool | None, block_n: int,
                 max_seq: int, min_bucket: int = 16):
        self.slots = slots
        self.pool = pool
        self.block_n = block_n
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Request] = {}  # slot -> request
        self.stats = {
            "submitted": 0,
            "admitted": 0,
            "completed": 0,
            "backpressure_events": 0,
        }

    # ------------------------------------------------------------ queue

    def submit(self, req: Request) -> None:
        if req.prompt_len + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt_len={req.prompt_len} + "
                f"max_new_tokens={req.max_new_tokens} exceeds max_seq="
                f"{self.max_seq}"
            )
        need = req.pages_needed(self.block_n)
        if self.pool is not None and need > self.pool.capacity:
            raise ValueError(
                f"request {req.uid} needs {need} pages but the pool holds "
                f"{self.pool.capacity} — it could never be admitted"
            )
        req.phase = Phase.WAITING
        self.waiting.append(req)
        self.stats["submitted"] += 1

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if i not in self.active]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # --------------------------------------------------------- admission

    def admit(self) -> dict[int, list[Request]]:
        """Admit waiting requests (strict FIFO) into free slots while the
        pool can reserve their worst-case pages; returns the admitted
        requests grouped by prefill bucket length, in admission order."""
        free = self.free_slots()
        groups: dict[int, list[Request]] = {}
        while self.waiting and free:
            req = self.waiting[0]
            need = req.pages_needed(self.block_n)
            if self.pool is not None and not self.pool.reserve(need):
                self.stats["backpressure_events"] += 1
                break  # strict FIFO: nothing overtakes the head
            self.waiting.popleft()
            req.reserved_pages = need
            req.slot = free.pop(0)
            req.phase = Phase.PREFILL
            req.pos = 0
            self.active[req.slot] = req
            self.stats["admitted"] += 1
            bucket = bucket_for(req.prompt_len, min_bucket=self.min_bucket)
            groups.setdefault(bucket, []).append(req)
        return groups

    # -------------------------------------------------------- retirement

    def complete(self, req: Request) -> None:
        """Retire a request: free its pages (refcounted), return its
        reservation, release its slot."""
        if self.pool is not None:
            for page in req.pages:
                self.pool.free(page)
            self.pool.release(req.reserved_pages)
        req.pages = []
        req.reserved_pages = 0
        if req.slot is not None:
            self.active.pop(req.slot, None)
        req.slot = None
        req.phase = Phase.DONE
        self.stats["completed"] += 1
