"""Continuous-batching scheduler: lifecycle, bucketed admission, prefix index.

Request lifecycle (the serving subsystem's state machine):

```
 submit()            admit()               prefill adopted        retire
WAITING ──────────► PREFILL ─────────────► DECODE ──────────────► DONE
   ▲  (slot free AND pages reservable)                │
   └──────────────── backpressure ◄───────────────────┘
        (pool cannot reserve worst-case pages          (completion frees
         -> request stays queued, FIFO)                 pages + reservation)
```

Admission is strict FIFO: the head of the waiting queue is admitted when a
decode slot is free *and* the page pool can reserve its worst-case *private*
page count; if the head cannot be admitted nothing behind it is (no
starvation, deterministic order).  The reservation makes decode-time page
allocation infallible — steady state never preempts (see serve/pages.py for
the commitment accounting, and docs/SERVING.md for the invariant as amended
by sharing).

**Prefix sharing** (:class:`PrefixIndex`): prompts are hashed as a chain of
``block_n``-sized chunks under a per-model-config namespace; at admission
the longest leading run of chunks already resident in the pool maps straight
onto the donor's pages (``PagePool.retain`` — no prefill compute, no second
copy, reservation discounted by the shared read blocks).  The last shareable
index is capped at ``(prompt_len - 1) // block_n`` so at least one suffix
token is always prefilled (the engine needs its logits).  When the prompt
ends mid-block and the donor has the covering block committed with a
matching token prefix, that page is additionally adopted as a *speculative
tail* — a flush-destination placeholder that the engine copy-on-writes at
the first divergent flush (its reservation unit is kept, so COW stays inside
the preempt-free budget).  Pages register after their prefill is adopted, so
sharing takes effect from the next scheduling cycle on.

Prompts admitted in the same cycle are grouped into *length buckets*
(powers of two ≥ ``min_bucket``) over their **divergent suffix** length and
right-padded to the bucket, so each bucket is one jitted prefill call; the
jit cache then keys on the bucket length alone, and a fully-shared prompt
costs a minimal bucket instead of its full length.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from collections import deque

import numpy as np

from repro.serve.pages import PagePool


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    # ---- lifecycle, managed by the scheduler/engine ----
    phase: Phase = Phase.WAITING
    slot: int | None = None
    pages: list = dataclasses.field(default_factory=list)
    pos: int = 0                 # cached tokens so far (host mirror)
    reserved_pages: int = 0      # remaining un-allocated reservation units
    arrival_s: float = 0.0       # virtual arrival time (bench offered-load)
    token_latencies_s: list = dataclasses.field(default_factory=list)
    # ---- prefix sharing (set at admission) ----
    shared_pages: list = dataclasses.field(default_factory=list)
    spec_page: int | None = None  # speculative tail page (COW candidate)
    chain: list = dataclasses.field(default_factory=list)  # chunk digests

    @property
    def done(self) -> bool:
        """Derived from the lifecycle phase (single source of truth)."""
        return self.phase == Phase.DONE

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    def pages_needed(self, block_n: int) -> int:
        """Worst-case committed blocks over the request's lifetime: the cache
        holds ``prompt_len + max_new_tokens`` tokens when it retires."""
        return (self.prompt_len + self.max_new_tokens) // block_n

    def suffix_len(self, block_n: int) -> int:
        """Divergent-suffix tokens this request must still prefill."""
        return self.prompt_len - len(self.shared_pages) * block_n


def bucket_for(n: int, *, min_bucket: int = 16) -> int:
    """Smallest power-of-two bucket >= max(n, min_bucket)."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


class PrefixIndex:
    """Block-granular prompt-prefix index: chunk-hash chains → resident pages.

    One chain node per full ``block_n``-sized prompt chunk:
    ``digest_j = H(digest_{j-1} || tokens[j*block_n:(j+1)*block_n])`` with
    ``digest_{-1} = H(namespace)`` — the namespace folds the model-config
    fields that determine cache content (arch, kv bits/block/granularity), so
    pools of incompatible layouts never cross-match.  A node maps to the pool
    page holding that chunk's committed block; pages register once (first
    writer wins) and are forgotten when their last pool reference drops
    (``PagePool.on_release``) or when the engine is about to overwrite a
    privately-held page in place.

    Per page the index also records the chunk's token ids — the speculative
    tail lookup (:meth:`spec_tail`) needs to check that a donor block's first
    ``r`` tokens equal a new prompt's mid-block tail.
    """

    def __init__(self, namespace: str, block_n: int):
        self.block_n = block_n
        self.root = hashlib.sha1(namespace.encode()).digest()
        self._page_of: dict[bytes, int] = {}
        # page -> (digest, parent digest, chunk token ids)
        self._meta: dict[int, tuple[bytes, bytes, np.ndarray]] = {}
        self._children: dict[bytes, list[int]] = {}

    def __len__(self) -> int:
        return len(self._page_of)

    def chain(self, prompt: np.ndarray) -> list[bytes]:
        """Digest after each *full* ``block_n`` chunk of ``prompt``."""
        h = self.root
        out = []
        p = np.ascontiguousarray(prompt, dtype=np.int32)
        for j in range(len(p) // self.block_n):
            chunk = p[j * self.block_n : (j + 1) * self.block_n]
            h = hashlib.sha1(h + chunk.tobytes()).digest()
            out.append(h)
        return out

    def lookup(self, chain: list[bytes]) -> list[int]:
        """Pages for the longest leading run of resident chain nodes."""
        pages = []
        for h in chain:
            page = self._page_of.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def spec_tail(self, parent: bytes, tail: np.ndarray) -> int | None:
        """A resident page one chain step below ``parent`` whose block starts
        with ``tail`` (the new prompt's mid-block remainder) — the engine
        adopts it as the speculative flush destination (COW candidate)."""
        if not len(tail):
            return None
        tail = np.ascontiguousarray(tail, dtype=np.int32)
        for page in self._children.get(parent, ()):
            _, _, toks = self._meta[page]
            if len(toks) >= len(tail) and np.array_equal(toks[: len(tail)], tail):
                return page
        return None

    def register(self, chain: list[bytes], pages: list[int],
                 prompt: np.ndarray) -> None:
        """Make ``pages[j]`` (holding ``prompt``'s chunk ``j``) discoverable.
        Nodes already resident and pages already registered are skipped, so
        re-registering a shared prefix is a no-op."""
        p = np.ascontiguousarray(prompt, dtype=np.int32)
        parent = self.root
        for j, (h, page) in enumerate(zip(chain, pages)):
            if h not in self._page_of and page not in self._meta:
                toks = p[j * self.block_n : (j + 1) * self.block_n].copy()
                self._page_of[h] = page
                self._meta[page] = (h, parent, toks)
                self._children.setdefault(parent, []).append(page)
            parent = h

    def forget_page(self, page: int) -> None:
        """Drop a page's node (page died, or its content is about to be
        overwritten in place)."""
        meta = self._meta.pop(page, None)
        if meta is None:
            return
        digest, parent, _ = meta
        self._page_of.pop(digest, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.remove(page)
            if not kids:
                self._children.pop(parent, None)


class Scheduler:
    """Continuous-batching admission over a fixed slot set and a PagePool."""

    def __init__(self, *, slots: int, pool: PagePool | None, block_n: int,
                 max_seq: int, min_bucket: int = 16,
                 share_prefix: bool = True, spec_tail: bool = True,
                 exact_buckets: bool = False, namespace: str = "default"):
        """``exact_buckets`` groups admissions by *exact* suffix length
        instead of power-of-two buckets — required by cache families whose
        prefill cannot be right-padded (recurrent side-state absorbs pad
        tokens: HybridLM's SSM states, xLSTM; ``PagedSpec.exact_prefill``).
        Costs one prefill compile per distinct prompt length instead of per
        bucket — the documented trade-off of those families."""
        self.slots = slots
        self.pool = pool
        self.block_n = block_n
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.spec_tail = spec_tail
        self.exact_buckets = exact_buckets
        self.index: PrefixIndex | None = None
        if share_prefix and pool is not None:
            self.index = PrefixIndex(namespace, block_n)
            pool.on_release = self.index.forget_page
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Request] = {}  # slot -> request
        self.stats = {
            "submitted": 0,
            "admitted": 0,
            "completed": 0,
            "backpressure_events": 0,
            "prefix_hit_requests": 0,
            "prefix_hit_blocks": 0,
            "prefix_lookup_blocks": 0,
            "spec_tail_adoptions": 0,
        }

    # ------------------------------------------------------------ queue

    def submit(self, req: Request) -> None:
        if req.prompt_len + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt_len={req.prompt_len} + "
                f"max_new_tokens={req.max_new_tokens} exceeds max_seq="
                f"{self.max_seq}"
            )
        need = req.pages_needed(self.block_n)
        if self.pool is not None and need > self.pool.capacity:
            raise ValueError(
                f"request {req.uid} needs {need} pages but the pool holds "
                f"{self.pool.capacity} — it could never be admitted"
            )
        req.phase = Phase.WAITING
        self.waiting.append(req)
        self.stats["submitted"] += 1

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if i not in self.active]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # --------------------------------------------------------- admission

    def _match_prefix(self, req: Request):
        """Resolve the head request's shareable pages (no state change)."""
        if self.index is None:
            return [], None, []
        if not req.chain and req.prompt_len >= self.block_n:
            # memoized: a backpressured head is re-probed every cycle, but
            # the prompt (hence its digest chain) is immutable
            req.chain = self.index.chain(req.prompt)
        chain = req.chain
        cap = (req.prompt_len - 1) // self.block_n  # keep >= 1 suffix token
        shared = self.index.lookup(chain[:cap])
        spec = None
        s = len(shared)
        if (
            self.spec_tail
            and req.prompt_len % self.block_n
            and s == req.prompt_len // self.block_n
        ):
            parent = chain[s - 1] if s else self.index.root
            spec = self.index.spec_tail(
                parent, req.prompt[s * self.block_n :]
            )
        return shared, spec, chain

    def admit(self) -> dict[int, list[Request]]:
        """Admit waiting requests (strict FIFO) into free slots while the
        pool can reserve their worst-case *private* pages (shared read
        blocks are counted once pool-wide, never re-reserved); returns the
        admitted requests grouped by divergent-suffix prefill bucket length,
        in admission order."""
        free = self.free_slots()
        groups: dict[int, list[Request]] = {}
        while self.waiting and free:
            req = self.waiting[0]
            shared, spec, chain = self._match_prefix(req)
            need = req.pages_needed(self.block_n) - len(shared)
            if self.pool is not None and not self.pool.reserve(need):
                self.stats["backpressure_events"] += 1
                break  # strict FIFO: nothing overtakes the head
            self.waiting.popleft()
            if self.pool is not None:
                for page in shared:
                    self.pool.retain(page)
                if spec is not None:
                    self.pool.retain(spec)
            req.shared_pages = list(shared)
            req.spec_page = spec
            req.chain = chain
            req.pages = list(shared) + ([spec] if spec is not None else [])
            req.reserved_pages = need
            req.slot = free.pop(0)
            req.phase = Phase.PREFILL
            req.pos = 0
            self.active[req.slot] = req
            self.stats["admitted"] += 1
            if shared:
                self.stats["prefix_hit_requests"] += 1
                self.stats["prefix_hit_blocks"] += len(shared)
            if self.index is not None:
                self.stats["prefix_lookup_blocks"] += len(chain)
            if spec is not None:
                self.stats["spec_tail_adoptions"] += 1
            if self.exact_buckets:
                bucket = req.suffix_len(self.block_n)
            else:
                bucket = bucket_for(
                    req.suffix_len(self.block_n), min_bucket=self.min_bucket
                )
            groups.setdefault(bucket, []).append(req)
        return groups

    def register_prefix(self, req: Request, pages: list[int]) -> None:
        """Register a just-adopted prompt's full-block pages (shared + fresh)
        in the index — the engine calls this after adoption, so same-cycle
        admissions never observe half-written pages."""
        if self.index is not None and req.chain:
            self.index.register(req.chain, pages, req.prompt)

    def forget_page(self, page: int) -> None:
        """Engine hook: a privately-held page is about to be overwritten in
        place (its indexed content would go stale)."""
        if self.index is not None:
            self.index.forget_page(page)

    # -------------------------------------------------------- retirement

    def complete(self, req: Request) -> None:
        """Retire a request: free its pages (refcounted — shared pages
        survive until their last holder), return its remaining reservation,
        release its slot."""
        if self.pool is not None:
            for page in req.pages:
                self.pool.free(page)
            self.pool.release(req.reserved_pages)
        req.pages = []
        req.shared_pages = []
        req.spec_page = None
        req.reserved_pages = 0
        if req.slot is not None:
            self.active.pop(req.slot, None)
        req.slot = None
        req.phase = Phase.DONE
        self.stats["completed"] += 1
