"""Continuous-batching scheduler: lifecycle, bucketed admission, prefix index.

Request lifecycle (the serving subsystem's state machine):

```
 submit()            admit()               prefill adopted        retire
WAITING ──────────► PREFILL ─────────────► DECODE ──────────────► DONE
   ▲  (slot free AND pages reservable)        │       │
   │ └───────────── backpressure ◄────────────┼───────┘
   └─────────────── preempt() ◄───────────────┘  (pool alloc would fail
   │                                              mid-decode: pages freed,
   │                                              decoded tokens queued
   │                                              for replay, FIFO head
   │                                              requeue)
   └──► REJECTED (submit: never admittable)     terminal phases:
        CANCELLED (cancel(uid))                 DONE / REJECTED / CANCELLED
        EXPIRED   (deadline_s passed)           / EXPIRED / ERRORED
        ERRORED   (poisoned step, isolated)
```

Admission is strict FIFO: the head of the waiting queue is admitted when a
decode slot is free *and* the page pool can reserve its page count under the
configured ``reserve_policy``; if the head cannot be admitted nothing behind
it is (no starvation, deterministic order).

* ``reserve_policy="worst_case"`` (default) reserves the request's full
  lifetime page count — decode-time allocation is infallible and steady
  state never preempts (the PR 3 invariant, unchanged);
* ``reserve_policy="expected"`` reserves for an *expected* decode length
  (``ceil(expected_quantile * max_new_tokens)`` generated tokens, never less
  than the prompt itself needs) — the pool admits more concurrent requests
  than it could at worst case, and a request that outlives its expectation
  extends its reservation one page at a time, **preempting** a victim when
  the commitment budget is full (engine's ``_alloc_page``).  Preemption is
  recoverable by construction: the victim's pages are freed (shared pages
  survive through their other holders), re-admission re-prefills its prompt
  through the ordinary (prefix-sharing) suffix path, and its already-decoded
  tokens are **replayed teacher-forced through the decode path** — the same
  computation that built them, so the quantized cache state (and therefore
  every future token) is reconstructed *bitwise*; a prefill recompute of
  decode-built blocks would quantize differently and break greedy parity.
  See docs/SERVING.md §10 for the bounded-preemption invariant that
  replaces preempt-free.

**Prefix sharing** (:class:`PrefixIndex`): prompts are hashed as a chain of
``block_n``-sized chunks under a per-model-config namespace; at admission
the longest leading run of chunks already resident in the pool maps straight
onto the donor's pages (``PagePool.retain`` — no prefill compute, no second
copy, reservation discounted by the shared read blocks).  The last shareable
index is capped at ``(prompt_len - 1) // block_n`` so at least one suffix
token is always prefilled (the engine needs its logits).  When the prompt
ends mid-block and the donor has the covering block committed with a
matching token prefix, that page is additionally adopted as a *speculative
tail* — a flush-destination placeholder that the engine copy-on-writes at
the first divergent flush (its reservation unit is kept, so COW stays inside
the preempt-free budget).  Pages register after their prefill is adopted, so
sharing takes effect from the next scheduling cycle on.

Prompts admitted in the same cycle are grouped into *length buckets*
(powers of two ≥ ``min_bucket``) over their **divergent suffix** length and
right-padded to the bucket, so each bucket is one jitted prefill call; the
jit cache then keys on the bucket length alone, and a fully-shared prompt
costs a minimal bucket instead of its full length.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import math
import time
from collections import deque

import numpy as np

from repro.serve.pages import PagePool
from repro.serve.telemetry import MetricsRegistry


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    REJECTED = "rejected"    # never admittable (submit-time guard)
    CANCELLED = "cancelled"  # cancel(uid)
    EXPIRED = "expired"      # deadline_s passed before completion
    ERRORED = "errored"      # isolated step-level failure (poisoned row)


#: phases a request never leaves (DONE plus the failure retirements)
TERMINAL_PHASES = frozenset(
    {Phase.DONE, Phase.REJECTED, Phase.CANCELLED, Phase.EXPIRED,
     Phase.ERRORED}
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    deadline_s: float | None = None  # TTL from submit() (engine clock)
    # ---- lifecycle, managed by the scheduler/engine ----
    phase: Phase = Phase.WAITING
    slot: int | None = None
    pages: list = dataclasses.field(default_factory=list)
    pos: int = 0                 # cached tokens so far (host mirror)
    reserved_pages: int = 0      # remaining un-allocated reservation units
    arrival_s: float = 0.0       # virtual arrival time (bench offered-load)
    submitted_s: float = 0.0     # scheduler clock at submit (deadline base)
    token_latencies_s: list = dataclasses.field(default_factory=list)
    error: str | None = None     # reason for REJECTED/EXPIRED/ERRORED/...
    # ---- prefix sharing (set at admission) ----
    shared_pages: list = dataclasses.field(default_factory=list)
    spec_page: int | None = None  # speculative tail page (COW candidate)
    chain: list = dataclasses.field(default_factory=list)  # chunk digests
    # ---- preemption-by-rematerialization ----
    remat_tokens: int = 0          # cumulative tokens replayed after preempts
    replay_left: int = 0           # decoded tokens still to teacher-force
    pending_token: int | None = None  # decoded-but-unfed token at preemption
    preemptions: int = 0
    # shared-block count of the FIRST admission, frozen so rematerializing
    # re-admissions reproduce the original prefill computation exactly: a
    # victim whose prompt entered cold must re-prefill cold even if its own
    # pages now sit in the RETAINED tier (suffix-over-dequantized-prior is
    # not bitwise vs. a raw full prefill, SERVING.md §9/§14)
    orig_shared_blocks: int | None = None
    admit_seq: int = -1            # global admission order (victim policy)
    admit_cycle: int = -1          # engine cycle of the last admission
    # ---- self-speculative decoding (engine spec_k > 1, SERVING.md §11) ----
    spec_accepted: int = 0         # draft tokens accepted by verify
    spec_rejected: int = 0         # draft tokens discarded at divergence
    # ---- telemetry timestamps (real perf_counter clock, never the
    # injectable TTL clock; docs/OBSERVABILITY.md) ----
    t_submit_s: float | None = None       # submit() wall time
    t_admit_s: float | None = None        # first admission wall time
    t_first_token_s: float | None = None  # first emitted token (TTFT base)

    @property
    def done(self) -> bool:
        """Derived from the lifecycle phase (single source of truth)."""
        return self.phase == Phase.DONE

    @property
    def finished(self) -> bool:
        """True in any terminal phase (DONE or a failure retirement)."""
        return self.phase in TERMINAL_PHASES

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    def pages_needed(self, block_n: int) -> int:
        """Worst-case committed blocks over the request's lifetime: the cache
        holds ``prompt + max_new_tokens`` tokens when it retires (preemption
        does not change the total — the prompt and budget are invariant)."""
        return (self.prompt_len + self.max_new_tokens) // block_n

    def suffix_len(self, block_n: int) -> int:
        """Divergent-suffix tokens this request must still prefill."""
        return self.prompt_len - len(self.shared_pages) * block_n


def bucket_for(n: int, *, min_bucket: int = 16) -> int:
    """Smallest power-of-two bucket >= max(n, min_bucket)."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


class PrefixIndex:
    """Block-granular prompt-prefix index: chunk-hash chains → resident pages.

    One chain node per full ``block_n``-sized prompt chunk:
    ``digest_j = H(digest_{j-1} || tokens[j*block_n:(j+1)*block_n])`` with
    ``digest_{-1} = H(namespace)`` — the namespace folds the model-config
    fields that determine cache content (arch, kv bits/block/granularity), so
    pools of incompatible layouts never cross-match.  A node maps to the pool
    page holding that chunk's committed block; pages register once (first
    writer wins) and are forgotten when their last pool reference drops
    (``PagePool.on_release``) or when the engine is about to overwrite a
    privately-held page in place.

    Per page the index also records the chunk's token ids — the speculative
    tail lookup (:meth:`spec_tail`) needs to check that a donor block's first
    ``r`` tokens equal a new prompt's mid-block tail.
    """

    def __init__(self, namespace: str, block_n: int):
        self.block_n = block_n
        self.root = hashlib.sha1(namespace.encode()).digest()
        self._page_of: dict[bytes, int] = {}
        # page -> (digest, parent digest, chunk token ids)
        self._meta: dict[int, tuple[bytes, bytes, np.ndarray]] = {}
        self._children: dict[bytes, list[int]] = {}

    def __len__(self) -> int:
        return len(self._page_of)

    def chain(self, prompt: np.ndarray) -> list[bytes]:
        """Digest after each *full* ``block_n`` chunk of ``prompt``."""
        h = self.root
        out = []
        p = np.ascontiguousarray(prompt, dtype=np.int32)
        for j in range(len(p) // self.block_n):
            chunk = p[j * self.block_n : (j + 1) * self.block_n]
            h = hashlib.sha1(h + chunk.tobytes()).digest()
            out.append(h)
        return out

    def lookup(self, chain: list[bytes]) -> list[int]:
        """Pages for the longest leading run of resident chain nodes."""
        pages = []
        for h in chain:
            page = self._page_of.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def spec_tail(self, parent: bytes, tail: np.ndarray) -> int | None:
        """A resident page one chain step below ``parent`` whose block starts
        with ``tail`` (the new prompt's mid-block remainder) — the engine
        adopts it as the speculative flush destination (COW candidate)."""
        if not len(tail):
            return None
        tail = np.ascontiguousarray(tail, dtype=np.int32)
        for page in self._children.get(parent, ()):
            _, _, toks = self._meta[page]
            if len(toks) >= len(tail) and np.array_equal(toks[: len(tail)], tail):
                return page
        return None

    def register(self, chain: list[bytes], pages: list[int],
                 prompt: np.ndarray) -> None:
        """Make ``pages[j]`` (holding ``prompt``'s chunk ``j``) discoverable.
        Nodes already resident and pages already registered are skipped, so
        re-registering a shared prefix is a no-op."""
        p = np.ascontiguousarray(prompt, dtype=np.int32)
        parent = self.root
        for j, (h, page) in enumerate(zip(chain, pages)):
            if h not in self._page_of and page not in self._meta:
                toks = p[j * self.block_n : (j + 1) * self.block_n].copy()
                self._page_of[h] = page
                self._meta[page] = (h, parent, toks)
                self._children.setdefault(parent, []).append(page)
            parent = h

    def is_registered(self, page: int) -> bool:
        """Whether ``page`` holds a live chain node — the pool's
        ``retainable`` predicate: only pages the index can re-discover are
        worth keeping in the RETAINED tier."""
        return page in self._meta

    def forget_page(self, page: int) -> None:
        """Drop a page's node (page died, or its content is about to be
        overwritten in place)."""
        meta = self._meta.pop(page, None)
        if meta is None:
            return
        digest, parent, _ = meta
        self._page_of.pop(digest, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.remove(page)
            if not kids:
                self._children.pop(parent, None)


class Scheduler:
    """Continuous-batching admission over a fixed slot set and a PagePool."""

    def __init__(self, *, slots: int, pool: PagePool | None, block_n: int,
                 max_seq: int, min_bucket: int = 16,
                 share_prefix: bool = True, spec_tail: bool = True,
                 retain_prefix: bool = False,
                 exact_buckets: bool = False, namespace: str = "default",
                 reserve_policy: str = "worst_case",
                 expected_quantile: float = 0.5, strict: bool = False,
                 clock=None, metrics: MetricsRegistry | None = None):
        """``exact_buckets`` groups admissions by *exact* suffix length
        instead of power-of-two buckets — required by cache families whose
        prefill cannot be right-padded (recurrent side-state absorbs pad
        tokens: HybridLM's SSM states, xLSTM; ``PagedSpec.exact_prefill``).
        Costs one prefill compile per distinct prompt length instead of per
        bucket — the documented trade-off of those families.

        ``reserve_policy`` selects the admission reservation: ``"worst_case"``
        reserves the full lifetime page count (preempt-free steady state),
        ``"expected"`` reserves for ``expected_quantile`` of the decode
        budget and relies on the engine's preemption-by-rematerialization
        when a request outlives it.  ``strict=True`` restores the historical
        behavior of raising ``ValueError`` from :meth:`submit` for
        never-admittable requests instead of retiring them ``REJECTED``.
        ``clock`` (default ``time.monotonic``) timestamps submissions for
        per-request ``deadline_s`` enforcement.  ``metrics`` shares the
        engine's `repro.serve.telemetry.MetricsRegistry` (counters register
        under the ``sched_`` prefix; default: a private registry) — the
        ``stats`` property keeps the historical unprefixed dict view.

        ``retain_prefix`` (needs ``share_prefix``) turns on the pool's
        RETAINED tier: prefix-registered pages survive their last holder's
        departure as evictable LRU entries, and admission promotes them
        back at zero cost (counted as ``prefix_retained_hits``).  Off by
        default — with retention on, a drained engine intentionally keeps
        registered pages out of the free list."""
        if reserve_policy not in ("worst_case", "expected"):
            raise ValueError(f"unknown reserve_policy {reserve_policy!r}")
        if not 0.0 <= expected_quantile <= 1.0:
            raise ValueError(
                f"expected_quantile must be in [0, 1], got {expected_quantile}"
            )
        self.slots = slots
        self.pool = pool
        self.block_n = block_n
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.spec_tail = spec_tail
        self.exact_buckets = exact_buckets
        self.reserve_policy = reserve_policy
        self.expected_quantile = expected_quantile
        self.strict = strict
        self.clock = clock if clock is not None else time.monotonic
        self.index: PrefixIndex | None = None
        self.retain_prefix = retain_prefix and share_prefix and pool is not None
        if share_prefix and pool is not None:
            self.index = PrefixIndex(namespace, block_n)
            pool.on_release = self.index.forget_page
            if self.retain_prefix:
                pool.retainable = self.index.is_registered
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Request] = {}  # slot -> request
        self._admit_seq = 0
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        for key in self._STAT_KEYS:
            self.metrics.counter("sched_" + key)

    #: lifecycle counters (registry names carry the ``sched_`` prefix the
    #: engine historically added when folding them into ``summary()``)
    _STAT_KEYS = (
        "submitted", "admitted", "completed", "rejected",
        "backpressure_events", "prefix_hit_requests", "prefix_hit_blocks",
        "prefix_lookup_blocks", "prefix_retained_hits",
        "spec_tail_adoptions",
    )

    @property
    def stats(self) -> dict:
        """Scheduler counters as a plain unprefixed dict (the pre-telemetry
        ``stats`` interface, now a read-only registry view)."""
        return {
            k: int(self.metrics.value("sched_" + k)) for k in self._STAT_KEYS
        }

    # ------------------------------------------------------------ queue

    def reject(self, req: Request, reason: str) -> None:
        """Retire ``req`` as REJECTED with ``reason`` (or raise it under
        ``strict=True``) — the graceful path for never-admittable requests,
        so one bad submission cannot crash a serving loop."""
        if self.strict:
            raise ValueError(reason)
        req.phase = Phase.REJECTED
        req.error = reason
        self.metrics.inc("sched_rejected")

    def submit(self, req: Request) -> bool:
        """Queue ``req``; returns False (phase REJECTED, ``req.error`` set)
        when it could never be admitted: over the sequence budget, or needing
        more pages than the pool holds."""
        if req.prompt_len + req.max_new_tokens > self.max_seq:
            self.reject(
                req,
                f"request {req.uid}: prompt_len={req.prompt_len} + "
                f"max_new_tokens={req.max_new_tokens} exceeds max_seq="
                f"{self.max_seq}",
            )
            return False
        need = req.pages_needed(self.block_n)
        if self.pool is not None and need > self.pool.capacity:
            self.reject(
                req,
                f"request {req.uid} needs {need} pages but the pool holds "
                f"{self.pool.capacity} — it could never be admitted",
            )
            return False
        req.phase = Phase.WAITING
        req.submitted_s = self.clock()
        if req.t_submit_s is None:  # real clock for TTFT/queue-wait series
            req.t_submit_s = time.perf_counter()
        self.waiting.append(req)
        self.metrics.inc("sched_submitted")
        return True

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if i not in self.active]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # --------------------------------------------------------- admission

    def _match_prefix(self, req: Request):
        """Resolve the head request's shareable pages (no state change)."""
        if self.index is None:
            return [], None, []
        if not req.chain and req.prompt_len >= self.block_n:
            # memoized: a backpressured head is re-probed every cycle, but
            # the prompt (hence its digest chain) is immutable
            req.chain = self.index.chain(req.prompt)
        chain = req.chain
        cap = (req.prompt_len - 1) // self.block_n  # keep >= 1 suffix token
        if req.preemptions and req.orig_shared_blocks is not None:
            # rematerialization must replay the original admission's exact
            # prefill: never share MORE blocks than the first admission did
            # (the wider hit would swap a raw-bf16 prefill for a suffix
            # prefill over a dequantized prior — not bitwise, §9)
            cap = min(cap, req.orig_shared_blocks)
        shared = self.index.lookup(chain[:cap])
        spec = None
        s = len(shared)
        if (
            self.spec_tail
            and req.prompt_len % self.block_n
            and s == req.prompt_len // self.block_n
        ):
            parent = chain[s - 1] if s else self.index.root
            spec = self.index.spec_tail(
                parent, req.prompt[s * self.block_n :]
            )
        return shared, spec, chain

    def reserve_need(self, req: Request, n_shared: int) -> int:
        """Reservation units to admit ``req`` with ``n_shared`` shared read
        blocks already resident.  ``worst_case`` covers the full lifetime;
        ``expected`` covers ``ceil(expected_quantile * remaining_budget)``
        generated tokens — never less than the prompt itself commits at
        admission (suffix blocks must be allocatable immediately), never
        more than the worst case."""
        worst = req.pages_needed(self.block_n)
        if self.reserve_policy == "expected":
            # already-decoded tokens are certain (a preempted request will
            # replay them); only the remaining budget is discounted
            certain = len(req.out_tokens)
            remaining = req.max_new_tokens - certain
            exp_new = certain + math.ceil(self.expected_quantile * remaining)
            expected = (req.prompt_len + exp_new) // self.block_n
            # the admission itself allocates every full prompt block not
            # already shared, so the reservation can never dip below that
            worst = min(worst, max(expected, req.prompt_len // self.block_n))
        return max(worst - n_shared, 0)

    def admit(self) -> dict[int, list[Request]]:
        """Admit waiting requests (strict FIFO) into free slots while the
        pool can reserve their policy-determined *private* pages (shared
        read blocks are counted once pool-wide, never re-reserved); returns
        the admitted requests grouped by divergent-suffix prefill bucket
        length, in admission order."""
        free = self.free_slots()
        groups: dict[int, list[Request]] = {}
        while self.waiting and free:
            req = self.waiting[0]
            shared, spec, chain = self._match_prefix(req)
            need = self.reserve_need(req, len(shared))
            promoted = 0
            if self.pool is not None:
                # retain BEFORE reserving: reserve() reclaims retained
                # pages under budget pressure, and the LRU tail it would
                # evict can be exactly the chain _match_prefix resolved.
                # Promotion is budget-neutral (a retained page already
                # counts in n_used), so retain-first never turns a
                # would-have-succeeded reserve into backpressure.
                for page in shared:
                    promoted += bool(self.pool.retain(page, owner=req.uid))
                if spec is not None:
                    promoted += bool(self.pool.retain(spec, owner=req.uid))
                if not self.pool.reserve(need, owner=req.uid):
                    # retract: promoted pages fall back to RETAINED (at
                    # the MRU end — they were just touched), plain shared
                    # refs simply drop
                    for page in shared:
                        self.pool.free(page, owner=req.uid)
                    if spec is not None:
                        self.pool.free(spec, owner=req.uid)
                    self.metrics.inc("sched_backpressure_events")
                    break  # strict FIFO: nothing overtakes the head
            self.waiting.popleft()
            req.shared_pages = list(shared)
            if req.orig_shared_blocks is None:
                req.orig_shared_blocks = len(shared)
            req.spec_page = spec
            req.chain = chain
            req.pages = list(shared) + ([spec] if spec is not None else [])
            req.reserved_pages = need
            req.slot = free.pop(0)
            req.phase = Phase.PREFILL
            req.pos = 0
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.active[req.slot] = req
            self.metrics.inc("sched_admitted")
            if shared:
                self.metrics.inc("sched_prefix_hit_requests")
                self.metrics.inc("sched_prefix_hit_blocks", len(shared))
            if promoted:
                self.metrics.inc("sched_prefix_retained_hits", promoted)
            if self.index is not None:
                self.metrics.inc("sched_prefix_lookup_blocks", len(chain))
            if spec is not None:
                self.metrics.inc("sched_spec_tail_adoptions")
            if self.exact_buckets:
                bucket = req.suffix_len(self.block_n)
            else:
                bucket = bucket_for(
                    req.suffix_len(self.block_n), min_bucket=self.min_bucket
                )
            groups.setdefault(bucket, []).append(req)
        return groups

    def register_prefix(self, req: Request, pages: list[int]) -> None:
        """Register a just-adopted prompt's full-block pages (shared + fresh)
        in the index — the engine calls this after adoption, so same-cycle
        admissions never observe half-written pages."""
        if self.index is not None and req.chain:
            self.index.register(req.chain, pages, req.prompt)

    def forget_page(self, page: int) -> None:
        """Engine hook: a privately-held page is about to be overwritten in
        place (its indexed content would go stale)."""
        if self.index is not None:
            self.index.forget_page(page)

    # ------------------------------------------- retirement & preemption

    def _release_resources(self, req: Request) -> None:
        """Free pages (refcounted — shared pages survive until their last
        holder), return the remaining reservation, release the slot."""
        if self.pool is not None:
            for page in req.pages:
                self.pool.free(page, owner=req.uid)
            self.pool.release(req.reserved_pages, owner=req.uid)
        req.pages = []
        req.shared_pages = []
        req.spec_page = None
        req.reserved_pages = 0
        if req.slot is not None and self.active.get(req.slot) is req:
            self.active.pop(req.slot)
        req.slot = None

    def retire(self, req: Request, phase: Phase = Phase.DONE,
               reason: str | None = None) -> None:
        """Move ``req`` to a terminal phase, releasing everything it holds."""
        if phase not in TERMINAL_PHASES:
            raise ValueError(f"retire to non-terminal phase {phase}")
        self._release_resources(req)
        req.phase = phase
        if reason is not None:
            req.error = reason
        if phase == Phase.DONE:
            self.metrics.inc("sched_completed")

    def complete(self, req: Request) -> None:
        """Retire a request as DONE (historical alias of :meth:`retire`)."""
        self.retire(req, Phase.DONE)

    def preempt(self, req: Request, pending_token: int | None = None) -> None:
        """Preempt an active request so its pages can serve someone else,
        keeping it *recoverable by rematerialization*: re-admission
        re-prefills its (unchanged) prompt through the ordinary — prefix-
        sharing — suffix path, then replays its already-decoded tokens
        teacher-forced through the decode path (``replay_left``), which
        rebuilds the quantized cache bit-for-bit; the decoded-but-not-yet-fed
        token is parked in ``pending_token`` and restored after the replay,
        so the continuation is exactly the unpreempted token stream.  The
        request requeues at the FIFO *head* — it is older than anything
        waiting behind it."""
        self._release_resources(req)
        req.replay_left = len(req.out_tokens)
        req.remat_tokens += req.replay_left
        req.pending_token = pending_token
        req.preemptions += 1
        req.phase = Phase.WAITING
        self.waiting.appendleft(req)

    def cancel(self, uid: int) -> Request | None:
        """Cancel a waiting or active request by uid; returns the retired
        request (phase CANCELLED) or None if no live request has that uid.
        The engine wraps this to also reset the victim's page-table row."""
        for req in self.waiting:
            if req.uid == uid:
                self.waiting.remove(req)
                self.retire(req, Phase.CANCELLED, reason="cancelled")
                return req
        for req in list(self.active.values()):
            if req.uid == uid:
                self.retire(req, Phase.CANCELLED, reason="cancelled")
                return req
        return None

    def expired(self, now: float) -> list[Request]:
        """Live requests whose ``deadline_s`` (TTL from submission) has
        passed at clock reading ``now`` — the engine retires them EXPIRED."""
        live = list(self.waiting) + list(self.active.values())
        return [
            r for r in live
            if r.deadline_s is not None
            and now - r.submitted_s > r.deadline_s
        ]
