"""Shared model layers: norms, RoPE / M-RoPE, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import P

# ---------------------------------------------------------------- norms


def rmsnorm_def(d: int):
    return {"w": P((d,), ("embed",), "ones", jnp.float32)}


def rmsnorm(p, x, *, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = p["w"] + 1.0 if plus_one else p["w"]
    return (y * w).astype(x.dtype)


def layernorm_def(d: int):
    return {"w": P((d,), ("embed",), "ones", jnp.float32),
            "b": P((d,), ("embed",), "zeros", jnp.float32)}


def layernorm(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]).astype(x.dtype)


def norm_def(kind: str, d: int):
    return layernorm_def(d) if kind == "ln" else rmsnorm_def(d)


def apply_norm(kind: str, p, x, **kw):
    return layernorm(p, x) if kind == "ln" else rmsnorm(p, x, **kw)


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, *, theta: float = 10000.0, sections=None):
    """x: [B, S, H, d]; positions: [B, S] int (or [3, B, S] for M-RoPE).

    M-RoPE (Qwen2-VL §3): the rotary frequency bands are split into
    ``sections = (t, h, w)`` groups (summing to d/2); each group consumes its
    own position stream — temporal for text, (h, w) grid for image patches.
    """
    b, s, h, d = x.shape
    freqs = rope_freqs(d, theta)  # [d/2]
    if sections is None:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    else:
        assert positions.ndim == 3, "M-RoPE needs positions [3, B, S]"
        parts = []
        off = 0
        for i, sec in enumerate(sections):
            ang_i = positions[i].astype(jnp.float32)[:, :, None] * freqs[None, None, off : off + sec]
            parts.append(ang_i)
            off += sec
        ang = jnp.concatenate(parts, axis=-1)  # [B, S, d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP


def mlp_def(d: int, d_ff: int, act: str, bias: bool = False):
    defs = {}
    if act in ("swiglu", "geglu"):
        defs["wi"] = P((d, 2 * d_ff), ("embed", "mlp"))
    else:
        defs["wi"] = P((d, d_ff), ("embed", "mlp"))
    defs["wo"] = P((d_ff, d), ("mlp", "embed"))
    if bias:
        defs["bi"] = P((defs["wi"].shape[-1],), ("mlp",), "zeros", jnp.float32)
        defs["bo"] = P((d,), ("embed",), "zeros", jnp.float32)
    return defs


def mlp(p, x, act: str):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if "bi" in p:
        h = h + p["bi"].astype(h.dtype)
    if act == "swiglu":
        u, g = jnp.split(h, 2, axis=-1)
        h = u * jax.nn.silu(g)
    elif act == "geglu":
        u, g = jnp.split(h, 2, axis=-1)
        h = u * jax.nn.gelu(g)
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    out = jnp.einsum("...f,fd->...d", h, p["wo"])
    if "bo" in p:
        out = out + p["bo"].astype(out.dtype)
    return out


# ---------------------------------------------------------------- embeddings


def embed_def(vocab: int, d: int):
    return {"table": P((vocab, d), ("vocab", "embed"), "embed")}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed_def(d: int, vocab: int):
    return {"w": P((d, vocab), ("embed", "vocab"), "normal")}


def unembed(p, x, true_vocab: int | None = None):
    logits = jnp.einsum("...d,dv->...v", x, p["w"]).astype(jnp.float32)
    return mask_padded_vocab(logits, true_vocab)


def mask_padded_vocab(logits, true_vocab: int | None):
    """Mask logits of vocab-padding ids (see ArchConfig.padded_vocab)."""
    v = logits.shape[-1]
    if true_vocab is None or true_vocab == v:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, v), 1)[0]
    return jnp.where(ids[None, None, :] < true_vocab, logits, -1e30)
