"""Model-level attention block: projections + RoPE + BitDecoding cache.

Train/prefill path uses the blockwise flash attention; the decode path
appends to the QuantKVCache and runs the fused low-bit kernel through the
query transformation (core/attention.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import attention as catt
from repro.core import qcache
from repro.models import layers
from repro.models.params import P


def _hq(cfg) -> int:
    return max(cfg.n_heads, cfg.n_heads_pad or 0)


def attn_def(cfg) -> dict:
    d, hq, hkv, hd = cfg.d_model, _hq(cfg), cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": P((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((hq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.attn_bias:
        defs["bq"] = P((hq, hd), ("heads", "head_dim"), "zeros", jnp.float32)
        defs["bk"] = P((hkv, hd), ("kv_heads", "head_dim"), "zeros", jnp.float32)
        defs["bv"] = P((hkv, hd), ("kv_heads", "head_dim"), "zeros", jnp.float32)
    if cfg.qk_norm:
        defs["qnorm"] = layers.rmsnorm_def(hd)
        defs["knorm"] = layers.rmsnorm_def(hd)
    return defs


def _qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["qnorm"], q)
        k = layers.rmsnorm(p["knorm"], k)
    if cfg.rope:
        q = layers.apply_rope(q, positions, theta=cfg.rope_theta, sections=cfg.mrope_sections)
        k = layers.apply_rope(k, positions, theta=cfg.rope_theta, sections=cfg.mrope_sections)
    return q, k, v


def attn_train(p, cfg, x, positions, *, causal=True):
    """x: [B, S, d] -> [B, S, d] (flash prefill/train attention)."""
    q, k, v = _qkv(p, cfg, x, positions)
    out = catt.blockwise_attention(q, k, v, causal=causal, block_k=cfg.attn_block_k)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


def attn_prefill_cache(p, cfg, x, positions, max_seq: int, *, quant_impl="auto",
                       lengths=None, block_align=None, prior=None,
                       prior_len=None):
    """Run train attention AND build the quantized cache from the prefill K/V.

    ``lengths`` ([B] int32, optional) marks a ragged right-padded batch (the
    serve scheduler's bucketed prefill): per-sequence cache occupancy follows
    the true lengths, pad rows never become valid cache content.
    ``block_align`` rounds the cache's packed-block capacity up (mesh-aligned
    allocation for split-KV).

    ``prior`` (optional ``(k_prior, v_prior)``, each ``[B, T, H, d]``) marks a
    *suffix* prefill (prefix sharing): ``x`` holds only the divergent suffix
    tokens, whose attention also covers the first ``prior_len[b]`` prior
    tokens (dequantized shared pool pages, K already RoPE'd — see
    ``qcache.dequant_prior``).  The built cache holds suffix content only;
    the serving engine splices it behind the shared pages
    (``serve.pages.adopt_prefill(base_blocks=...)``).  Callers must pass
    suffix-global ``positions`` (``prior_len + arange``) so RoPE matches the
    unshared layout."""
    q, k, v = _qkv(p, cfg, x, positions)
    if prior is not None:
        out = catt.prefix_suffix_attention(q, k, v, *prior, prior_len)
    else:
        out = catt.blockwise_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    cache = qcache.init_cache(
        x.shape[0], cfg.n_kv_heads, cfg.head_dim, max_seq,
        bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
        block_align=block_align,
    )
    cache = qcache.prefill(
        cache, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        lengths=lengths, quant_impl=quant_impl,
    )
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"]), cache


def attn_decode(p, cfg, x, positions, cache, *, impl="auto", quant_impl="auto",
                append=True):
    """x: [B, 1, d]; appends to cache (unless attending a static cross cache)
    then runs the fused low-bit decode kernel.  ``impl`` picks the attention
    kernel, ``quant_impl`` the residual-flush kernel."""
    q, k, v = _qkv(p, cfg, x, positions)
    if append:
        out, cache = catt.decode_append_attention(
            q, cache, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            quant_impl=quant_impl, impl=impl,
        )
    else:
        out = catt.decode_attention(q, cache, impl=impl)  # [B,1,hq,hd]
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"]), cache


def cross_attn_def(cfg) -> dict:
    return attn_def(cfg)


def cross_attn_train(p, cfg, x, mem):
    """Encoder-decoder cross attention (training): full-precision."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", mem, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", mem, p["wv"])
    out = catt.blockwise_attention(q, k, v, causal=False, block_k=cfg.attn_block_k)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


def build_cross_cache(p, cfg, mem, *, quant_impl="auto"):
    """Quantize the (static) encoder K/V once — the paper's Fig. 1a offline
    case, handled by the same Residual-Kernel machinery with the tail held in
    the residual buffer and never flushed."""
    k = jnp.einsum("btd,dhk->bthk", mem, p["wk"]).transpose(0, 2, 1, 3)
    v = jnp.einsum("btd,dhk->bthk", mem, p["wv"]).transpose(0, 2, 1, 3)
    cache = qcache.init_cache(
        mem.shape[0], cfg.n_kv_heads, cfg.head_dim, mem.shape[1],
        bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
    )
    return qcache.prefill(cache, k, v, quant_impl=quant_impl)


def cross_attn_decode(p, cfg, x, cross_cache, *, impl="auto"):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = catt.decode_attention(q, cross_cache, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
