"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent gating).  Both are attention-free — no growing KV cache, so
BitDecoding is inapplicable (DESIGN.md §Arch-applicability); decode state is
O(1) in sequence length.

Training uses a stabilized sequential scan over time (chunkwise-parallel
forms exist but are a kernel-level optimization orthogonal to this paper);
the scan keeps HLO size independent of sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers
from repro.models.params import P

TIME_CHUNK = 64


def _chunked_time_scan(cell, state, xs, chunk: int = TIME_CHUNK):
    """lax.scan over time with sqrt-style remat: outer scan over chunks keeps
    only chunk-boundary states for backward; each chunk recomputes its inner
    steps (jax.checkpoint).  Without this, backprop through an S-step scan
    stores S copies of the (large) mLSTM matrix memory."""
    s = jax.tree.leaves(xs)[0].shape[0]
    nc, rem = divmod(s, chunk)
    ys_parts = []
    if nc:
        xs_main = jax.tree.map(
            lambda a: a[: nc * chunk].reshape(nc, chunk, *a.shape[1:]), xs
        )

        @jax.checkpoint
        def inner(st, xc):
            return lax.scan(cell, st, xc)

        def outer(st, xc):
            st2, ys = inner(st, xc)
            return st2, ys

        state, ys_main = lax.scan(outer, state, xs_main)
        ys_parts.append(
            jax.tree.map(lambda a: a.reshape(nc * chunk, *a.shape[2:]), ys_main)
        )
    if rem:
        xs_tail = jax.tree.map(lambda a: a[nc * chunk :], xs)
        state, ys_tail = lax.scan(cell, state, xs_tail)
        ys_parts.append(ys_tail)
    if len(ys_parts) == 1:
        return state, ys_parts[0]
    return state, jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *ys_parts)


# ------------------------------------------------------------------ mLSTM


def mlstm_def(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "wqkv": P((d, 3, h, dh), ("embed", None, "heads", "head_dim")),
        "wif": P((d, 2, h), ("embed", None, "heads"), "normal", jnp.float32),
        "bif": P((2, h), (None, "heads"), "zeros", jnp.float32),
        "wo_gate": P((d, d), ("embed", "mlp")),
        "norm": layers.rmsnorm_def(d),
        "wo": P((d, d), ("mlp", "embed")),
    }


def mlstm_init_state(cfg, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_cell(state, qkv_if):
    """One timestep of the stabilized mLSTM recurrence."""
    q, k, v, i_pre, f_pre = qkv_if  # q,k,v [B,H,dh]; i/f [B,H]
    C, n, m = state["C"], state["n"], state["m"]
    logf = -jax.nn.softplus(-f_pre)  # log sigmoid(f)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )  # [B,H,dh,dh] (v k^T)
    n = f_g[..., None] * n + i_g[..., None] * k
    hv = jnp.einsum("bhvk,bhk->bhv", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h_t = hv / denom[..., None]
    return {"C": C, "n": n, "m": m_new}, h_t


def _mlstm_inner(p, cfg, x, state):
    """x [B,S,d] -> (y [B,S,d], state).  Scan over time."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    qkv = jnp.einsum("bsd,dthk->tbshk", x, p["wqkv"]).astype(jnp.float32)
    q, k, v = qkv[0], qkv[1] / dh**0.5, qkv[2]
    gates = jnp.einsum("bsd,dgh->gbsh", x.astype(jnp.float32), p["wif"]) + p["bif"][:, None, None, :]
    i_pre, f_pre = gates[0], gates[1]

    if getattr(cfg, "xlstm_chunkwise", False) and s % cfg.xlstm_time_chunk == 0:
        y, state = mlstm_chunkwise(
            q, k, v, i_pre, f_pre, state, chunk=cfg.xlstm_time_chunk
        )
        return y.reshape(b, s, d).astype(x.dtype), state

    def step(st, inp):
        return _mlstm_cell(st, inp)

    xs = (
        q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
        i_pre.transpose(1, 0, 2), f_pre.transpose(1, 0, 2),
    )
    state, ys = _chunked_time_scan(step, state, xs, cfg.xlstm_time_chunk)  # ys [S,B,H,dh]
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    return y, state


def mlstm_block(p, cfg, x, state=None):
    """Full mLSTM mixer with output gate + norm.  state=None -> fresh."""
    if state is None:
        state = mlstm_init_state(cfg, x.shape[0])
    y, state = _mlstm_inner(p, cfg, x, state)
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wo_gate"]))
    y = layers.rmsnorm(p["norm"], y) * gate
    return jnp.einsum("bsf,fd->bsd", y, p["wo"]), state


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state, *, chunk: int):
    """Chunkwise-parallel mLSTM — mathematically EXACT vs the stabilized
    sequential cell (tests/test_xlstm_chunkwise.py), but the matrix memory
    C only materializes at chunk boundaries: per-chunk HBM traffic drops
    from L·|C| to |C| + O(L·d), turning the memory-bound recurrence into
    MXU matmuls (the SSD/GLA trick applied to mLSTM's stabilizer).

    Key identity: with F_t = Σ_{r≤t} log f_r and g_s = i_s - F_s,
      m_t = F_t + max(m_0 - 0, cummax_{s≤t} g_s)
      W_ts = exp(F_t - F_s + i_s - m_t) = exp(F_t - m_t) · exp(g_s)
    — the intra-chunk weight matrix is SEPARABLE (row x col scaling of the
    plain q·k score matrix), so everything is masked matmuls.

    q,k,v: [B,S,H,dh] (k pre-scaled by 1/sqrt(dh)); i_pre,f_pre: [B,S,H].
    state: {"C": [B,H,dh,dh], "n": [B,H,dh], "m": [B,H]}.
    Returns (h [B,S,H,dh], state').
    """
    b, s, hh, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def re(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = re(q), re(k), re(v)          # [nc,B,L,H,dh]
    ic, fc = re(i_pre), re(f_pre)             # [nc,B,L,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def one_chunk(st, xs):
        qb, kb, vb, ib, fb = xs                # [B,L,H,*]
        c0, n0, m0 = st["C"], st["n"], st["m"]
        logf = -jax.nn.softplus(-fb)           # [B,L,H]
        F = jnp.cumsum(logf, axis=1)
        g = ib - F
        m_run = jnp.maximum(jax.lax.cummax(g, axis=1), m0[:, None, :])
        m_t = F + m_run                        # [B,L,H]
        # per-pair log-weights (combined in log space so neither factor of
        # the separable form can overflow on its own)
        scores_log = (F[:, :, None, :] - m_t[:, :, None, :]) + g[:, None, :, :]
        # [B, t, s, H] log-weights; masked lower-tri
        w_ts = jnp.where(tri[None, :, :, None], jnp.exp(scores_log), 0.0)
        qk = jnp.einsum("blhd,bshd->blsh", qb.astype(jnp.float32),
                        kb.astype(jnp.float32))
        y_intra = jnp.einsum("blsh,blsh,bshd->blhd", qk, w_ts,
                             vb.astype(jnp.float32))
        decay_in = jnp.exp(F + m0[:, None, :] - m_t)  # [B,L,H]
        y_inter = decay_in[..., None] * jnp.einsum(
            "blhk,bhvk->blhv", qb.astype(jnp.float32), c0)
        n_t = decay_in[..., None] * n0[:, None] + jnp.einsum(
            "blsh,bshd->blhd", w_ts, kb.astype(jnp.float32))
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("blhd,blhd->blh", qb.astype(jnp.float32), n_t)), 1.0
        )
        h = (y_intra + y_inter) / denom[..., None]

        # chunk-end state (t = L-1)
        mL = m_t[:, -1]
        wL = jnp.exp(F[:, -1:, :] - mL[:, None] + g)     # [B,L,H] weight per s
        cL = jnp.exp(F[:, -1] + m0 - mL)[..., None, None] * c0 + jnp.einsum(
            "bshv,bshk,bsh->bhvk", vb.astype(jnp.float32),
            kb.astype(jnp.float32), wL)
        nL = jnp.exp(F[:, -1] + m0 - mL)[..., None] * n0 + jnp.einsum(
            "bshk,bsh->bhk", kb.astype(jnp.float32), wL)
        return {"C": cL, "n": nL, "m": mL}, h

    state, hs = lax.scan(one_chunk, state, (qc, kc, vc, ic, fc))
    return hs.swapaxes(0, 1).reshape(b, s, hh, dh), state


# ------------------------------------------------------------------ sLSTM


def slstm_def(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "wx": P((d, 4, h, dh), ("embed", None, "heads", "head_dim")),
        "r": P((4, h, dh, dh), (None, "heads", "head_dim", None), "normal",
              jnp.bfloat16, 0.02),  # block-diagonal hidden-hidden recurrence
        "b": P((4, h, dh), (None, "heads", "head_dim"), "zeros", jnp.float32),
        "norm": layers.rmsnorm_def(d),
        "wo": P((d, d), ("mlp", "embed")),
    }


def slstm_init_state(cfg, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = lambda: jnp.zeros((batch, h, dh), jnp.float32)  # noqa: E731
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def _slstm_cell(p, state, wx_t):
    """wx_t [B,4,H,dh] precomputed input contribution."""
    hprev = state["h"]
    rec = jnp.einsum("bhk,ghvk->bghv", hprev.astype(jnp.bfloat16), p["r"]).astype(jnp.float32)
    pre = wx_t.astype(jnp.float32) + rec.transpose(0, 1, 2, 3) + p["b"][None]
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z_t = jnp.tanh(z_pre)
    o_t = jax.nn.sigmoid(o_pre)
    logf = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + state["m"] - m_new)
    c = f_g * state["c"] + i_g * z_t
    n = f_g * state["n"] + i_g
    h_t = o_t * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h_t, "m": m_new}, h_t


def _slstm_inner(p, cfg, x, state):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    wx = jnp.einsum("bsd,dghk->bsghk", x, p["wx"]).astype(jnp.float32)

    def step(st, wx_t):
        return _slstm_cell(p, st, wx_t)

    state, ys = _chunked_time_scan(step, state, wx.transpose(1, 0, 2, 3, 4),
                                   cfg.xlstm_time_chunk)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    return y, state


def slstm_block(p, cfg, x, state=None):
    if state is None:
        state = slstm_init_state(cfg, x.shape[0])
    y, state = _slstm_inner(p, cfg, x, state)
    y = layers.rmsnorm(p["norm"], y)
    return jnp.einsum("bsf,fd->bsd", y, p["wo"]), state
