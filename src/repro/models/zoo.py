"""Model zoo: build the right backbone for an ArchConfig."""
from __future__ import annotations

from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM, HybridLM, XLSTMLM


def build_model(cfg):
    if cfg.encdec:
        return EncDecLM(cfg)
    if cfg.mixer == "mamba2":
        return HybridLM(cfg)
    if cfg.mixer == "xlstm":
        return XLSTMLM(cfg)
    return DecoderLM(cfg)
