"""Token-choice top-k Mixture-of-Experts with capacity-based group-local
dispatch.

Tokens are processed in groups (one sequence = one group by default); the
dispatch sort/positioning is *within-group* (vmapped), so under pjit with the
group dimension sharded along (pod, data) the routing math is local to a data
shard and the only cross-device movement is the dispatched activations being
resharded onto the expert-parallel (model) axis — the all-to-all pattern,
inserted by the SPMD partitioner at the sharding-constraint boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.params import P


def moe_def(cfg) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    defs = {
        "router": P((d, e), ("embed", None), "normal", jnp.float32),
        # inner expert dims use 'expert_mlp' (replicated): the expert axis
        # itself carries the model-parallel (EP) sharding
        "wi": P((e, d, 2 * f), ("experts", "embed", "expert_mlp")),
        "wo": P((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        defs["shared"] = layers.mlp_def(d, cfg.n_shared_experts * f, cfg.act)
    return defs


def _capacity(cfg, group_tokens: int) -> int:
    c = max(1, int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    # decode (1-token groups): a token's top-k experts are distinct, so
    # capacity 1 is exact — the old floor of 8 inflated decode expert
    # compute 8x.  Align to 8 sublanes only once the capacity warrants it.
    return c if c < 8 else -(-c // 8) * 8


def moe_ffn(p, cfg, x):
    """x: [B, S, d] -> [B, S, d].  Groups = sequences (B is the group dim)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if cfg.router_score == "sigmoid":  # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, k)  # [B, S, k]
    if cfg.router_norm_topk:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)

    def dispatch_one(xg, eg, wg):
        # xg [S, d], eg [S, k] expert ids, wg [S, k] weights — one group.
        flat_e = eg.reshape(-1)  # [S*k]
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [S*k, E]
        # position within the expert's capacity buffer (0-based)
        pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # [S*k]
        keep = (pos >= 0) & (pos < cap)
        src = jnp.repeat(jnp.arange(s), k)  # token index per slot
        # scatter tokens into [E, cap, d]
        xe = jnp.zeros((e, cap, d), x.dtype)
        xe = xe.at[flat_e, jnp.where(keep, pos, cap - 1)].add(
            jnp.where(keep[:, None], xg[src], 0).astype(x.dtype)
        )
        return xe, (flat_e, pos, keep, src)

    xe, meta = jax.vmap(dispatch_one)(x, top_e, top_w)  # [B, E, cap, d]

    # expert-parallel resharding boundary: dispatched tokens move onto the
    # expert (model) axis here — the all-to-all pattern — instead of letting
    # the partitioner replicate the dispatch tensors (§Perf iteration B)
    from repro.dist.sharding import constrain

    xe = constrain(xe, ("pod", "data"), "model", None, None)
    # expert FFN (SwiGLU), experts sharded on the model axis (EP)
    h = jnp.einsum("becd,edf->becf", xe, p["wi"])
    u, g = jnp.split(h, 2, axis=-1)
    h = u * jax.nn.silu(g)
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])  # [B, E, cap, d]
    ye = constrain(ye, ("pod", "data"), "model", None, None)

    def combine_one(ye_g, wg, m):
        flat_e, pos, keep, src = m
        vals = ye_g[flat_e, jnp.clip(pos, 0, cap - 1)]  # [S*k, d]
        vals = jnp.where(keep[:, None], vals, 0)
        w = wg.reshape(-1)[:, None].astype(vals.dtype)
        out = jnp.zeros((s, d), vals.dtype).at[src].add(vals * w)
        return out

    out = jax.vmap(combine_one)(ye, top_w, meta)
    if cfg.n_shared_experts:
        out = out + layers.mlp(p["shared"], x, cfg.act)
    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1, 2))
    pe = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
    aux = e * jnp.sum(me * pe)
    return out.astype(x.dtype), aux
