"""Mamba2 (SSD) mixer: chunked parallel scan for training, O(1)-state
recurrence for decode.  BitDecoding is inapplicable here (constant-size state,
no growing KV cache) — see DESIGN.md §Arch-applicability.  Structure follows
the minimal SSD formulation (Mamba2 paper, Listing 1), with a causal
depthwise conv on the xBC stream and a gated RMSNorm output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers
from repro.models.params import P

CONV_K = 4


def mamba2_def(cfg) -> dict:
    d = cfg.d_model
    di = cfg.mamba_d_inner
    h = cfg.mamba_heads
    n = cfg.ssm_state
    g = cfg.mamba_groups
    conv_dim = di + 2 * g * n
    return {
        "in_proj": P((d, 2 * di + 2 * g * n + h), ("embed", "mlp")),
        "conv_w": P((CONV_K, conv_dim), (None, "mlp"), "normal", jnp.float32, 0.2),
        "conv_b": P((conv_dim,), ("mlp",), "zeros", jnp.float32),
        "a_log": P((h,), (None,), "zeros", jnp.float32),  # A = -exp(a_log)
        "dt_bias": P((h,), (None,), "zeros", jnp.float32),
        "d_skip": P((h,), (None,), "ones", jnp.float32),
        "norm": layers.rmsnorm_def(di),
        "out_proj": P((di, d), ("mlp", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    di, g, n, h = cfg.mamba_d_inner, cfg.mamba_groups, cfg.ssm_state, cfg.mamba_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _conv_train(p, xbc):
    """Causal depthwise conv along S: xbc [B, S, C]."""
    w = p["conv_w"].astype(xbc.dtype)  # [K, C]
    pad = jnp.pad(xbc, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(CONV_K))
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _segsum(x):
    """Stable segment-sum: x [..., T] -> [..., T, T] lower-tri cumulative."""
    t = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    ss = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int):
    """Minimal SSD. x [B,S,H,P]; dt [B,S,H] (softplus'd); a_log [H];
    b, c [B,S,G,N].  Returns y [B,S,H,P]."""
    bsz, s, h, pdim = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    assert s % chunk == 0
    rep = h // g

    a = -jnp.exp(a_log)  # [H]
    da = dt * a[None, None, :]  # [B,S,H] log-decay per step
    xdt = x * dt[..., None]

    # reshape into chunks
    da_c = da.reshape(bsz, nc, chunk, h)
    x_c = xdt.reshape(bsz, nc, chunk, h, pdim)
    b_c = b.reshape(bsz, nc, chunk, g, n)
    c_c = c.reshape(bsz, nc, chunk, g, n)
    b_ch = jnp.repeat(b_c, rep, axis=3)  # [B,nc,T,H,N]
    c_ch = jnp.repeat(c_c, rep, axis=3)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(da_c.transpose(0, 1, 3, 2)))  # [B,nc,H,T,T]
    scores = jnp.einsum("bcthn,bcshn->bchts", c_ch, b_ch)  # [B,nc,H,T,S]
    y_diag = jnp.einsum("bchts,bchts,bcshp->bcthp", scores, L, x_c.transpose(0, 1, 2, 3, 4))

    # 2. chunk-final states
    decay_tail = jnp.exp(jnp.cumsum(da_c, axis=2)[:, :, -1:, :] - jnp.cumsum(da_c, axis=2))
    # decay from step t to end of chunk: [B,nc,T,H]
    states = jnp.einsum("bcthn,bcth,bcthp->bchpn", b_ch, decay_tail, x_c)

    # 3. inter-chunk recurrence over chunk states
    da_sum = jnp.sum(da_c, axis=2)  # [B,nc,H]

    def step(carry, inp):
        st, dsum = inp
        new = carry * jnp.exp(dsum)[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    st0 = jnp.zeros((bsz, h, pdim, n), jnp.float32)
    final_state, prev_states = lax.scan(
        step, st0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32), da_sum.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # 4. contribution of entering state to each position
    decay_in = jnp.exp(jnp.cumsum(da_c, axis=2))  # decay from chunk start to t
    y_off = jnp.einsum("bcthn,bchpn,bcth->bcthp", c_ch, prev_states.astype(c_ch.dtype), decay_in)

    y = (y_diag + y_off).reshape(bsz, s, h, pdim)
    return y, final_state


def _mamba2_forward(p, cfg, x):
    di, g, n, h = cfg.mamba_d_inner, cfg.mamba_groups, cfg.ssm_state, cfg.mamba_heads
    pdim = di // h
    z, xbc_raw, dt = _split_proj(cfg, jnp.einsum("bsd,df->bsf", x, p["in_proj"]))
    xbc = _conv_train(p, xbc_raw)
    xin = xbc[..., :di].reshape(*x.shape[:2], h, pdim)
    b = xbc[..., di : di + g * n].reshape(*x.shape[:2], g, n)
    c = xbc[..., di + g * n :].reshape(*x.shape[:2], g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    s = x.shape[1]
    pad = (-s) % cfg.mamba_chunk
    if pad:  # pad the tail chunk with zero-input steps (dt=0 -> identity)
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))  # noqa: E731
        xin_p, dt_p, b_p, c_p = map(zpad, (xin, dt, b, c))
    else:
        xin_p, dt_p, b_p, c_p = xin, dt, b, c
    y, final = ssd_chunked(
        xin_p.astype(jnp.float32), dt_p, p["a_log"], b_p.astype(jnp.float32),
        c_p.astype(jnp.float32), chunk=cfg.mamba_chunk,
    )
    y = y[:, :s]
    y = y + xin.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    return out, final, xbc_raw


def mamba2_train(p, cfg, x):
    """x [B,S,d] -> [B,S,d]."""
    return _mamba2_forward(p, cfg, x)[0]


def mamba2_prefill(p, cfg, x):
    """Chunked-parallel prefill returning the decode state (SSD final state +
    conv tail) — the SSM analogue of building the KV cache."""
    out, final, xbc_raw = _mamba2_forward(p, cfg, x)
    conv = xbc_raw[:, -(CONV_K - 1) :].astype(jnp.bfloat16)
    # left-pad if the prompt is shorter than the conv window
    short = CONV_K - 1 - xbc_raw.shape[1]
    if short > 0:
        conv = jnp.pad(conv, ((0, 0), (short, 0), (0, 0)))
    return out, {"ssm": final, "conv": conv}


def mamba2_init_state(cfg, batch: int):
    di, g, n, h = cfg.mamba_d_inner, cfg.mamba_groups, cfg.ssm_state, cfg.mamba_heads
    conv_dim = di + 2 * g * n
    return {
        "ssm": jnp.zeros((batch, h, di // h, n), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), jnp.bfloat16),
    }


def mamba2_decode(p, cfg, x, state):
    """x [B,1,d]; O(1) recurrent update."""
    di, g, n, h = cfg.mamba_d_inner, cfg.mamba_groups, cfg.ssm_state, cfg.mamba_heads
    pdim = di // h
    z, xbc, dt = _split_proj(cfg, jnp.einsum("bsd,df->bsf", x, p["in_proj"]))
    xbc = xbc[:, 0]  # [B, C]
    # rolling conv buffer
    hist = jnp.concatenate([state["conv"], xbc[:, None].astype(jnp.bfloat16)], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    xin = xbc_t[:, :di].reshape(-1, h, pdim)
    b = xbc_t[:, di : di + g * n].reshape(-1, g, n)
    c = xbc_t[:, di + g * n :].reshape(-1, g, n)
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1)  # [B,H,N]
    ch = jnp.repeat(c, rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtv * a[None, :])  # [B,H]
    ssm = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xin, bh, dtv
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, ch) + xin * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    return out, {"ssm": ssm, "conv": new_conv}
