"""Encoder-decoder LM (Seamless-M4T backbone).  The modality frontend is a
stub: the encoder consumes precomputed frame embeddings from input_specs().

Decode uses two BitDecoding caches per decoder layer:
  * self-attention: growing quantized cache (online Residual-Kernel path);
  * cross-attention: *static* quantized cache built once after encoding —
    the paper's offline (Fig. 1a) case, same kernels, residual never flushed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import qcache
from repro.models import attention as mattn
from repro.models import layers
from repro.models.params import init_tree, shape_tree, spec_tree, stack
from repro.models.transformer import _ce_loss, _positions_lm


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def paged_spec(self):
        """Not serveable by the engine: prefill needs encoder frame
        embeddings, which Requests don't carry (repro.models.family)."""
        return None

    def _enc_def(self):
        cfg = self.cfg
        return {
            "ln1": layers.norm_def(cfg.norm, cfg.d_model),
            "attn": mattn.attn_def(cfg),
            "ln2": layers.norm_def(cfg.norm, cfg.d_model),
            "mlp": layers.mlp_def(cfg.d_model, cfg.d_ff, cfg.act, cfg.attn_bias),
        }

    def _dec_def(self):
        d = self._enc_def()
        cfg = self.cfg
        d["ln_x"] = layers.norm_def(cfg.norm, cfg.d_model)
        d["xattn"] = mattn.cross_attn_def(cfg)
        return d

    def param_defs(self):
        cfg = self.cfg
        return {
            "embed": layers.embed_def(cfg.padded_vocab, cfg.d_model),
            "enc_norm": layers.norm_def(cfg.norm, cfg.d_model),
            "final_norm": layers.norm_def(cfg.norm, cfg.d_model),
            "unembed": layers.unembed_def(cfg.d_model, cfg.padded_vocab),
            "encoder": stack(self._enc_def(), cfg.enc_layers),
            "decoder": stack(self._dec_def(), cfg.dec_layers),
        }

    def init(self, rng):
        return init_tree(self.param_defs(), rng)

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def param_specs(self, rules):
        return spec_tree(self.param_defs(), rules)

    # ------------------------------------------------------------ encoder

    def encode(self, params, frames):
        """frames [B, T, d] (stub frontend output) -> memory [B, T, d]."""
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16)
        positions = _positions_lm(*x.shape[:2])

        def body(x, lp):
            h = layers.apply_norm(cfg.norm, lp["ln1"], x)
            x = x + mattn.attn_train(lp["attn"], cfg, h, positions, causal=False)
            h2 = layers.apply_norm(cfg.norm, lp["ln2"], x)
            return x + layers.mlp(lp["mlp"], h2, cfg.act), None

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, params["encoder"])
        return layers.apply_norm(cfg.norm, params["enc_norm"], x)

    # ------------------------------------------------------------ train

    def loss(self, params, batch):
        cfg = self.cfg
        mem = self.encode(params, batch["frames"])
        x = layers.embed(params["embed"], batch["tokens"])
        positions = _positions_lm(*x.shape[:2])

        def body(x, lp):
            h = layers.apply_norm(cfg.norm, lp["ln1"], x)
            x = x + mattn.attn_train(lp["attn"], cfg, h, positions)
            hx = layers.apply_norm(cfg.norm, lp["ln_x"], x)
            x = x + mattn.cross_attn_train(lp["xattn"], cfg, hx, mem)
            h2 = layers.apply_norm(cfg.norm, lp["ln2"], x)
            return x + layers.mlp(lp["mlp"], h2, cfg.act), None

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, params["decoder"])
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return _ce_loss(logits[:, :-1], batch["labels"][:, 1:], batch["loss_mask"][:, 1:])

    # ------------------------------------------------------------ decode

    def init_decode_state(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        self_c = qcache.init_cache(
            batch_size, cfg.n_kv_heads, cfg.head_dim, max_seq,
            bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
        )
        cross_c = qcache.init_cache(
            batch_size, cfg.n_kv_heads, cfg.head_dim, cfg.enc_len,
            bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
        )
        n = cfg.dec_layers
        return {
            "self": jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), self_c),
            "cross": jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), cross_c),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def prefill(self, params, batch, max_seq: int):
        """Encode + build static cross caches + prefill decoder self caches."""
        cfg = self.cfg
        mem = self.encode(params, batch["frames"])
        x = layers.embed(params["embed"], batch["tokens"])
        b, s = x.shape[:2]
        positions = _positions_lm(b, s)

        def body(x, lp):
            h = layers.apply_norm(cfg.norm, lp["ln1"], x)
            a, self_c = mattn.attn_prefill_cache(lp["attn"], cfg, h, positions, max_seq)
            x = x + a
            cross_c = mattn.build_cross_cache(lp["xattn"], cfg, mem)
            hx = layers.apply_norm(cfg.norm, lp["ln_x"], x)
            x = x + mattn.cross_attn_train(lp["xattn"], cfg, hx, mem)
            h2 = layers.apply_norm(cfg.norm, lp["ln2"], x)
            return x + layers.mlp(lp["mlp"], h2, cfg.act), (self_c, cross_c)

        x, (self_caches, cross_caches) = lax.scan(body, x, params["decoder"])
        x = layers.apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return logits, {
            "self": self_caches,
            "cross": cross_caches,
            "pos": jnp.full((b,), s, jnp.int32),
        }

    def decode_step(self, params, state, tokens, *, impl="auto", quant_impl="auto"):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        pos = state["pos"]
        positions = pos[:, None]

        def body(x, xs):
            lp, self_c, cross_c = xs
            h = layers.apply_norm(cfg.norm, lp["ln1"], x)
            a, self_c = mattn.attn_decode(
                lp["attn"], cfg, h, positions, self_c, impl=impl,
                quant_impl=quant_impl,
            )
            x = x + a
            hx = layers.apply_norm(cfg.norm, lp["ln_x"], x)
            x = x + mattn.cross_attn_decode(lp["xattn"], cfg, hx, cross_c, impl=impl)
            h2 = layers.apply_norm(cfg.norm, lp["ln2"], x)
            return x + layers.mlp(lp["mlp"], h2, cfg.act), self_c

        x, self_caches = lax.scan(
            body, x, (params["decoder"], state["self"], state["cross"])
        )
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return logits, dict(state, self=self_caches, pos=pos + 1)
