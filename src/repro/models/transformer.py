"""Decoder-only LM backbones with scan-over-layers.

Three backbone classes cover the assigned architecture families:

* :class:`DecoderLM`   — dense / MoE / MLA transformers (+ VLM stub front);
* :class:`HybridLM`    — Mamba2 backbone with a *shared* attention block every
                         ``attn_every`` layers (Zamba2's weight sharing: same
                         params, per-invocation KV cache);
* :class:`XLSTMLM`     — super-blocks of k mLSTM + 1 sLSTM.

All stacks store per-layer params with a leading ``layers`` axis and run
``lax.scan`` so HLO size is depth-independent; ``jax.checkpoint`` on the scan
body implements full-block remat for training.

Decode state is a plain dict pytree:
  {"caches": [per-stack stacked QuantKVCache], "ssm": ..., "pos": int32[B]}
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import qcache
from repro.models import attention as mattn
from repro.models import layers, mamba2, mla, moe, xlstm
from repro.models.family import PagedSpec
from repro.models.params import P, init_tree, shape_tree, spec_tree, stack


def _ce_loss(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _positions_lm(b, s, offset=0):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None] + offset, (b, s))


def _mrope_positions(cfg, b, s_total):
    """Stub M-RoPE position ids: image patches on a (t=0, h, w) grid, text
    continuing at offset max(grid)."""
    gh, gw = cfg.patch_grid
    p = cfg.n_patches
    idx = jnp.arange(p, dtype=jnp.int32)
    pt = jnp.zeros((p,), jnp.int32)
    ph, pw = idx // gw, idx % gw
    n_text = s_total - p
    toff = max(gh, gw)
    tpos = jnp.arange(n_text, dtype=jnp.int32) + toff
    t = jnp.concatenate([pt, tpos])
    h = jnp.concatenate([ph, tpos])
    w = jnp.concatenate([pw, tpos])
    pos = jnp.stack([t, h, w])  # [3, S]
    return jnp.broadcast_to(pos[:, None, :], (3, b, s_total))


def _mrope_decode_positions(cfg, pos):
    """pos [B] absolute index (incl. patch slots); text stream continues at
    offset max(grid) after the patch grid, matching _mrope_positions."""
    t = pos - cfg.n_patches + max(cfg.patch_grid)
    return jnp.broadcast_to(t[None, :, None], (3, pos.shape[0], 1))


class DecoderLM:
    """Dense / MoE / MLA decoder-only LM (optionally with VLM patch stub)."""

    def __init__(self, cfg):
        self.cfg = cfg
        if cfg.n_experts:
            fd = cfg.first_dense_layers
            self.stacks = ([("mlp", fd)] if fd else []) + [("moe", cfg.n_layers - fd)]
        elif cfg.d_ff:
            self.stacks = [("mlp", cfg.n_layers)]
        else:
            self.stacks = [("none", cfg.n_layers)]

    # ------------------------------------------------------------ params

    def _block_def(self, kind):
        cfg = self.cfg
        d = {"ln1": layers.norm_def(cfg.norm, cfg.d_model)}
        if cfg.mixer == "mla":
            d["attn"] = mla.mla_def(cfg)
        else:
            d["attn"] = mattn.attn_def(cfg)
        if kind == "mlp":
            d["mlp"] = layers.mlp_def(cfg.d_model, cfg.d_ff, cfg.act, cfg.attn_bias)
        elif kind == "moe":
            d["moe"] = moe.moe_def(cfg)
        if kind != "none" and not cfg.parallel_residual:
            d["ln2"] = layers.norm_def(cfg.norm, cfg.d_model)
        return d

    def param_defs(self):
        cfg = self.cfg
        defs: dict[str, Any] = {
            "embed": layers.embed_def(cfg.padded_vocab, cfg.d_model),
            "final_norm": layers.norm_def(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            defs["unembed"] = layers.unembed_def(cfg.d_model, cfg.padded_vocab)
        for i, (kind, n) in enumerate(self.stacks):
            defs[f"stack_{i}"] = stack(self._block_def(kind), n)
        if cfg.mtp:
            defs["mtp"] = {
                "norm": layers.norm_def(cfg.norm, cfg.d_model),
                "proj": P((cfg.d_model, cfg.d_model), ("embed", "mlp")),
            }
        return defs

    def init(self, rng):
        return init_tree(self.param_defs(), rng)

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def param_specs(self, rules):
        return spec_tree(self.param_defs(), rules)

    # ------------------------------------------------------------ embedding

    def _embed(self, params, batch):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"])
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        if cfg.vision_stub:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        b, s = x.shape[0], x.shape[1]
        if cfg.mrope_sections:
            positions = _mrope_positions(cfg, b, s)
        else:
            positions = _positions_lm(b, s)
        return x, positions

    def _logits(self, params, x):
        cfg = self.cfg
        x = layers.apply_norm(cfg.norm, params["final_norm"], x, plus_one=cfg.rms_plus_one)
        if cfg.tie_embeddings:
            logits = jnp.einsum(
                "bsd,vd->bsv", x, params["embed"]["table"]
            ).astype(jnp.float32)
            return layers.mask_padded_vocab(logits, cfg.vocab)
        return layers.unembed(params["unembed"], x, cfg.vocab)

    # ------------------------------------------------------------ blocks

    def _mixer_train(self, p, x, positions):
        cfg = self.cfg
        if cfg.mixer == "mla":
            return mla.mla_train(p, cfg, x, positions)
        return mattn.attn_train(p, cfg, x, positions)

    def _block_train(self, p, kind, x, positions):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        h = layers.apply_norm(cfg.norm, p["ln1"], x, plus_one=cfg.rms_plus_one)
        if cfg.parallel_residual:
            a = self._mixer_train(p["attn"], h, positions)
            f = layers.mlp(p["mlp"], h, cfg.act) if kind == "mlp" else 0.0
            return x + a + f, aux
        x = x + self._mixer_train(p["attn"], h, positions)
        if kind != "none":
            h2 = layers.apply_norm(cfg.norm, p["ln2"], x, plus_one=cfg.rms_plus_one)
            if kind == "moe":
                f, aux = moe.moe_ffn(p["moe"], cfg, h2)
            else:
                f = layers.mlp(p["mlp"], h2, cfg.act)
            x = x + f
        return x, aux

    def _run_stacks_train(self, params, x, positions):
        cfg = self.cfg
        aux_total = jnp.float32(0.0)

        for i, (kind, _) in enumerate(self.stacks):
            def body(carry, lp, _kind=kind):
                x, aux = carry
                x, a = self._block_train(lp, _kind, x, positions)
                return (x, aux + a), None

            if cfg.remat == "full":
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_total), _ = lax.scan(body, (x, aux_total), params[f"stack_{i}"])
        return x, aux_total

    # ------------------------------------------------------------ train

    def loss(self, params, batch):
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        x, aux = self._run_stacks_train(params, x, positions)
        logits = self._logits(params, x)
        if cfg.vision_stub:  # logits over text region only
            logits = logits[:, cfg.n_patches :]
        loss = _ce_loss(logits[:, :-1], batch["labels"][:, 1:], batch["loss_mask"][:, 1:])
        if cfg.mtp:  # simplified multi-token-prediction head: predict t+2
            h = layers.apply_norm(cfg.norm, params["mtp"]["norm"], x)
            h = jnp.einsum("bsd,df->bsf", h, params["mtp"]["proj"])
            logits2 = self._logits(params, h)
            if cfg.vision_stub:
                logits2 = logits2[:, cfg.n_patches :]
            loss = loss + 0.3 * _ce_loss(
                logits2[:, :-2], batch["labels"][:, 2:], batch["loss_mask"][:, 2:]
            )
        if cfg.n_experts:
            loss = loss + cfg.aux_loss_weight * aux / cfg.n_layers
        return loss

    # ------------------------------------------------------------ prefill

    def _block_prefill(self, p, kind, x, positions, max_seq, lengths=None,
                       block_align=None, prior=None, prior_len=None):
        cfg = self.cfg
        h = layers.apply_norm(cfg.norm, p["ln1"], x, plus_one=cfg.rms_plus_one)
        if cfg.mixer == "mla":
            a, cache = mla.mla_prefill_cache(
                p["attn"], cfg, h, positions, max_seq, lengths=lengths,
                block_align=block_align, prior=prior, prior_len=prior_len,
            )
        else:
            a, cache = mattn.attn_prefill_cache(
                p["attn"], cfg, h, positions, max_seq, lengths=lengths,
                block_align=block_align, prior=prior, prior_len=prior_len,
            )
        if cfg.parallel_residual:
            f = layers.mlp(p["mlp"], h, cfg.act) if kind == "mlp" else 0.0
            return x + a + f, cache
        x = x + a
        if kind != "none":
            h2 = layers.apply_norm(cfg.norm, p["ln2"], x, plus_one=cfg.rms_plus_one)
            f = moe.moe_ffn(p["moe"], cfg, h2)[0] if kind == "moe" else layers.mlp(p["mlp"], h2, cfg.act)
            x = x + f
        return x, cache

    def prefill(self, params, batch, max_seq: int, *, lengths=None,
                block_align=None, prior=None, prior_len=None):
        """Process the prompt, build quantized caches, return (last_logits, state).

        ``lengths`` ([B] int32, optional): the batch is ragged — same-bucket
        prompts right-padded to a common static length (the serve
        scheduler's bucketed prefill).  Causality keeps real tokens blind to
        the right-pad, per-sequence cache occupancy follows the true lengths
        (``qcache.prefill``), and the returned logits are gathered at each
        sequence's last *real* token instead of the padded tail.
        ``block_align`` propagates mesh-aligned block allocation (split-KV).

        ``prior`` / ``prior_len`` turn this into a *suffix* prefill (prefix
        sharing, serve engine): ``batch["tokens"]`` holds only the divergent
        suffix of each prompt; ``prior`` is a per-stack list of
        ``(k_prior, v_prior)`` pairs (``[layers, B, T, H, d]``, dequantized
        shared pool pages) whose first ``prior_len[b]`` tokens the suffix
        attends through :func:`~repro.core.attention.prefix_suffix_attention`.
        For MLA stacks the prior is the latent stream itself
        (``(lat, None)`` from a shared_kv paged cache) and each layer expands
        it through its own up-projections (``mla.mla_prefill_cache``).
        Token positions (RoPE) are offset by ``prior_len`` so the suffix lands
        at its unshared global positions; the returned caches hold *suffix*
        content only and ``pos`` counts ``prior_len + lengths``.  Requires a
        token-only front (no vision / M-RoPE).
        """
        cfg = self.cfg
        if prior is not None:
            if cfg.vision_stub or cfg.mrope_sections:
                raise ValueError(
                    "suffix prefill (prior=) requires a token-only front "
                    "(no vision/M-RoPE)"
                )
            if lengths is None or prior_len is None:
                raise ValueError("suffix prefill needs lengths and prior_len")
        x, positions = self._embed(params, batch)
        if prior is not None:
            positions = prior_len[:, None] + jnp.arange(
                x.shape[1], dtype=jnp.int32
            )[None]
        n_lead = cfg.n_patches if cfg.vision_stub else 0  # patch prefix offset
        cache_lengths = None if lengths is None else lengths + n_lead
        caches = []
        for i, (kind, _) in enumerate(self.stacks):
            if prior is None:
                def body(x, lp, _kind=kind):
                    x, cache = self._block_prefill(
                        lp, _kind, x, positions, max_seq, cache_lengths,
                        block_align
                    )
                    return x, cache

                x, cache_stack = lax.scan(body, x, params[f"stack_{i}"])
            elif prior[i][1] is None:  # latent prior (MLA shared_kv pools)
                def body_l(x, xs, _kind=kind):
                    lp, kp = xs
                    x, cache = self._block_prefill(
                        lp, _kind, x, positions, max_seq, cache_lengths,
                        block_align, prior=(kp, None), prior_len=prior_len,
                    )
                    return x, cache

                x, cache_stack = lax.scan(
                    body_l, x, (params[f"stack_{i}"], prior[i][0])
                )
            else:
                def body_p(x, xs, _kind=kind):
                    lp, kp, vp = xs
                    x, cache = self._block_prefill(
                        lp, _kind, x, positions, max_seq, cache_lengths,
                        block_align, prior=(kp, vp), prior_len=prior_len,
                    )
                    return x, cache

                kp_i, vp_i = prior[i]
                x, cache_stack = lax.scan(
                    body_p, x, (params[f"stack_{i}"], kp_i, vp_i)
                )
            caches.append(cache_stack)
        if lengths is None:
            logits = self._logits(params, x[:, -1:])
            pos = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
        else:
            last = jnp.clip(n_lead + lengths - 1, 0, x.shape[1] - 1)
            x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
            logits = self._logits(params, x_last)
            pos = (n_lead + lengths).astype(jnp.int32)
            if prior_len is not None:
                pos = pos + prior_len.astype(jnp.int32)
        state = {"caches": caches, "pos": pos}
        return logits, state

    # ------------------------------------------------------------ decode

    def init_decode_state(self, batch_size: int, max_seq: int, *, mesh=None,
                          splitkv_axis: str = "data"):
        """Dense decode state.  When a ``mesh`` is given, the packed-block
        capacity is rounded up to the ``splitkv_axis`` size so
        ``dist.splitkv`` shards the block axis pad-free (mesh-aligned cache
        allocation — otherwise the per-call zero-pad copies the whole cache
        every decoded token at ``nb % axis_size != 0`` shapes)."""
        cfg = self.cfg
        align = qcache.splitkv_block_align(mesh, splitkv_axis)
        caches = []
        for kind, n in self.stacks:
            if cfg.mixer == "mla":
                one = mla.mla_init_cache(cfg, batch_size, max_seq, block_align=align)
            else:
                one = qcache.init_cache(
                    batch_size, cfg.n_kv_heads, cfg.head_dim, max_seq,
                    bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
                    block_align=align,
                )
            caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), one))
        return {
            "caches": caches,
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def paged_spec(self) -> PagedSpec | None:
        """Declared cache family (see repro.models.family).  Plain attention
        and MLA both page; token-plus-patch fronts (VLM stub, M-RoPE) return
        ``None`` — the serving engine cannot feed their prefill."""
        cfg = self.cfg
        if cfg.vision_stub or cfg.mrope_sections:
            return None
        n_layers = sum(n for _, n in self.stacks)
        if cfg.mixer == "mla":
            return PagedSpec(
                paged=True, block_n=cfg.kv_block, n_kv_heads=1,
                d_k=cfg.kv_lora + cfg.qk_rope, d_v=cfg.kv_lora,
                shared_kv=True, page_layers=n_layers, supports_prior=True,
            )
        if cfg.mixer == "attn":
            return PagedSpec(
                paged=True, block_n=cfg.kv_block, n_kv_heads=cfg.n_kv_heads,
                d_k=cfg.head_dim, d_v=cfg.head_dim,
                page_layers=n_layers, supports_prior=True,
            )
        return None

    def init_paged_decode_state(self, batch_size: int, *, n_pages: int,
                                nb_max: int):
        """Paged decode state for the serving engine: per-stack
        :class:`~repro.core.qcache.PagedQuantKVCache` pools (stacked along
        layers, page tables managed host-side by serve/pages.py).  MLA stacks
        allocate the shared_kv latent pool layout
        (``mla.mla_init_paged_cache``); both families decode through
        ``kernels/paged_bitdecode``."""
        cfg = self.cfg
        spec = self.paged_spec()
        if spec is None or not spec.paged:
            raise ValueError(
                f"no paged decode path for mixer={cfg.mixer!r} with this "
                "front (see DecoderLM.paged_spec)"
            )
        caches = []
        for kind, n in self.stacks:
            if cfg.mixer == "mla":
                one = mla.mla_init_paged_cache(cfg, n_pages, batch_size, nb_max)
            else:
                one = qcache.init_paged_cache(
                    n_pages, batch_size, cfg.n_kv_heads, cfg.head_dim, nb_max,
                    bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
                )
            caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), one))
        return {
            "caches": caches,
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def _block_decode(self, p, kind, x, positions, cache, impl, quant_impl):
        cfg = self.cfg
        h = layers.apply_norm(cfg.norm, p["ln1"], x, plus_one=cfg.rms_plus_one)
        if cfg.mixer == "mla":
            a, cache = mla.mla_decode(
                p["attn"], cfg, h, positions, cache, impl=impl,
                quant_impl=quant_impl,
            )
        else:
            a, cache = mattn.attn_decode(
                p["attn"], cfg, h, positions, cache, impl=impl,
                quant_impl=quant_impl,
            )
        if cfg.parallel_residual:
            f = layers.mlp(p["mlp"], h, cfg.act) if kind == "mlp" else 0.0
            return x + a + f, cache
        x = x + a
        if kind != "none":
            h2 = layers.apply_norm(cfg.norm, p["ln2"], x, plus_one=cfg.rms_plus_one)
            f = moe.moe_ffn(p["moe"], cfg, h2)[0] if kind == "moe" else layers.mlp(p["mlp"], h2, cfg.act)
            x = x + f
        return x, cache

    def decode_step(self, params, state, tokens, *, impl="auto", quant_impl="auto"):
        """tokens [B, 1] -> (logits [B,1,V], new state)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        pos = state["pos"]
        if cfg.mrope_sections:
            positions = _mrope_decode_positions(cfg, pos)
        else:
            positions = pos[:, None]
        new_caches = []
        for i, (kind, _) in enumerate(self.stacks):
            def body(x, xs, _kind=kind):
                lp, cache = xs
                x, cache = self._block_decode(
                    lp, _kind, x, positions, cache, impl, quant_impl
                )
                return x, cache

            x, cache_stack = lax.scan(body, x, (params[f"stack_{i}"], state["caches"][i]))
            new_caches.append(cache_stack)
        logits = self._logits(params, x)
        return logits, {"caches": new_caches, "pos": pos + 1}


class HybridLM:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block.

    Layout: n_super super-blocks of (attn_every mamba layers + 1 invocation of
    the SHARED attention+MLP block), plus a tail of leftover mamba layers.
    The shared block has one set of weights but a separate KV cache per
    invocation — BitDecoding applies to those caches.
    """

    def __init__(self, cfg):
        self.cfg = cfg
        self.n_super = cfg.n_layers // cfg.attn_every
        self.tail = cfg.n_layers - self.n_super * cfg.attn_every

    def _mamba_def(self):
        cfg = self.cfg
        return {
            "ln": layers.norm_def(cfg.norm, cfg.d_model),
            "mixer": mamba2.mamba2_def(cfg),
        }

    def _shared_def(self):
        cfg = self.cfg
        return {
            "ln1": layers.norm_def(cfg.norm, cfg.d_model),
            "attn": mattn.attn_def(cfg),
            "ln2": layers.norm_def(cfg.norm, cfg.d_model),
            "mlp": layers.mlp_def(cfg.d_model, cfg.d_ff, cfg.act),
        }

    def param_defs(self):
        cfg = self.cfg
        defs = {
            "embed": layers.embed_def(cfg.padded_vocab, cfg.d_model),
            "final_norm": layers.norm_def(cfg.norm, cfg.d_model),
            "unembed": layers.unembed_def(cfg.d_model, cfg.padded_vocab),
            "shared_attn": self._shared_def(),
            "main": stack(stack(self._mamba_def(), cfg.attn_every, "inner"), self.n_super),
        }
        if self.tail:
            defs["tail"] = stack(self._mamba_def(), self.tail)
        return defs

    def init(self, rng):
        return init_tree(self.param_defs(), rng)

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def param_specs(self, rules):
        return spec_tree(self.param_defs(), rules)

    def _mamba_train(self, p, x):
        cfg = self.cfg
        return x + mamba2.mamba2_train(
            p["mixer"], cfg, layers.apply_norm(cfg.norm, p["ln"], x)
        )

    def _shared_train(self, p, x, positions):
        cfg = self.cfg
        x = x + mattn.attn_train(
            p["attn"], cfg, layers.apply_norm(cfg.norm, p["ln1"], x), positions
        )
        return x + layers.mlp(p["mlp"], layers.apply_norm(cfg.norm, p["ln2"], x), cfg.act)

    def loss(self, params, batch):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"])
        positions = _positions_lm(*x.shape[:2])
        shared = params["shared_attn"]

        def super_body(x, group):
            def inner(x, lp):
                return self._mamba_train(lp, x), None

            x, _ = lax.scan(inner, x, group)
            x = self._shared_train(shared, x, positions)
            return x, None

        body = super_body
        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, params["main"])
        if self.tail:
            def tail_body(x, lp):
                return self._mamba_train(lp, x), None
            x, _ = lax.scan(tail_body, x, params["tail"])
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return _ce_loss(logits[:, :-1], batch["labels"][:, 1:], batch["loss_mask"][:, 1:])

    def paged_spec(self) -> PagedSpec:
        """Mixed cache family: the shared attention block's caches (one per
        super-block invocation) page; the Mamba2 recurrent states are
        constant-size per-slot ``side_state`` the engine splices at admission
        and that carry no page-table work (asserted by the jaxpr proof in
        tests/test_serve_families.py).  ``exact_prefill``: the recurrent
        states would absorb right-padding, so prompts prefill at exact
        lengths; prefix sharing would additionally need prefix SSM states
        cached per page, which pages don't hold — ``supports_prior=False``."""
        cfg = self.cfg
        side = (("ssm_main", 2),) + ((("ssm_tail", 1),) if self.tail else ())
        return PagedSpec(
            paged=True, block_n=cfg.kv_block, n_kv_heads=cfg.n_kv_heads,
            d_k=cfg.head_dim, d_v=cfg.head_dim, page_layers=self.n_super,
            side_state=side, exact_prefill=True, supports_prior=False,
        )

    def _side_states(self, batch_size: int):
        cfg = self.cfg
        one_m = mamba2.mamba2_init_state(cfg, batch_size)
        st = {
            "ssm_main": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_super, cfg.attn_every, *a.shape)), one_m
            ),
        }
        if self.tail:
            st["ssm_tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.tail, *a.shape)), one_m
            )
        return st

    def init_decode_state(self, batch_size: int, max_seq: int, *, mesh=None,
                          splitkv_axis: str = "data"):
        cfg = self.cfg
        cache = qcache.init_cache(
            batch_size, cfg.n_kv_heads, cfg.head_dim, max_seq,
            bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
            block_align=qcache.splitkv_block_align(mesh, splitkv_axis),
        )
        return {
            **self._side_states(batch_size),
            "caches": [jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_super, *a.shape)), cache
            )],
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def init_paged_decode_state(self, batch_size: int, *, n_pages: int,
                                nb_max: int):
        """Paged decode state: one PagedQuantKVCache pool set stacked over
        the ``n_super`` shared-attention invocations; SSM recurrent states
        stay dense per slot (they never touch the page table)."""
        cfg = self.cfg
        one = qcache.init_paged_cache(
            n_pages, batch_size, cfg.n_kv_heads, cfg.head_dim, nb_max,
            bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran=cfg.kv_gran,
        )
        return {
            **self._side_states(batch_size),
            "caches": [jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_super, *a.shape)), one
            )],
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def decode_step(self, params, state, tokens, *, impl="auto", quant_impl="auto"):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        pos = state["pos"]
        positions = pos[:, None]
        shared = params["shared_attn"]

        def super_body(x, xs):
            group, sst, cache = xs

            def inner(x, ys):
                lp, st = ys
                h = layers.apply_norm(cfg.norm, lp["ln"], x)
                out, st = mamba2.mamba2_decode(lp["mixer"], cfg, h, st)
                return x + out, st

            x, sst = lax.scan(inner, x, (group, sst))
            h = layers.apply_norm(cfg.norm, shared["ln1"], x)
            a, cache = mattn.attn_decode(
                shared["attn"], cfg, h, positions, cache, impl=impl,
                quant_impl=quant_impl,
            )
            x = x + a
            x = x + layers.mlp(
                shared["mlp"], layers.apply_norm(cfg.norm, shared["ln2"], x), cfg.act
            )
            return x, (sst, cache)

        x, (ssm_main, caches) = lax.scan(
            super_body, x, (params["main"], state["ssm_main"], state["caches"][0])
        )
        new_state = dict(state, ssm_main=ssm_main, caches=[caches], pos=pos + 1)
        if self.tail:
            def tail_body(x, ys):
                lp, st = ys
                h = layers.apply_norm(cfg.norm, lp["ln"], x)
                out, st = mamba2.mamba2_decode(lp["mixer"], cfg, h, st)
                return x + out, st

            x, ssm_tail = lax.scan(tail_body, x, (params["tail"], state["ssm_tail"]))
            new_state["ssm_tail"] = ssm_tail
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return logits, new_state

    def prefill(self, params, batch, max_seq: int):
        """Chunked-parallel prefill: SSD scan for Mamba states, flash prefill
        + fused quantization for the shared attention caches."""
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"])
        b, s = x.shape[:2]
        positions = _positions_lm(b, s)
        shared = params["shared_attn"]

        def super_body(x, group):
            def inner(x, lp):
                h = layers.apply_norm(cfg.norm, lp["ln"], x)
                out, st = mamba2.mamba2_prefill(lp["mixer"], cfg, h)
                return x + out, st

            x, states = lax.scan(inner, x, group)
            h = layers.apply_norm(cfg.norm, shared["ln1"], x)
            a, cache = mattn.attn_prefill_cache(shared["attn"], cfg, h, positions, max_seq)
            x = x + a
            x = x + layers.mlp(
                shared["mlp"], layers.apply_norm(cfg.norm, shared["ln2"], x), cfg.act
            )
            return x, (states, cache)

        x, (ssm_main, caches) = lax.scan(super_body, x, params["main"])
        state = {
            "ssm_main": ssm_main,
            "caches": [caches],
            "pos": jnp.full((b,), s, jnp.int32),
        }
        if self.tail:
            def tail_body(x, lp):
                h = layers.apply_norm(cfg.norm, lp["ln"], x)
                out, st = mamba2.mamba2_prefill(lp["mixer"], cfg, h)
                return x + out, st

            x, ssm_tail = lax.scan(tail_body, x, params["tail"])
            state["ssm_tail"] = ssm_tail
        x = layers.apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return logits, state


class XLSTMLM:
    """xLSTM: super-blocks of (mlstm_per_slstm mLSTM + 1 sLSTM) blocks."""

    def __init__(self, cfg):
        self.cfg = cfg
        per = cfg.mlstm_per_slstm + 1
        assert cfg.n_layers % per == 0, "n_layers must divide super-block size"
        self.n_super = cfg.n_layers // per

    def _mlstm_def(self):
        cfg = self.cfg
        return {"ln": layers.norm_def(cfg.norm, cfg.d_model), "mixer": xlstm.mlstm_def(cfg)}

    def _slstm_def(self):
        cfg = self.cfg
        return {"ln": layers.norm_def(cfg.norm, cfg.d_model), "mixer": xlstm.slstm_def(cfg)}

    def param_defs(self):
        cfg = self.cfg
        super_def = {
            "mlstm": stack(self._mlstm_def(), cfg.mlstm_per_slstm, "inner"),
            "slstm": self._slstm_def(),
        }
        return {
            "embed": layers.embed_def(cfg.padded_vocab, cfg.d_model),
            "final_norm": layers.norm_def(cfg.norm, cfg.d_model),
            "unembed": layers.unembed_def(cfg.d_model, cfg.padded_vocab),
            "blocks": stack(super_def, self.n_super),
        }

    def init(self, rng):
        return init_tree(self.param_defs(), rng)

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def param_specs(self, rules):
        return spec_tree(self.param_defs(), rules)

    def _forward(self, params, x, states=None):
        """states=None -> training (fresh states, discarded)."""
        cfg = self.cfg
        carry_states = states is not None

        def super_body(x, xs):
            if carry_states:
                group, st = xs
            else:
                group, st = xs, None

            def inner(x, ys):
                if carry_states:
                    lp, s = ys
                else:
                    lp, s = ys, None
                h = layers.apply_norm(cfg.norm, lp["ln"], x)
                out, s = xlstm.mlstm_block(lp["mixer"], cfg, h, s)
                return x + out, s

            if carry_states:
                x, mst = lax.scan(inner, x, (group["mlstm"], st["mlstm"]))
            else:
                x, mst = lax.scan(inner, x, group["mlstm"])
            h = layers.apply_norm(cfg.norm, group["slstm"]["ln"], x)
            out, sst = xlstm.slstm_block(
                group["slstm"]["mixer"], cfg, h, st["slstm"] if carry_states else None
            )
            x = x + out
            return x, {"mlstm": mst, "slstm": sst}

        body = super_body
        if cfg.remat == "full" and not carry_states:
            body = jax.checkpoint(body, prevent_cse=False)
        if carry_states:
            x, new_states = lax.scan(body, x, (params["blocks"], states))
        else:
            x, new_states = lax.scan(body, x, params["blocks"])
        return x, new_states

    def loss(self, params, batch):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"])
        x, _ = self._forward(params, x)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, cfg.vocab)
        return _ce_loss(logits[:, :-1], batch["labels"][:, 1:], batch["loss_mask"][:, 1:])

    def paged_spec(self) -> PagedSpec:
        """No growing KV anywhere: every state is a constant-size recurrent
        pytree.  ``paged=False`` routes the serving engine's exact-length
        shim; ``side_state`` tells it where the recurrent states live and on
        which axis their batch sits (after the super-block stacking dims)."""
        return PagedSpec(
            paged=False, block_n=self.cfg.kv_block, n_kv_heads=0, d_k=0,
            d_v=0, side_state=(("blocks/mlstm", 2), ("blocks/slstm", 1)),
            exact_prefill=True,
        )

    def init_decode_state(self, batch_size: int, max_seq: int = 0):
        cfg = self.cfg
        m1 = xlstm.mlstm_init_state(cfg, batch_size)
        s1 = xlstm.slstm_init_state(cfg, batch_size)
        return {
            "blocks": {
                "mlstm": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (self.n_super, cfg.mlstm_per_slstm, *a.shape)), m1
                ),
                "slstm": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (self.n_super, *a.shape)), s1
                ),
            },
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def decode_step(self, params, state, tokens, *, impl="auto", quant_impl="auto"):
        del impl, quant_impl  # no attention KV cache in this backbone
        x = layers.embed(params["embed"], tokens)
        x, new_states = self._forward(params, x, state["blocks"])
        x = layers.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["unembed"], x, self.cfg.vocab)
        return logits, {"blocks": new_states, "pos": state["pos"] + 1}

    def prefill(self, params, batch, max_seq: int = 0):
        x = layers.embed(params["embed"], batch["tokens"])
        state = self.init_decode_state(x.shape[0])
        x, new_states = self._forward(params, x, state["blocks"])
        x = layers.apply_norm(self.cfg.norm, params["final_norm"], x[:, -1:])
        logits = layers.unembed(params["unembed"], x, self.cfg.vocab)
        pos = jnp.full((x.shape[0],), batch["tokens"].shape[1], jnp.int32)
        return logits, {"blocks": new_states, "pos": pos}
