"""Multi-head Latent Attention (DeepSeek-V2/V3) with a quantized latent cache.

Train uses the expanded form; decode uses the *absorbed* form, where queries
are projected into the latent space (q @ W_uk) and attention runs directly
against the cached latent stream ``[c_kv ; k_rope]``.  BitDecoding applies to
the latent cache itself (shared_kv mode): one quantized stream feeds both the
score and value sides, and g_q = n_heads (128) — the query transformation's
best case, a fully-populated MXU M dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import attention as catt
from repro.core import qcache
from repro.models import layers
from repro.models.params import P


def mla_def(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora, cfg.kv_lora
    dn, dr, dv = cfg.qk_nope, cfg.qk_rope, cfg.v_head_dim
    return {
        "q_down": P((d, ql), ("embed", None)),
        "q_norm": layers.rmsnorm_def(ql),
        "q_up": P((ql, h, dn + dr), (None, "heads", "head_dim")),
        "kv_down": P((d, kvl + dr), ("embed", None)),
        "kv_norm": layers.rmsnorm_def(kvl),
        "k_up": P((kvl, h, dn), (None, "heads", "head_dim")),
        "v_up": P((kvl, h, dv), (None, "heads", "head_dim")),
        "wo": P((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _latent(p, cfg, x, positions):
    """x [B,S,d] -> (c_kv [B,S,kv_lora], k_rope [B,S,qk_rope]) with RoPE."""
    kvr = jnp.einsum("bsd,dl->bsl", x, p["kv_down"])
    c_kv = layers.rmsnorm(p["kv_norm"], kvr[..., : cfg.kv_lora])
    k_rope = kvr[..., cfg.kv_lora :]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _queries(p, cfg, x, positions):
    c_q = layers.rmsnorm(p["q_norm"], jnp.einsum("bsd,dl->bsl", x, p["q_down"]))
    q = jnp.einsum("bsl,lhk->bshk", c_q, p["q_up"])
    q_nope = q[..., : cfg.qk_nope]
    q_rope = layers.apply_rope(q[..., cfg.qk_nope :], positions, theta=cfg.rope_theta)
    return q_nope, q_rope


def mla_train(p, cfg, x, positions):
    """Expanded-form training attention."""
    b, s, d = x.shape
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["k_up"])
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (b, s, cfg.n_heads, cfg.qk_rope)
    ).astype(k_nope.dtype)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["v_up"])
    # §Perf iteration B2: the concat of differently-sharded parts (nope from
    # the FSDP-sharded up-projection, rope replicated) otherwise makes the
    # partitioner shard the score dot's CONTRACTION dim -> a partial-sum
    # all-reduce of every (S x block) score tile, ~64 TB/device at 32K.
    # Pin q/k/v to batch x head sharding before attention.
    from repro.dist.sharding import constrain

    q = constrain(q, ("pod", "data"), None, "model", None)
    k = constrain(k, ("pod", "data"), None, "model", None)
    v = constrain(v, ("pod", "data"), None, "model", None)
    out = catt.blockwise_attention(
        q, k, v, causal=True,
        sm_scale=1.0 / (cfg.qk_nope + cfg.qk_rope) ** 0.5,
        block_k=cfg.attn_block_k,
    )
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


def mla_init_cache(cfg, batch: int, max_seq: int, *, block_align=None):
    """Latent cache: one KV 'head' of width kv_lora + qk_rope, shared_kv."""
    return qcache.init_cache(
        batch, 1, cfg.kv_lora + cfg.qk_rope, max_seq,
        bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran="channel", shared_kv=True,
        block_align=block_align,
    )


def mla_init_paged_cache(cfg, n_pages: int, batch: int, nb_max: int):
    """Paged latent cache (serving engine layout): the single quantized
    latent stream lives in shared ``shared_kv`` page pools — no V-side pools
    at all — and decodes through ``kernels/paged_bitdecode``'s latent walk."""
    return qcache.init_paged_cache(
        n_pages, batch, 1, cfg.kv_lora + cfg.qk_rope, nb_max,
        bits=cfg.kv_bits, block_n=cfg.kv_block, k_gran="channel",
        shared_kv=True,
    )


def _expand_latent(p, cfg, lat):
    """Latent ``[B, T, kv_lora + qk_rope]`` -> expanded per-head
    (k [B,T,h,qk_nope+qk_rope], v [B,T,h,v_head_dim]) via the up-projections.

    Algebraically the absorbed decode score ``q_eff · lat`` equals the
    expanded ``q · k`` (``q_nope·(c@W_uk) == (q_nope@W_uk)·c``), so attending
    an expanded *dequantized* latent prior gives the suffix prefill the same
    numeric view of shared pages that paged latent decode has.
    """
    b, t = lat.shape[:2]
    c = lat[..., : cfg.kv_lora]
    r = lat[..., cfg.kv_lora :]
    k_nope = jnp.einsum("btl,lhk->bthk", c, p["k_up"])
    k = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(r[:, :, None, :], (b, t, cfg.n_heads, cfg.qk_rope)
                          ).astype(k_nope.dtype)],
        axis=-1,
    )
    v = jnp.einsum("btl,lhk->bthk", c, p["v_up"])
    return k, v


def mla_prefill_cache(p, cfg, x, positions, max_seq: int, *, quant_impl="auto",
                      lengths=None, block_align=None, prior=None,
                      prior_len=None):
    """Prefill attention + latent cache build.

    ``prior`` (prefix sharing, serving engine) is the dequantized shared
    latent prior ``(lat [B, T, 1, kv_lora+qk_rope], None)`` from
    ``qcache.dequant_prior`` on a shared_kv paged cache: ``x`` holds only the
    divergent suffix, whose expanded Q/K/V attend the expanded prior through
    :func:`repro.core.attention.prefix_suffix_attention` (callers pass
    suffix-global ``positions``).  The built cache holds suffix latents only.
    """
    c_kv, k_rope = _latent(p, cfg, x, positions)
    if prior is None:
        out = mla_train(p, cfg, x, positions)
    else:
        b, s = x.shape[:2]
        q_nope, q_rope = _queries(p, cfg, x, positions)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["k_up"])
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope[:, :, None, :],
                              (b, s, cfg.n_heads, cfg.qk_rope)
                              ).astype(k_nope.dtype)],
            axis=-1,
        )
        v = jnp.einsum("bsl,lhk->bshk", c_kv, p["v_up"])
        k_prior, v_prior = _expand_latent(p, cfg, prior[0][:, :, 0, :])
        out = catt.prefix_suffix_attention(
            q, k, v, k_prior, v_prior, prior_len,
            sm_scale=1.0 / (cfg.qk_nope + cfg.qk_rope) ** 0.5,
        )
        out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, None]  # [B,1,S,kvl+dr]
    cache = mla_init_cache(cfg, x.shape[0], max_seq, block_align=block_align)
    cache = qcache.prefill(cache, lat, None, lengths=lengths, quant_impl=quant_impl)
    return out, cache


def mla_decode(p, cfg, x, positions, cache, *, impl="auto", quant_impl="auto"):
    """Absorbed-form decode against the quantized latent cache."""
    b = x.shape[0]
    q_nope, q_rope = _queries(p, cfg, x, positions)  # [B,1,h,*]
    c_kv, k_rope = _latent(p, cfg, x, positions)
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, None]  # [B, H=1, S=1, kvl+dr]
    # absorb: q_eff = [q_nope @ W_uk ; q_rope]  -> width kv_lora + qk_rope
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["k_up"])  # [B,1,h,kv_lora]
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)
    out_lat, cache = catt.decode_append_attention(
        q_eff, cache, lat, None, quant_impl=quant_impl,
        sm_scale=1.0 / (cfg.qk_nope + cfg.qk_rope) ** 0.5,
        d_v=cfg.kv_lora, impl=impl,
    )  # [B,1,h,kv_lora]
    out = jnp.einsum("bshl,lhk->bshk", out_lat.astype(x.dtype), p["v_up"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache
