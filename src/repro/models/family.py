"""Cache-family protocol: what a model declares about its decode state.

Every backbone exposes ``model.paged_spec() -> PagedSpec | None`` and the
serving engine (serve/engine.py) is driven *entirely* by the returned spec —
there is no per-architecture branching in the engine anymore:

* ``PagedSpec(paged=True, ...)`` — the model's KV layers decode through the
  page table (``model.init_paged_decode_state`` returns a state whose
  ``state["caches"]`` is a list of layer-stacked
  :class:`repro.core.qcache.PagedQuantKVCache`).  ``shared_kv`` marks the
  MLA latent layout (one pool set, V sliced from K); ``side_state`` names
  the constant-size per-slot pytrees that ride along *outside* the page
  table (HybridLM's SSM recurrent states) together with the batch axis the
  engine splices them on at admission.
* ``PagedSpec(paged=False, ...)`` — the model has no growing KV at all
  (xLSTM: every state is constant-size recurrent).  The engine serves it
  through the thin exact-length shim: per-request exact-length prefill
  spliced into the batched dense state, same scheduler, same decode cycle.
* ``None`` — the model cannot be served by the engine (its prefill needs
  inputs beyond ``tokens``: enc-dec frame embeddings, VLM patches).

``pages_per_token`` and ``page_layers`` are the per-family page accounting:
one page-table column covers ``block_n`` tokens across *all* ``page_layers``
paged layer-caches, so a hybrid page is a factor ``n_layers / n_super``
smaller than a dense transformer's at equal width; both surface in the
engine's ``summary()`` next to the measured ``kv_page_bytes``.  ``d_k`` /
``d_v`` / ``shared_kv`` declare the pool layout — the engine validates them
against the pools ``init_paged_decode_state`` actually allocates, so a
model whose spec and state constructor drift apart fails at construction,
not mid-decode.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Declared decode-cache capabilities of one model family."""

    paged: bool           # KV layers decode through the page table
    block_n: int          # tokens per page-table column
    n_kv_heads: int       # KV heads per paged layer (1 for the MLA latent)
    d_k: int              # packed K (or latent) width
    d_v: int              # value width (latent slice when shared_kv)
    shared_kv: bool = False   # single latent pool (MLA) vs split K/V pools
    page_layers: int = 0      # layer-cache instances behind each table column
    # constant-size per-slot state spliced at admission: ("path", batch_dim)
    # pairs, where "path" is a '/'-joined key path into the decode state
    side_state: tuple = ()
    # prompts must prefill at their exact length (recurrent side-state would
    # absorb right-padding) — admission buckets become exact lengths
    exact_prefill: bool = False
    # the model supports suffix prefill against a dequantized prior
    # (``model.prefill(prior=...)``) — the prefix-sharing prerequisite
    supports_prior: bool = False

    @property
    def pages_per_token(self) -> float:
        """Page-table columns consumed per cached token (per family)."""
        return 1.0 / self.block_n if self.paged else 0.0


def get_path(tree, path: str):
    """Resolve a '/'-joined ``side_state`` path inside a decode state."""
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def set_path(tree, path: str, value) -> None:
    """Write a '/'-joined ``side_state`` path inside a decode state."""
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[part]
    node[parts[-1]] = value
