"""Parameter-definition trees: shapes + logical sharding axes, materialized
lazily.

Models define a pytree of :class:`P` leaves (shape, logical axes, init).
From that single source of truth we derive:
  * ``shape_tree``   — ShapeDtypeStructs for the dry-run (never allocates);
  * ``init_tree``    — materialized params for smoke tests / real training;
  * ``spec_tree``    — jax.sharding.PartitionSpec per leaf via logical-axis
                       rules (dist/sharding.py), MaxText-style.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]  # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed
    dtype: Any = jnp.bfloat16
    scale: float | None = None  # stddev override; default fan-in scaled

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def stack(defs, n: int, axis_name: str = "layers"):
    """Prepend a scan (layer) dimension to every leaf."""
    return jax.tree.map(
        lambda p: P((n, *p.shape), (axis_name, *p.axes), p.init, p.dtype, p.scale),
        defs,
        is_leaf=lambda x: isinstance(x, P),
    )


def shape_tree(defs):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _init_leaf(p: P, key):
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    # fan-in scaled normal over the last dim by default
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale if p.scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)


def init_tree(defs, rng):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(p, k) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def spec_tree(defs, rules: dict):
    """Map logical axes -> PartitionSpec via ``rules`` (axis name -> mesh axis
    or tuple of mesh axes or None)."""
    from jax.sharding import PartitionSpec as PS

    def leaf(p: P):
        return PS(*[rules.get(a) for a in p.axes])

    return jax.tree.map(leaf, defs, is_leaf=lambda x: isinstance(x, P))
