#!/usr/bin/env bash
# Tier-1 verification: the invariant every PR keeps green.
#   scripts/run_tier1.sh [extra pytest args]
# Runs the full test suite (PYTHONPATH=src, fail-fast, quiet) followed by the
# docs-drift check (README kernel inventory + SERVING/ARCHITECTURE symbol/
# flag/counter sync) and the named serve-pressure gate.  The suite includes
# the serving gates:
# tests/test_serve_paged.py (paged engine + exact-length shim),
# tests/test_serve_prefix.py (prefix sharing + COW parity),
# tests/test_serve_families.py (unified paged decode across cache families:
# MLA latent paging, hybrid mixed states, SSM page-table-free jaxpr proof),
# and tests/test_serve_pressure.py (preemption-by-rematerialization parity,
# lifecycle guards, pool-invariant auditor, deterministic fault injection) —
# plus the shared_kv paged kernel grid in tests/test_kernels_paged.py.
# CI (.github/workflows/ci.yml) calls exactly this script, so local and CI
# runs cannot diverge.
#
#   scripts/run_tier1.sh --serve-pressure   # run only the pressure gate
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

if [[ "${1:-}" == "--serve-pressure" ]]; then
    shift
    echo "[tier1] serve-pressure gate (preemption parity, faults, auditor)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q tests/test_serve_pressure.py "$@"
    exit 0
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
python scripts/check_docs.py
