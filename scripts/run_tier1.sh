#!/usr/bin/env bash
# Tier-1 verification: the invariant every PR keeps green.
#   scripts/run_tier1.sh [extra pytest args]
# Runs the full test suite (PYTHONPATH=src, fail-fast, quiet) followed by the
# docs-drift check (README kernel inventory + SERVING/ARCHITECTURE symbol/
# flag/counter sync + the OBSERVABILITY metric-catalog/event-schema sync)
# and the named serve-pressure / serve-telemetry gates.  The suite includes
# the serving gates:
# tests/test_serve_paged.py (paged engine + exact-length shim),
# tests/test_serve_prefix.py (prefix sharing + COW parity),
# tests/test_serve_families.py (unified paged decode across cache families:
# MLA latent paging, hybrid mixed states, SSM page-table-free jaxpr proof),
# tests/test_serve_pressure.py (preemption-by-rematerialization parity,
# lifecycle guards, pool-invariant auditor, deterministic fault injection),
# tests/test_serve_spec.py (self-speculative decoding bitwise parity across
# families/bits/pressure, docs/SERVING.md §11),
# tests/test_serve_telemetry.py (metrics registry, event tracer,
# phase-timing breakdown, telemetry-on/off bitwise parity,
# docs/OBSERVABILITY.md),
# tests/test_serve_async.py (async-vs-sync differential parity across
# families/speculation/pressure/faults, completion-thread ledger,
# deadlock watchdogs, docs/SERVING.md §13), and
# tests/test_serve_invariants.py (generative random-op audit sweep;
# hypothesis-gated),
# tests/test_serve_prefix_tier.py (persistent prefix-cache tier: retained-
# page survival + bitwise re-admission, reclaim-before-preemption ordering,
# auditor detection, evict_storm faults, docs/SERVING.md §14) — plus the
# shared_kv paged kernel grid in tests/test_kernels_paged.py.
# CI (.github/workflows/ci.yml) calls exactly this script, so local and CI
# runs cannot diverge.
#
#   scripts/run_tier1.sh --serve-pressure     # run only the pressure gate
#   scripts/run_tier1.sh --serve-telemetry    # run only the telemetry gate
#   scripts/run_tier1.sh --serve-async        # run only the async gate
#   scripts/run_tier1.sh --serve-prefix-tier  # run only the retention gate
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

# The async runtime runs real threads: a wedged completion queue or decode
# pipeline must fail a test, never hang the suite.  The runtime's own
# watchdogs (DeadlockError) are the first line; pytest-timeout is the CI
# backstop (requirements-test.txt installs it; bare local environments
# degrade to the watchdogs alone).
TIMEOUT_ARGS=()
if python -c "import pytest_timeout" >/dev/null 2>&1; then
    TIMEOUT_ARGS=(--timeout=600 --timeout-method=thread)
fi

if [[ "${1:-}" == "--serve-pressure" ]]; then
    shift
    echo "[tier1] serve-pressure gate (preemption parity, faults, auditor)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q "${TIMEOUT_ARGS[@]}" \
        tests/test_serve_pressure.py "$@"
    exit 0
fi

if [[ "${1:-}" == "--serve-telemetry" ]]; then
    shift
    echo "[tier1] serve-telemetry gate (tracer schema, phase timing, on/off parity)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q "${TIMEOUT_ARGS[@]}" \
        tests/test_serve_telemetry.py "$@"
    exit 0
fi

if [[ "${1:-}" == "--serve-async" ]]; then
    shift
    echo "[tier1] serve-async gate (async-vs-sync bitwise parity, liveness, completion ledger)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q "${TIMEOUT_ARGS[@]}" \
        tests/test_serve_async.py "$@"
    exit 0
fi

if [[ "${1:-}" == "--serve-prefix-tier" ]]; then
    shift
    echo "[tier1] serve-prefix-tier gate (retained-page survival, reclaim ordering, evict_storm)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q "${TIMEOUT_ARGS[@]}" \
        tests/test_serve_prefix_tier.py "$@"
    exit 0
fi

# Coverage floor on the serving subsystem (engine, scheduler, pages, audit,
# faults, speculative, async_runtime): enforced whenever pytest-cov is
# installed (CI always installs it via requirements-test.txt; bare local
# environments degrade to an uninstrumented run).
COV_ARGS=()
if python -c "import pytest_cov" >/dev/null 2>&1; then
    COV_ARGS=(--cov=repro.serve --cov-report=term --cov-fail-under=70)
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "${TIMEOUT_ARGS[@]}" "${COV_ARGS[@]}" "$@"
python scripts/check_docs.py
