#!/usr/bin/env bash
# Tier-1 verification: the invariant every PR keeps green.
#   scripts/run_tier1.sh [extra pytest args]
# Runs the full test suite (PYTHONPATH=src, fail-fast, quiet) followed by the
# docs-drift check (README kernel inventory + SERVING/ARCHITECTURE symbol/
# flag/counter sync).  The suite includes the serving gates:
# tests/test_serve_paged.py (paged engine + exact-length shim),
# tests/test_serve_prefix.py (prefix sharing + COW parity), and
# tests/test_serve_families.py (unified paged decode across cache families:
# MLA latent paging, hybrid mixed states, SSM page-table-free jaxpr proof) —
# plus the shared_kv paged kernel grid in tests/test_kernels_paged.py.
# CI (.github/workflows/ci.yml) calls exactly this script, so local and CI
# runs cannot diverge.
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
python scripts/check_docs.py
