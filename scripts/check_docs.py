#!/usr/bin/env python
"""Docs-drift check: README's kernel-family inventory must match the actual
kernel directories under src/repro/kernels/.

A kernel family counts as documented when README.md's "Kernel families"
table has a row whose first cell is the backtick-quoted directory name.
Run directly (exit 1 on drift) or via tests/test_docs.py in the tier-1
suite.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
README = REPO / "README.md"
KERNELS = REPO / "src" / "repro" / "kernels"

_ROW = re.compile(r"^\|\s*`([A-Za-z0-9_]+)`\s*\|")


def kernel_dirs() -> set[str]:
    return {
        p.name
        for p in KERNELS.iterdir()
        if p.is_dir() and (p / "__init__.py").exists()
    }


def documented_families(readme_text: str) -> set[str]:
    """Backtick-named first cells of table rows in the 'Kernel families'
    section (up to the next '## ' heading)."""
    lines = readme_text.splitlines()
    fams: set[str] = set()
    in_section = False
    for line in lines:
        if line.startswith("## "):
            in_section = line.lower().startswith("## kernel families")
            continue
        if not in_section:
            continue
        m = _ROW.match(line)
        if m and m.group(1) != "family":  # skip the header row
            fams.add(m.group(1))
    return fams


def check() -> list[str]:
    """Returns a list of human-readable drift errors (empty == in sync)."""
    errors = []
    if not README.exists():
        return [f"missing {README}"]
    actual = kernel_dirs()
    documented = documented_families(README.read_text())
    if not documented:
        errors.append("README.md has no 'Kernel families' table rows")
    for name in sorted(actual - documented):
        errors.append(
            f"kernel family src/repro/kernels/{name}/ is missing from "
            "README.md's 'Kernel families' table"
        )
    for name in sorted(documented - actual):
        errors.append(
            f"README.md documents kernel family `{name}` but "
            f"src/repro/kernels/{name}/ does not exist"
        )
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({len(kernel_dirs())} kernel families in sync)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
