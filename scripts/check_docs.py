#!/usr/bin/env python
"""Docs-drift check: the docs surface must track the code it describes.

Checks:

* README's "Kernel families" table rows match the actual kernel directories
  under src/repro/kernels/;
* backticked dotted ``repro.*`` symbol references in docs/SERVING.md *and*
  docs/ARCHITECTURE.md resolve to real attributes (import + getattr walk) —
  this is what keeps protocol names like ``repro.models.family.PagedSpec``
  honest;
* docs/SERVING.md's "Engine flags" table rows are real keyword parameters
  of ``ServeEngine.__init__``;
* docs/SERVING.md's counter table rows appear as string literals in the
  serving sources (engine.py / scheduler.py / pages.py / audit.py /
  faults.py / speculative.py / telemetry.py), modulo the ``sched_``
  prefix the engine adds when folding scheduler stats into ``summary()``;
* docs/OBSERVABILITY.md exists, its backticked ``repro.*`` symbols
  resolve, and every row of its "Metric catalog" and "Event schema"
  tables appears as a string literal in the serving sources — the metric
  and event names a dashboard or trace viewer would key on cannot drift
  from what the code actually emits.

Run directly (exit 1 on drift) or via tests/test_docs.py in the tier-1
suite.
"""
from __future__ import annotations

import importlib
import inspect
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
README = REPO / "README.md"
SERVING = REPO / "docs" / "SERVING.md"
OBSERVABILITY = REPO / "docs" / "OBSERVABILITY.md"
ARCHITECTURE = REPO / "docs" / "ARCHITECTURE.md"
KERNELS = REPO / "src" / "repro" / "kernels"
SERVE_SRC = REPO / "src" / "repro" / "serve"

_ROW = re.compile(r"^\|\s*`([A-Za-z0-9_]+)`\s*\|")
_DOTTED = re.compile(r"`(repro\.[A-Za-z0-9_.]+)`")

if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))


def kernel_dirs() -> set[str]:
    return {
        p.name
        for p in KERNELS.iterdir()
        if p.is_dir() and (p / "__init__.py").exists()
    }


def documented_families(readme_text: str) -> set[str]:
    """Backtick-named first cells of table rows in the 'Kernel families'
    section (up to the next '## ' heading)."""
    lines = readme_text.splitlines()
    fams: set[str] = set()
    in_section = False
    for line in lines:
        if line.startswith("## "):
            in_section = line.lower().startswith("## kernel families")
            continue
        if not in_section:
            continue
        m = _ROW.match(line)
        if m and m.group(1) != "family":  # skip the header row
            fams.add(m.group(1))
    return fams


def serving_symbols(text: str) -> set[str]:
    """Backticked dotted ``repro.*`` references in docs/SERVING.md."""
    return {m.group(1) for m in _DOTTED.finditer(text)}


def resolve_symbol(dotted: str) -> bool:
    """Import the longest importable module prefix, then getattr-walk."""
    parts = dotted.split(".")
    for split in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:split]))
        except ImportError:
            continue
        try:
            for attr in parts[split:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def table_rows(text: str, heading_match: str) -> set[str]:
    """Backtick-named first cells of table rows under a heading whose line
    contains ``heading_match`` (up to the next heading)."""
    rows: set[str] = set()
    in_section = False
    for line in text.splitlines():
        if line.startswith("#"):
            in_section = heading_match.lower() in line.lower()
            continue
        if in_section:
            m = _ROW.match(line)
            if m:
                rows.add(m.group(1))
    return rows


def check_symbols(text: str, doc_name: str) -> list[str]:
    """Unresolvable backticked ``repro.*`` references in one doc."""
    return [
        f"{doc_name} references `{sym}` which does not resolve to a repro "
        "symbol"
        for sym in sorted(serving_symbols(text))
        if not resolve_symbol(sym)
    ]


def check_serving(text: str) -> list[str]:
    """Drift errors for docs/SERVING.md against the serving sources."""
    errors = check_symbols(text, "docs/SERVING.md")
    from repro.serve.engine import ServeEngine

    params = set(inspect.signature(ServeEngine.__init__).parameters)
    flags = table_rows(text, "Engine flags")
    if not flags:
        errors.append("docs/SERVING.md has no 'Engine flags' table rows")
    for flag in sorted(flags - params):
        errors.append(f"docs/SERVING.md documents engine flag `{flag}` but "
                      "ServeEngine.__init__ has no such parameter")
    counters = table_rows(text, "counters")
    if not counters:
        errors.append("docs/SERVING.md has no counter table rows")
    errors.extend(_check_names_in_sources(
        counters, "docs/SERVING.md", "counter"))
    return errors


def _serve_sources() -> str:
    return "".join(
        (SERVE_SRC / f).read_text()
        for f in ("engine.py", "scheduler.py", "pages.py", "audit.py",
                  "faults.py", "speculative.py", "telemetry.py",
                  "async_runtime.py")
    )


def _check_names_in_sources(names: set[str], doc: str, what: str) -> list[str]:
    """Each documented name must appear as a string literal somewhere in
    the serving sources (``sched_``-prefixed registry names may appear
    bare — the scheduler constructs the prefix)."""
    src = _serve_sources()
    return [
        f"{doc} documents {what} `{n}` which appears nowhere in the "
        "serving sources"
        for n in sorted(names)
        if n not in src and n.removeprefix("sched_") not in src
    ]


def check_observability(text: str) -> list[str]:
    """Drift errors for docs/OBSERVABILITY.md: symbols resolve, and the
    metric-catalog / event-schema rows name things the code emits."""
    errors = check_symbols(text, "docs/OBSERVABILITY.md")
    metrics = table_rows(text, "Metric catalog")
    if not metrics:
        errors.append("docs/OBSERVABILITY.md has no 'Metric catalog' rows")
    errors.extend(_check_names_in_sources(
        metrics, "docs/OBSERVABILITY.md", "metric"))
    events = table_rows(text, "Event schema")
    if not events:
        errors.append("docs/OBSERVABILITY.md has no 'Event schema' rows")
    errors.extend(_check_names_in_sources(
        events, "docs/OBSERVABILITY.md", "event"))
    # the engine's registered metric names must all be documented: the
    # catalog is the dashboard contract, so an undocumented instrument is
    # drift in the other direction
    from repro.serve.engine import PHASE_METRICS, STAT_COUNTERS

    expected = set(STAT_COUNTERS) | set(PHASE_METRICS.values())
    for name in sorted(expected - metrics):
        errors.append(
            f"engine metric `{name}` is missing from docs/OBSERVABILITY.md's "
            "'Metric catalog'"
        )
    return errors


def check() -> list[str]:
    """Returns a list of human-readable drift errors (empty == in sync)."""
    errors = []
    if not README.exists():
        return [f"missing {README}"]
    actual = kernel_dirs()
    documented = documented_families(README.read_text())
    if not documented:
        errors.append("README.md has no 'Kernel families' table rows")
    for name in sorted(actual - documented):
        errors.append(
            f"kernel family src/repro/kernels/{name}/ is missing from "
            "README.md's 'Kernel families' table"
        )
    for name in sorted(documented - actual):
        errors.append(
            f"README.md documents kernel family `{name}` but "
            f"src/repro/kernels/{name}/ does not exist"
        )
    if not SERVING.exists():
        errors.append("missing docs/SERVING.md")
    else:
        errors.extend(check_serving(SERVING.read_text()))
    if not OBSERVABILITY.exists():
        errors.append("missing docs/OBSERVABILITY.md")
    else:
        errors.extend(check_observability(OBSERVABILITY.read_text()))
    if not ARCHITECTURE.exists():
        errors.append("missing docs/ARCHITECTURE.md")
    else:
        errors.extend(
            check_symbols(ARCHITECTURE.read_text(), "docs/ARCHITECTURE.md")
        )
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(
            f"check_docs: OK ({len(kernel_dirs())} kernel families, "
            f"{len(serving_symbols(SERVING.read_text()))} serving symbols, "
            f"{len(table_rows(OBSERVABILITY.read_text(), 'Metric catalog'))} "
            "catalogued metrics; engine flags + counters + events in sync)"
        )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
