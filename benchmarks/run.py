"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  bench_kernel_decode   Fig. 8/9/10 (kernel speedups across settings)
  bench_e2e             Fig. 11/12  (end-to-end decode + serving throughput)
  bench_accuracy        Table I     (bits vs fidelity/throughput)
  bench_quant_overhead  Table II + Fig. 13 (quant/pack overhead, residual)
  bench_blocksweep      Table III   (parallelization granularity sweep)
  bench_breakdown       Table IV    (optimization breakdown)
  bench_roofline        §Roofline table from dry-run artifacts
  bench_serve           Offered-load serving sweep (paged engine; BENCH_serve.json)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_accuracy, bench_blocksweep, bench_breakdown,
                            bench_e2e, bench_flash_prefill,
                            bench_kernel_decode, bench_paged,
                            bench_quant_overhead, bench_roofline, bench_serve)

    print("name,us_per_call,derived")
    failed = []
    for mod in (bench_kernel_decode, bench_paged, bench_flash_prefill,
                bench_accuracy, bench_quant_overhead, bench_blocksweep,
                bench_breakdown, bench_e2e, bench_serve, bench_roofline):
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append((mod.__name__, e))
            traceback.print_exc(limit=3, file=sys.stderr)
    if failed:
        for name, e in failed:
            print(f"{name},nan,FAILED:{e!r}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
