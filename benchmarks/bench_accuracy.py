"""Paper Table I analogue: efficiency/fidelity trade-off across bit widths.

No datasets/weights offline, so LongBench accuracy is replaced by attention-
output fidelity vs the exact fp16 oracle on heavy-tailed synthetic K/V
(DESIGN.md §7.6), plus the modeled throughput gain from cache-bytes
reduction at seq 32K (the paper's Table I setting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, kv_bytes_fp16, kv_bytes_quant,
                               make_decode_case)
from repro.core import attention as catt


def run():
    from repro.core import qcache

    b, h_kv, g_q, d, s = 2, 4, 4, 128, 2048
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    # retrieval-structured K (the realistic regime): each query has a
    # "needle" key aligned with it at a robust margin, the rest is noise.
    # Pure iid K makes the softmax winner a coin-flip that any quantizer
    # perturbs — a worst case no serving workload resembles.
    q = jax.random.normal(ks[0], (b, 1, h_kv * g_q, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h_kv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h_kv, s, d), jnp.float32)
    qt0 = q.reshape(b, h_kv, g_q, d)
    needle_pos = jax.random.randint(ks[3], (b, h_kv, g_q), 0, s)
    qn = qt0 / jnp.linalg.norm(qt0, axis=-1, keepdims=True)
    for bi in range(b):
        for hi in range(h_kv):
            for gi in range(g_q):
                k = k.at[bi, hi, needle_pos[bi, hi, gi]].set(
                    2.5 * d**0.25 * qn[bi, hi, gi])
    k = k.astype(jnp.bfloat16)
    v = v.astype(jnp.bfloat16)
    q = q.astype(jnp.bfloat16)
    qt = q.reshape(b, h_kv, g_q, d)
    sc = jnp.einsum("bhgd,bhtd->bhgt", qt.astype(jnp.float32), k.astype(jnp.float32))
    p = jax.nn.softmax(sc / d**0.5, axis=-1)
    ref = jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))

    for bits in (8, 4, 2):
        cache = qcache.init_cache(b, h_kv, d, s, bits=bits, block_n=128)
        cache = qcache.prefill(cache, k, v, quant_impl="xla")
        out = catt.decode_attention(q, cache, impl="xla").reshape(b, h_kv, g_q, d)
        rel = float(np.linalg.norm(np.asarray(out) - np.asarray(ref))
                    / np.linalg.norm(np.asarray(ref)))
        cos = float(np.sum(np.asarray(out) * np.asarray(ref))
                    / (np.linalg.norm(np.asarray(out)) * np.linalg.norm(np.asarray(ref))))
        bl = kv_bytes_fp16(1, 8, 32768, 128)
        bq = kv_bytes_quant(1, 8, 32768, 128, bits)
        emit(
            f"accuracy.int{bits}", 0.0,
            f"rel_err={rel:.4f};cosine={cos:.6f};modeled_throughput_32k={bl/bq:.2f}x",
        )


if __name__ == "__main__":
    run()
