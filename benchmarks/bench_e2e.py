"""Paper Fig. 11/12 analogue: end-to-end decoding with low-bit KV cache.

(a) Single setting: per-token decode latency of a small llama-family model,
fp16-equivalent (bits=16 -> pure bf16 residual path unavailable, so we use
int8 as the near-lossless stand-in) vs int4 vs int2, on CPU at reduced size.
(b) Batches setting: serving throughput (tokens/s) through the slot engine.
(c) Modeled 128K single-batch speedup from cache-bytes (the bandwidth-bound
regime the paper reports 3x end-to-end on A100)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, kv_bytes_fp16, kv_bytes_quant, timeit
from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine


def run():
    base = smoke_config("llama3-8b")
    for bits in (8, 4, 2):
        cfg = base.with_(kv_bits=bits)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 1, 64
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        _, state = jax.jit(lambda p, b: model.prefill(p, b, 512))(
            params, {"tokens": tokens})
        step = jax.jit(model.decode_step)
        tok = tokens[:, -1:]
        us = timeit(step, params, state, tok, warmup=2, iters=5)
        bl = kv_bytes_fp16(1, 32 * 8, 131072, 128)
        bq = kv_bytes_quant(1, 32 * 8, 131072, 128, bits)
        emit(f"e2e.single_decode.int{bits}", us,
             f"modeled_128k_speedup={bl/bq:.2f}x")

    # batched serving throughput via the slot engine
    cfg = base.with_(kv_bits=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=4, max_seq=256)
    rng = np.random.default_rng(0)
    for uid in range(8):
        engine.submit(Request(uid=uid,
                              prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
                              max_new_tokens=8))
    stats = engine.run()
    emit("e2e.serve_batched.int4", stats["wall_s"] * 1e6,
         f"tokens_per_s={stats['tokens_per_s']:.1f};decoded={stats['decoded_tokens']}")


if __name__ == "__main__":
    run()
