"""Paper Fig. 8/9/10 analogue: decode-kernel performance across serving
settings (Single / Batches) × bits {16,4,2} × attention variants (MHA/GQA).

On CPU we report (a) measured XLA-path wall time at reduced sizes and (b) the
modeled HBM-bytes speedup vs the fp16 baseline at paper-scale sizes — decode
is bandwidth-bound (paper §II), so bytes-moved ratio is the TPU roofline
prediction of the kernel speedup the paper measures on GPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, kv_bytes_fp16, kv_bytes_quant,
                               make_decode_case, timeit)
from repro.core import attention as catt


def _fp16_decode(q, k, v):
    qt = q.reshape(q.shape[0], k.shape[1], -1, q.shape[-1])
    s = jnp.einsum("bhgd,bhtd->bhgt", qt.astype(jnp.float32), k.astype(jnp.float32))
    p = jax.nn.softmax(s / q.shape[-1] ** 0.5, axis=-1)
    return jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))


def run():
    d, block_n = 128, 128
    settings = [
        ("single-mha", dict(b=1, h_kv=8, g_q=1, s=4096)),
        ("single-gqa", dict(b=1, h_kv=2, g_q=4, s=4096)),
        ("batches-mha", dict(b=8, h_kv=8, g_q=1, s=2048)),
        ("batches-gqa", dict(b=8, h_kv=2, g_q=4, s=2048)),
    ]
    for name, kw in settings:
        q, cache16, (k, v) = make_decode_case(d=d, bits=8, block_n=block_n, **kw)
        fp16 = jax.jit(_fp16_decode)
        us16 = timeit(fp16, q, k, v)
        for bits in (4, 2):
            _, cache, _ = make_decode_case(d=d, bits=bits, block_n=block_n, **kw)
            fn = jax.jit(functools.partial(catt.decode_attention, impl="xla"))
            us = timeit(fn, q, cache)
            # paper-scale modeled speedup (S=128K) from bytes moved
            bl = kv_bytes_fp16(kw["b"], kw["h_kv"], 131072, d)
            bq = kv_bytes_quant(kw["b"], kw["h_kv"], 131072, d, bits, block_n)
            emit(
                f"kernel_decode.{name}.int{bits}", us,
                f"modeled_speedup_vs_fp16_128k={bl / bq:.2f}x;cpu_fp16_us={us16:.0f}",
            )


if __name__ == "__main__":
    run()
