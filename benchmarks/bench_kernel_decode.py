"""Paper Fig. 8/9/10 analogue: decode-kernel performance across serving
settings (Single / Batches) × bits {16,4,2} × attention variants (MHA/GQA),
plus the split-KV (FlashDecoding) num_splits sweep at the single-batch
long-context setting.

On CPU we report (a) measured XLA-path wall time at reduced sizes and (b) the
modeled HBM-bytes speedup vs the fp16 baseline at paper-scale sizes — decode
is bandwidth-bound (paper §II), so bytes-moved ratio is the TPU roofline
prediction of the kernel speedup the paper measures on GPUs.  The split-KV
sweep additionally records the roofline parallel-work model (exposed parallel
grid cells and per-core sequential depth) and appends each run to
BENCH_splitkv.json so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import functools
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, kv_bytes_fp16, kv_bytes_quant,
                               make_decode_case, timeit)
from repro.core import attention as catt
from repro.kernels.bitdecode import ops as bd_ops

_BENCH_SPLITKV = Path(__file__).resolve().parent.parent / "BENCH_splitkv.json"


def _fp16_decode(q, k, v):
    qt = q.reshape(q.shape[0], k.shape[1], -1, q.shape[-1])
    s = jnp.einsum("bhgd,bhtd->bhgt", qt.astype(jnp.float32), k.astype(jnp.float32))
    p = jax.nn.softmax(s / q.shape[-1] ** 0.5, axis=-1)
    return jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))


def run_splitkv_sweep(*, s=8192, out_path: Path | None = None):
    """num_splits sweep at the paper's headline regime: b=1, GQA h_kv=2,
    long context (nb = s / block_n packed blocks).

    Measured: XLA split-path wall time per num_splits (the CPU harness; on
    TPU the same sweep times the Pallas grid).  Modeled: bandwidth-bound
    roofline — a split-KV grid exposes ``b * h_kv * num_splits`` independent
    cells whose per-core sequential depth is ``ceil(nb/num_splits) + 1``
    blocks, so with >= num_splits cores the streaming time shrinks by
    (nb + 1) / depth while total bytes moved stay constant.
    """
    d, block_n, bits = 128, 128, 4
    b, h_kv, g_q = 1, 2, 4
    nb = s // block_n
    q, cache, _ = make_decode_case(b=b, h_kv=h_kv, g_q=g_q, d=d, s=s,
                                   bits=bits, block_n=block_n)
    cores = bd_ops.default_splitkv_cores()
    auto_ns = bd_ops.auto_num_splits(b, h_kv, nb)
    src = "env" if os.environ.get("REPRO_SPLITKV_CORES") else "device_count"
    emit(
        f"kernel_decode.splitkv.s{s}.auto", 0.0,
        f"auto_num_splits={auto_ns};cores_target={cores};source={src}",
    )
    records = []
    us_unsplit = None
    for ns in (1, 2, 4, 8):
        fn = jax.jit(functools.partial(
            catt.decode_attention, impl="xla", num_splits=ns))
        us = timeit(fn, q, cache)
        if ns == 1:
            us_unsplit = us
        depth = -(-nb // ns) + 1
        exposure = b * h_kv * ns
        modeled_speedup = (nb + 1) / depth
        rec = {
            "setting": f"single-gqa-long.b{b}.hkv{h_kv}.s{s}",
            "bits": bits,
            "num_splits": ns,
            "auto_num_splits": auto_ns,
            "splitkv_cores_target": cores,
            "measured_us": round(us, 1),
            "measured_speedup_vs_unsplit": round(us_unsplit / us, 3),
            "parallel_exposure": exposure,  # independent grid cells
            "sequential_depth_blocks": depth,
            "modeled_speedup_cores_ge_splits": round(modeled_speedup, 3),
        }
        records.append(rec)
        emit(
            f"kernel_decode.splitkv.s{s}.ns{ns}", us,
            f"exposure={exposure};depth={depth};"
            f"modeled_speedup={modeled_speedup:.2f}x",
        )
    out_path = _BENCH_SPLITKV if out_path is None else out_path
    history = []
    if out_path.exists():
        try:
            history = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            history = []
    history.append({"backend": jax.default_backend(), "records": records})
    out_path.write_text(json.dumps(history, indent=2) + "\n")
    return records


def run():
    run_splitkv_sweep()
    d, block_n = 128, 128
    settings = [
        ("single-mha", dict(b=1, h_kv=8, g_q=1, s=4096)),
        ("single-gqa", dict(b=1, h_kv=2, g_q=4, s=4096)),
        ("batches-mha", dict(b=8, h_kv=8, g_q=1, s=2048)),
        ("batches-gqa", dict(b=8, h_kv=2, g_q=4, s=2048)),
    ]
    for name, kw in settings:
        q, cache16, (k, v) = make_decode_case(d=d, bits=8, block_n=block_n, **kw)
        fp16 = jax.jit(_fp16_decode)
        us16 = timeit(fp16, q, k, v)
        for bits in (4, 2):
            _, cache, _ = make_decode_case(d=d, bits=bits, block_n=block_n, **kw)
            fn = jax.jit(functools.partial(catt.decode_attention, impl="xla"))
            us = timeit(fn, q, cache)
            # paper-scale modeled speedup (S=128K) from bytes moved
            bl = kv_bytes_fp16(kw["b"], kw["h_kv"], 131072, d)
            bq = kv_bytes_quant(kw["b"], kw["h_kv"], 131072, d, bits, block_n)
            emit(
                f"kernel_decode.{name}.int{bits}", us,
                f"modeled_speedup_vs_fp16_128k={bl / bq:.2f}x;cpu_fp16_us={us16:.0f}",
            )


if __name__ == "__main__":
    run()
