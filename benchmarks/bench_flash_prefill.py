"""Flash-prefill kernel: measured CPU-interpret parity with the oracle and
the analytic HBM-traffic model vs the XLA materialized-score path — the
quantified close of §Perf cells B/C's remaining memory term."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.flash_prefill import ops as fp_ops


def _analytic(b, hq, hkv, s, d, bq, bk):
    nq = -(-s // bq)
    flash = (
        b * hq * s * d * 2            # Q read
        + nq * b * hkv * s * d * 2 * 2  # K+V re-streamed per q block
        + b * hq * s * d * 2          # O write
    )
    # XLA path: score tile materialized f32 (dot out + exp read/write + pv read)
    xla = flash + b * hq * s * s * 4 * 3
    return flash, xla


def run():
    b, hq, hkv, d = 1, 4, 2, 128
    for s in (1024, 4096):
        q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, d)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d)).astype(jnp.bfloat16)
        fn = jax.jit(functools.partial(
            fp_ops.flash_prefill_attention, bq=256, bk=256, impl="xla"))
        us = timeit(fn, q, k, v)
        fl, xl = _analytic(b, hq, hkv, s, d, 256, 256)
        emit(f"flash_prefill.s{s}", us,
             f"kernel_hbm={fl/1e6:.1f}MB;xla_hbm={xl/1e6:.1f}MB;traffic_cut={xl/fl:.1f}x")
    # paper-scale: the starcoder2 prefill cell (§Perf C): per-device slice
    fl, xl = _analytic(2, 2, 1, 32768, 128, 512, 512)
    emit("flash_prefill.starcoder2_32k_perdev", 0.0,
         f"kernel_hbm={fl/1e9:.1f}GB;xla_hbm={xl/1e9:.1f}GB;traffic_cut={xl/fl:.0f}x")


if __name__ == "__main__":
    run()
