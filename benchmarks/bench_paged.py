"""Paper Fig. 8/9/10 "Page" setting: paged low-bit decode through the
scalar-prefetch kernel — scrambled page tables over a shared pool, per-seq
lengths, vs the dense kernel on the same content."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.kv_quant import ref as kq_ref
from repro.kernels.paged_bitdecode import ops as pg_ops


def run():
    b, h, g, d, block_n, nb = 4, 4, 4, 128, 128, 8
    n_pages = b * nb
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    for bits in (4, 2):
        k = jax.random.normal(ks[0], (1, h, n_pages * block_n, d)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[1], (1, h, n_pages * block_n, d)).astype(jnp.bfloat16)
        kw, ksc, kzp = kq_ref.quantize_kv_ref(k, bits, "channel", block_n=block_n)
        vw, vsc, vzp = kq_ref.quantize_kv_ref(v, bits, "tensor", block_n=block_n)
        pool = lambda x: jnp.moveaxis(x[0], 1, 0)  # noqa: E731
        q = jax.random.normal(ks[2], (b, h, g, d)).astype(jnp.bfloat16)
        k_res = jax.random.normal(ks[3], (b, h, block_n, d)).astype(jnp.bfloat16)
        v_res = jax.random.normal(ks[4], (b, h, block_n, d)).astype(jnp.bfloat16)
        table = jax.random.permutation(ks[5], n_pages).reshape(b, nb).astype(jnp.int32)
        pb = jnp.full((b,), nb, jnp.int32)
        rl = jnp.full((b,), 33, jnp.int32)
        fn = jax.jit(functools.partial(
            pg_ops.paged_bitdecode_attention, bits=bits, block_n=block_n,
            impl="xla"))
        us = timeit(fn, q, pool(kw), pool(ksc), pool(kzp), pool(vw), pool(vsc),
                    pool(vzp), k_res, v_res, table, pb, rl)
        emit(f"paged_decode.int{bits}", us,
             f"pages={n_pages};scrambled_table;per_seq_lengths")


if __name__ == "__main__":
    run()
