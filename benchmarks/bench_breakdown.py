"""Paper Table IV analogue: breakdown of BitDecoding's optimizations.

GPU knobs -> TPU analogues measured here:
  * lop3 layout remap  -> strided packing vs a transpose-requiring layout
    (consecutive packing needs an extra relayout before the matmul);
  * warp-efficient design -> query transformation on (g_q as matmul M) vs
    per-head GEMV loop;
  * async pipeline -> fused dequant+attention vs separate dequant kernel
    with a materialized fp16 cache round-trip (the KIVI-style non-fused path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_decode_case, timeit
from repro.core import attention as catt
from repro.core.layout import packing_ratio, qmax
from repro.kernels.bitdecode import ref as bd_ref


def _consecutive_unpack(w, bits, block_n):
    """Anti-optimization: consecutive token packing -> strided planes that
    must be interleaved (transpose) after extraction."""
    r = packing_ratio(bits)
    planes = [(w >> (bits * k)) & qmax(bits) for k in range(r)]
    st = jnp.stack(planes, axis=-2)  # [..., npr, R, d] -> interleave
    *lead, npr, _, dd = st.shape
    return st.reshape(*lead, npr * r, dd)


def run():
    b, h_kv, g_q, d, s, bits = 1, 4, 4, 128, 4096, 4
    q, cache, (k, v) = make_decode_case(b=b, h_kv=h_kv, g_q=g_q, d=d, s=s, bits=bits)

    # full fused path (all optimizations on)
    fused = jax.jit(functools.partial(catt.decode_attention, impl="xla"))
    us_all = timeit(fused, q, cache)
    emit("breakdown.fused_all_on", us_all, "strided+qtransform+fused")

    # (1) layout: consecutive packing with explicit interleave cost
    @jax.jit
    def unfused_layout(cache_kw):
        x = _consecutive_unpack(cache_kw, bits, cache.block_n)
        return x.sum()

    @jax.jit
    def strided_layout(cache_kw):
        from repro.core.layout import unpack_strided

        return unpack_strided(cache_kw, bits).sum()

    us_strided = timeit(strided_layout, cache.kw)
    us_consec = timeit(unfused_layout, cache.kw)
    emit("breakdown.unpack_strided", us_strided,
         f"vs_consecutive={us_consec/max(us_strided,1e-9):.2f}x")

    # (2) query transform: one (g_q x d) matmul vs per-head GEMV loop
    def per_head(qq, cache):
        outs = []
        for i in range(g_q):
            qi = qq[:, :, i::g_q][:, :, : h_kv]  # one head per kv group
            outs.append(catt.decode_attention(qi.reshape(b, 1, h_kv, d), cache, impl="xla"))
        return jnp.concatenate(outs, axis=2)

    us_gemv = timeit(jax.jit(per_head), q, cache)
    emit("breakdown.query_transform", us_all,
         f"vs_per_head_gemv={us_gemv/max(us_all,1e-9):.2f}x")

    # (3) fused vs non-fused (KIVI-style): dequantize whole cache to fp16 in
    # HBM, then run fp16 attention over it (extra round-trip)
    @jax.jit
    def non_fused(qq, cache):
        k_hat = bd_ref._dequant_blocks(cache.kw, cache.k_scale, cache.k_zero,
                                       cache.bits, cache.k_gran)
        v_hat = bd_ref._dequant_blocks(cache.vw, cache.v_scale, cache.v_zero,
                                       cache.bits, "tensor")
        # force materialization boundary (separate kernel in the paper)
        k_hat = jax.lax.optimization_barrier(k_hat)
        v_hat = jax.lax.optimization_barrier(v_hat)
        qt = qq.reshape(b, h_kv, g_q, d)
        sc = jnp.einsum("bhgd,bhtd->bhgt", qt.astype(jnp.float32),
                        k_hat.astype(jnp.float32))
        p = jax.nn.softmax(sc / d**0.5, axis=-1)
        return jnp.einsum("bhgt,bhtd->bhgd", p, v_hat.astype(jnp.float32))

    us_nonfused = timeit(non_fused, q, cache)
    emit("breakdown.fused_pipeline", us_all,
         f"vs_nonfused={us_nonfused/max(us_all,1e-9):.2f}x")


if __name__ == "__main__":
    run()
