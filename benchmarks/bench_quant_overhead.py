"""Paper Table II + Fig. 13 analogue: quantization+packing overhead.

Marlin/Ladder-style pre-transform is impossible for a dynamic KV cache; the
paper's point is that the fused Residual-Kernel path makes online
quantization negligible.  We measure (a) prefill-time fused quantize+pack of
a long context, (b) per-decode-step residual append (amortized flush), and
(c) the residual fraction of total cache bytes vs sequence length (Fig. 13)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import qcache
from repro.kernels.kv_quant import ops as kvq_ops


def run():
    b, h, d, block_n = 1, 8, 128, 128
    # (a) prefill quantize+pack (paper: Prefill row of Table II)
    for s in (4096, 16384):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), jnp.bfloat16)
        fn = jax.jit(functools.partial(
            kvq_ops.quantize_kv, bits=4, granularity="channel", impl="xla"))
        us = timeit(fn, x)
        gbps = (x.size * 2) / (us * 1e-6) / 1e9
        emit(f"quant_overhead.prefill_s{s}", us, f"throughput={gbps:.2f}GB/s")

    # (b) decode-step append incl. amortized flush (Table II Decode row)
    cache = qcache.init_cache(b, h, d, 4096, bits=4, block_n=block_n)
    kn = jax.random.normal(jax.random.PRNGKey(1), (b, h, 1, d), jnp.bfloat16)

    @jax.jit
    def append(c, kn):
        return qcache.append_decode(c, kn, kn)

    us = timeit(append, cache, kn)
    emit("quant_overhead.decode_append", us, "fused_residual_append")

    # (c) residual memory fraction vs seq len (Fig. 13): bf16 residual
    # (N_r tokens x 2B/elem) over the int4 packed cache (bits/8 B/elem)
    for s in (4096, 32768, 131072):
        res_frac = block_n * 2 / (s * 4 / 8 + block_n * 2)
        emit(f"quant_overhead.residual_frac_s{s}", 0.0, f"frac={res_frac:.4f}")


if __name__ == "__main__":
    run()
