"""Paper Table II + Fig. 13 analogue: quantization+packing overhead.

Marlin/Ladder-style pre-transform is impossible for a dynamic KV cache; the
paper's point is that the fused Residual-Kernel path makes online
quantization negligible.  We measure (a) prefill-time fused quantize+pack of
a long context, (b) per-decode-step residual append (amortized flush), (c)
the residual fraction of total cache bytes vs sequence length (Fig. 13), and
(d) the flush-vs-speculative sweep: the gated residual-flush append
(kernels/residual_flush — quantize only when the residual fills) against the
pre-fusion speculative path (re-quantize the whole block every token),
appended to BENCH_residual_flush.json so the trajectory is tracked across
PRs."""
from __future__ import annotations

import functools
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import qcache
from repro.kernels.kv_quant import ops as kvq_ops

_BENCH_RESIDUAL = Path(__file__).resolve().parent.parent / "BENCH_residual_flush.json"


def _cache_at_fill(b, h, d, *, bits, block_n, k_gran, res_len):
    """A cache whose residual holds ``res_len`` tokens (one packed block so
    the commit path has a real destination)."""
    s = block_n + res_len
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.bfloat16)
    cache = qcache.init_cache(
        b, h, d, 32 * block_n, bits=bits, block_n=block_n, k_gran=k_gran
    )
    return qcache.prefill(cache, k, k, quant_impl="xla")


def run_flush_sweep(*, out_path: Path | None = None):
    """Per-token append cost, gated flush vs speculative re-quantization.

    Two fill levels per case: *hot* (res_len = 1 after the append — the
    ``block_n - 1`` out of ``block_n`` steps where the gated path does no
    quantization work) and *flush* (res_len hits ``block_n`` and the
    residual-flush kernel commits one packed block).  The amortized
    per-token cost weights them (block_n-1):1; the speculative baseline pays
    its full quantize+pack+select on every step by construction.
    """
    b, h, d, block_n = 1, 8, 128, 128
    kn = jax.random.normal(jax.random.PRNGKey(1), (b, h, 1, d), jnp.bfloat16)
    records = []
    for bits, k_gran in ((4, "channel"), (2, "channel"), (4, "tensor")):
        # quant_impl="auto" so a TPU run times the fused Pallas flush (the
        # kernel this trajectory exists to track); on CPU auto resolves to
        # the XLA paths for both sides
        gated = jax.jit(functools.partial(qcache.append_decode, quant_impl="auto"))
        spec = jax.jit(
            functools.partial(qcache.append_decode_speculative, quant_impl="auto")
        )
        c_hot = _cache_at_fill(b, h, d, bits=bits, block_n=block_n,
                               k_gran=k_gran, res_len=0)
        c_edge = _cache_at_fill(b, h, d, bits=bits, block_n=block_n,
                                k_gran=k_gran, res_len=block_n - 1)
        us = {
            "gated_hot_us": timeit(gated, c_hot, kn, kn),
            "gated_flush_us": timeit(gated, c_edge, kn, kn),
            "speculative_hot_us": timeit(spec, c_hot, kn, kn),
            "speculative_flush_us": timeit(spec, c_edge, kn, kn),
        }
        amort_gated = (
            us["gated_hot_us"] * (block_n - 1) + us["gated_flush_us"]
        ) / block_n
        amort_spec = (
            us["speculative_hot_us"] * (block_n - 1) + us["speculative_flush_us"]
        ) / block_n
        rec = {
            "setting": f"b{b}.h{h}.d{d}.block{block_n}",
            "bits": bits,
            "k_gran": k_gran,
            "quant_impl": "auto",
            **{k: round(v, 1) for k, v in us.items()},
            "amortized_gated_us": round(amort_gated, 1),
            "amortized_speculative_us": round(amort_spec, 1),
            "amortized_speedup": round(amort_spec / amort_gated, 3),
        }
        records.append(rec)
        emit(
            f"quant_overhead.flush_sweep.int{bits}.{k_gran}",
            amort_gated,
            f"speculative_us={amort_spec:.1f};speedup={rec['amortized_speedup']}x",
        )
    out_path = _BENCH_RESIDUAL if out_path is None else out_path
    history = []
    if out_path.exists():
        try:
            history = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            history = []
    history.append({"backend": jax.default_backend(), "records": records})
    out_path.write_text(json.dumps(history, indent=2) + "\n")
    return records


def run():
    b, h, d, block_n = 1, 8, 128, 128
    # (a) prefill quantize+pack (paper: Prefill row of Table II)
    for s in (4096, 16384):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), jnp.bfloat16)
        fn = jax.jit(functools.partial(
            kvq_ops.quantize_kv, bits=4, granularity="channel", impl="xla"))
        us = timeit(fn, x)
        gbps = (x.size * 2) / (us * 1e-6) / 1e9
        emit(f"quant_overhead.prefill_s{s}", us, f"throughput={gbps:.2f}GB/s")

    # (b) decode-step append incl. amortized flush (Table II Decode row)
    cache = qcache.init_cache(b, h, d, 4096, bits=4, block_n=block_n)
    kn = jax.random.normal(jax.random.PRNGKey(1), (b, h, 1, d), jnp.bfloat16)

    @jax.jit
    def append(c, kn):
        return qcache.append_decode(c, kn, kn)

    us = timeit(append, cache, kn)
    emit("quant_overhead.decode_append", us, "gated_residual_append")

    # (c) residual memory fraction vs seq len (Fig. 13): bf16 residual
    # (N_r tokens x 2B/elem) over the int4 packed cache (bits/8 B/elem)
    for s in (4096, 32768, 131072):
        res_frac = block_n * 2 / (s * 4 / 8 + block_n * 2)
        emit(f"quant_overhead.residual_frac_s{s}", 0.0, f"frac={res_frac:.4f}")

    # (d) flush-vs-speculative sweep -> BENCH_residual_flush.json
    run_flush_sweep()


if __name__ == "__main__":
    run()
