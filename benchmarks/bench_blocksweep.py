"""Paper Table III analogue: parallelization-granularity sweep.

The GPU knob W_n (warps along the KV dimension, with cooperative softmax
restoring correctness) maps on TPU to the Pallas block_n / residual size: it
sets the per-step tile the grid pipeline overlaps, the VMEM working set, and
the online-softmax carry count.  We sweep block_n, validating correctness
against the fp16 oracle (the paper's "Valid" column) and reporting the VMEM
working set per grid step (the structural analogue of TC utilization —
reasoned from the lowered IR, per the dry-run methodology)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, make_decode_case, timeit
from repro.core import attention as catt


def run():
    b, h_kv, g_q, d, s, bits = 1, 4, 4, 128, 4096, 4
    # fp16 oracle once
    q, _, (k, v) = make_decode_case(b=b, h_kv=h_kv, g_q=g_q, d=d, s=s, bits=8)
    qt = q.reshape(b, h_kv, g_q, d)
    sc = jnp.einsum("bhgd,bhtd->bhgt", qt.astype(jnp.float32), k.astype(jnp.float32))
    ref = jnp.einsum("bhgt,bhtd->bhgd",
                     jax.nn.softmax(sc / d**0.5, axis=-1), v.astype(jnp.float32))

    for block_n in (128, 256, 512):
        q2, cache, _ = make_decode_case(
            b=b, h_kv=h_kv, g_q=g_q, d=d, s=s, bits=bits, block_n=block_n)
        fn = jax.jit(functools.partial(catt.decode_attention, impl="xla"))
        us = timeit(fn, q2, cache)
        out = fn(q2, cache).reshape(b, h_kv, g_q, d)
        rel = float(np.linalg.norm(np.asarray(out) - np.asarray(ref))
                    / np.linalg.norm(np.asarray(ref)))
        # validity = quantized result tracks the fp16 oracle; different
        # block_n legitimately changes quantization groups, so the bound is
        # the int4 noise floor, not equality across blocks
        valid = rel < 0.25
        # VMEM working set per grid step of the Pallas kernel:
        # packed K+V words + dequant tiles + q + acc (f32)
        npr = block_n // (32 // bits)
        vmem = (
            2 * npr * d * 4            # packed K,V words
            + 2 * block_n * d * 2      # dequantized bf16 tiles
            + 8 * d * 2                # q tile
            + 8 * d * 4 + 2 * 8 * 128 * 4  # acc + m/l carries
        )
        emit(
            f"blocksweep.block{block_n}", us,
            f"valid={valid};rel_err={rel:.4f};vmem_per_step={vmem/1024:.0f}KiB",
        )


if __name__ == "__main__":
    run()
