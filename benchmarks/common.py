"""Shared benchmark utilities: timing, case construction, CSV emission."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import qcache
from repro.kernels.kv_quant import ref as kq_ref


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall time of a jitted callable, in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def make_decode_case(*, b, h_kv, g_q, d, s, bits, block_n=128, k_gran="channel",
                     key=0):
    """Build a filled quantized cache + query for decode benchmarks."""
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    k = jax.random.normal(ks[0], (b, h_kv, s, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (b, h_kv, s, d), jnp.float32).astype(jnp.bfloat16)
    q = jax.random.normal(ks[2], (b, 1, h_kv * g_q, d), jnp.float32).astype(jnp.bfloat16)
    cache = qcache.init_cache(
        b, h_kv, d, s + block_n, bits=bits, block_n=block_n, k_gran=k_gran
    )
    cache = qcache.prefill(cache, k, v, quant_impl="xla")
    return q, cache, (k, v)


def kv_bytes_fp16(b, h, s, d):
    return 2 * b * h * s * d * 2  # K+V, fp16


def kv_bytes_quant(b, h, s, d, bits, block_n=128, k_gran="channel",
                   param_bytes=2):
    """Analytic HBM bytes of the packed cache + metadata (the fused kernel's
    definitional traffic)."""
    packed = 2 * b * h * s * d * bits / 8
    nb = s // block_n
    k_params = b * h * nb * d * 2 * param_bytes  # scale+zero per channel/block
    v_params = b * h * s * 2 * param_bytes  # per-token
    return packed + k_params + v_params
