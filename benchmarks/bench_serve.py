"""Serving-throughput sweeps for the paged continuous-batching engine.

Five sweeps, all appending to BENCH_serve.json so future PRs track them:

* **offered load** (default): requests arrive on a virtual clock (the
  measured engine wall time) at a configured rate with a prompt-length mix;
  each (rate x mix) cell reports end-to-end tokens/s, per-token latency
  percentiles, scheduler backpressure counts, and page-pool occupancy.
* **shared prefix** (``--shared-prefix``): a shared-fraction x prompt-length
  grid where every request's prompt begins with a common template prefix;
  each cell reports the prefix-index hit rate, prefill tokens actually
  computed vs. served from resident pages, pool pages used with vs. without
  sharing, and copy-on-write counts — the serving face of the prefix-sharing
  tentpole (docs/SERVING.md §4-5).
* **cache families** (``--family {attn,mla,hybrid}``): the same mixed
  workload through the unified paged engine per cache family — plain/GQA
  K/V pools, MLA shared-kv latent pools, hybrid paged-attention +
  dense SSM side-state — reporting per-family throughput, latency, and the
  per-family page byte size (``kv_page_bytes``; a hybrid page spans
  ``n_super`` layer-caches, an MLA page has no V stream).
* **oversubscription** (``--oversubscribe``): the pool capped at
  0.5x/0.75x/1.0x of the workload's worst-case concurrent page demand under
  ``reserve_policy="expected"`` — the pressure face of
  preemption-by-rematerialization (docs/SERVING.md §10): each cell reports
  the preemption rate, replayed (rematerialized) tokens, tokens/s, and
  occupancy, with the invariant auditor enabled every cycle.
* **self-speculative decoding** (``--spec-decode``): spec_k x spec_bits
  against the sequential baseline (docs/SERVING.md §11) — accepted-token
  rate, tokens per cycle, end-to-end speedup, and a bitwise-parity check
  of every output stream.
* **async runtime** (``--async-sweep``): the same offered-load curve
  through ``async_runtime=False`` and ``True`` (docs/SERVING.md §13) —
  per-cell tokens/s and ``host_stall_fraction`` before/after overlap, plus
  a bitwise-parity check; the acceptance bar is the async stall fraction
  strictly below the sync baseline on the same workload.
* **tenant churn** (``--tenant-churn``): rotating sessions over per-tenant
  shared system prompts where every session departs before the tenant's
  next arrives (docs/SERVING.md §14) — prefix hit rate, prefill tokens
  computed vs. saved, retained-hit and retained-reclaim counts with
  retention off and on, over an ample and a deliberately tight pool, plus
  the §14 bitwise oracles (cold first round, retained-hit == live-hit).
* **pool gauges** (``--pool-gauges``): host-side micro-bench of the
  allocator's gauge refresh — ``gauge_mode="incremental"`` vs ``"full"``
  microseconds per reserve/alloc/free round-trip.

Telemetry (docs/OBSERVABILITY.md): every offered-load cell reports TTFT and
TPOT percentiles (split latency series — queueing shows up in TTFT, steady
decode in TPOT) and the host-stall fraction (share of each cycle NOT spent
waiting on the device).  ``--phase-breakdown`` adds the per-phase seconds
(schedule / prefill / decode_dispatch / device_wait / advance) to each
record; ``--trace-out PATH`` traces the first cell and writes a Chrome
``trace_event`` JSON openable in Perfetto.

CPU smoke scale by default; the same sweeps run unchanged on TPU.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import smoke_config
from repro.launch import serve as _serve_cli
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine

_BENCH_SERVE = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# prompt-length mixes: (name, [(length, weight), ...])
_MIXES = [
    ("short", [(8, 0.7), (24, 0.3)]),
    ("mixed", [(8, 0.5), (48, 0.35), (96, 0.15)]),
]


def _make_requests(n, mix, max_new, vocab, rate_rps, rng):
    lengths = [l for l, _ in mix]
    weights = np.asarray([w for _, w in mix])
    weights = weights / weights.sum()
    # deterministic arrival spacing at the offered rate, jittered
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        plen = int(rng.choice(lengths, p=weights))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=max_new,
            arrival_s=float(arrivals[i]),
        ))
    return reqs


def run_serve_sweep(*, n_requests=8, max_new=8, slots=4, max_seq=256,
                    rates=(2.0, 16.0), out_path: Path | None = None,
                    time_scale=1.0, phase_breakdown=False,
                    trace_out: Path | None = None):
    """Offered-load sweep: rate (requests/s on the virtual clock) x prompt
    mix.  ``time_scale`` stretches the virtual clock (CPU cycles are slow;
    scale keeps arrival dynamics interesting at smoke sizes).
    ``phase_breakdown`` adds per-phase seconds to every record;
    ``trace_out`` traces the first cell into a Chrome trace_event JSON."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    records = []
    first_cell = True
    for mix_name, mix in _MIXES:
        for rate in rates:
            # deterministic per-cell seed (str hash is salted per process)
            rng = np.random.default_rng(zlib.crc32(f"{mix_name}:{rate}".encode()))
            reqs = _make_requests(n_requests, mix, max_new, cfg.vocab, rate, rng)
            trace_cell = trace_out is not None and first_cell
            first_cell = False
            engine = ServeEngine(model, params, slots=slots, max_seq=max_seq,
                                 trace=trace_cell)
            pending = sorted(reqs, key=lambda r: r.arrival_s)
            import time as _time

            t0 = _time.perf_counter()
            cycles = 0
            while pending or engine._has_work():
                now = (_time.perf_counter() - t0) * time_scale
                while pending and pending[0].arrival_s <= now:
                    engine.submit(pending.pop(0))
                if not engine._has_work():
                    # idle gap before the next arrival: jump the virtual clock
                    if pending:
                        engine.submit(pending.pop(0))
                    continue
                engine.step()
                cycles += 1
                if cycles > 20_000:
                    break
            stats = engine.summary(wall_s=_time.perf_counter() - t0)
            rec = {
                "mix": mix_name,
                "offered_rate_rps": rate,
                "n_requests": n_requests,
                "slots": slots,
                "decoded_tokens": stats["decoded_tokens"],
                "tokens_per_s": round(stats["tokens_per_s"], 2),
                "latency_p50_ms": round(stats["latency_p50_ms"], 2),
                "latency_p99_ms": round(stats["latency_p99_ms"], 2),
                "prefill_calls": stats["prefill_calls"],
                "backpressure_events": stats["sched_backpressure_events"],
                "occupancy_mean": round(stats["occupancy_mean"], 4),
                "occupancy_max": round(stats["occupancy_max"], 4),
                "ttft_p50_ms": round(stats["ttft_p50_ms"], 2),
                "ttft_p99_ms": round(stats["ttft_p99_ms"], 2),
                "tpot_p50_ms": round(stats["tpot_p50_ms"], 3),
                "tpot_p99_ms": round(stats["tpot_p99_ms"], 3),
                "queue_wait_p50_ms": round(stats["queue_wait_p50_ms"], 2),
                "host_stall_fraction": round(stats["host_stall_fraction"], 4),
            }
            if phase_breakdown:
                rec["phase_s"] = {
                    k: round(v, 5) for k, v in stats["phase_s"].items()
                }
            if trace_cell:
                engine.tracer.write_chrome(Path(trace_out))
                print(f"[bench_serve] trace ({mix_name} @ {rate:g} rps) -> "
                      f"{trace_out}")
            records.append(rec)
            emit(
                f"serve.{mix_name}.rps{rate:g}", stats["latency_p50_ms"] * 1e3,
                f"tok/s={rec['tokens_per_s']};p99_ms={rec['latency_p99_ms']}"
                f";occ_max={rec['occupancy_max']};prefills={rec['prefill_calls']}"
                f";ttft_p50_ms={rec['ttft_p50_ms']}"
                f";tpot_p50_ms={rec['tpot_p50_ms']}"
                f";host_stall={rec['host_stall_fraction']}",
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {"backend": jax.default_backend(), "records": records})
    return records


def _append(out_path: Path, entry: dict) -> None:
    history = []
    if out_path.exists():
        try:
            history = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            history = []
    history.append(entry)
    out_path.write_text(json.dumps(history, indent=2) + "\n")


def run_shared_prefix_sweep(*, shared_fracs=(0.0, 0.5, 0.9),
                            prompt_lens=(64, 128), n_requests=6, max_new=6,
                            slots=4, max_seq=256,
                            out_path: Path | None = None):
    """Shared-fraction x prompt-length grid through the prefix-sharing
    engine: every request's prompt starts with the same template prefix of
    ``frac * plen`` tokens (rounded down to whole ``kv_block`` blocks — the
    sharing granularity), followed by a private tail.  The first request is
    admitted alone so its pages register before the rest arrive (sharing is
    cross-cycle by design, docs/SERVING.md §4)."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    records = []
    for plen in prompt_lens:
        for frac in shared_fracs:
            rng = np.random.default_rng(
                zlib.crc32(f"shared:{plen}:{frac}".encode())
            )
            shared_len = int(frac * plen) // cfg.kv_block * cfg.kv_block
            prefix = rng.integers(0, cfg.vocab, shared_len).astype(np.int32)
            reqs = [
                Request(
                    uid=i,
                    prompt=np.concatenate([
                        prefix,
                        rng.integers(0, cfg.vocab, plen - shared_len).astype(np.int32),
                    ]),
                    max_new_tokens=max_new,
                )
                for i in range(n_requests)
            ]
            engine = ServeEngine(model, params, slots=slots, max_seq=max_seq)
            import time as _time

            t0 = _time.perf_counter()
            engine.submit(reqs[0])
            engine.step()  # register the template prefix
            for r in reqs[1:]:
                engine.submit(r)
            engine.run()
            stats = engine.summary(wall_s=_time.perf_counter() - t0)
            rec = {
                "prompt_len": plen,
                "shared_frac": frac,
                "shared_blocks": shared_len // cfg.kv_block,
                "n_requests": n_requests,
                "prefill_tokens": stats["prefill_tokens"],
                "prefill_tokens_saved": stats["prefill_tokens_saved"],
                "prefix_hit_rate": round(stats["prefix_hit_rate"], 4),
                "prefix_hit_requests": stats["sched_prefix_hit_requests"],
                "spec_tail_adoptions": stats["sched_spec_tail_adoptions"],
                "cow_copies": stats["cow_copies"],
                "tokens_per_s": round(stats["tokens_per_s"], 2),
                "occupancy_max": round(stats["occupancy_max"], 4),
            }
            records.append(rec)
            emit(
                f"serve.shared.L{plen}.f{frac:g}",
                stats["prefill_tokens"],
                f"saved={rec['prefill_tokens_saved']}"
                f";hit_rate={rec['prefix_hit_rate']}"
                f";cow={rec['cow_copies']};tok/s={rec['tokens_per_s']}",
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "shared_prefix",
        "records": records,
    })
    return records


# representative archs per cache family — shared with the serving CLI so
# the bench rows always exercise what `repro.launch.serve --family` runs
# (xlstm is CLI-only: the shim has no page accounting to sweep)
_FAMILY_ARCHS = {
    f: a for f, a in _serve_cli.FAMILY_ARCHS.items() if f != "xlstm"
}


def run_family_sweep(*, families=("attn", "mla", "hybrid"), n_requests=6,
                     max_new=8, slots=4, max_seq=256,
                     out_path: Path | None = None):
    """Per-cache-family serving sweep through the unified paged engine: the
    same mixed prompt-length workload per family, throughput/latency plus
    the per-family page accounting (kv_page_bytes differs: a hybrid page
    spans only the shared-attention invocations, an MLA latent page has no
    V stream at all)."""
    records = []
    for family in families:
        cfg = smoke_config(_FAMILY_ARCHS[family]).with_(kv_bits=4, kv_block=32)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(zlib.crc32(f"family:{family}".encode()))
        plens = [8, 40, 70]
        reqs = [
            Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab, plens[i % len(plens)]).astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(n_requests)
        ]
        engine = ServeEngine(model, params, slots=slots, max_seq=max_seq)
        import time as _time

        t0 = _time.perf_counter()
        for r in reqs:
            engine.submit(r)
        engine.run()
        stats = engine.summary(wall_s=_time.perf_counter() - t0)
        rec = {
            "family": family,
            "arch": cfg.name,
            "paged": engine.paged,
            "shared_kv": bool(engine.spec.shared_kv),
            "exact_prefill": bool(engine.spec.exact_prefill),
            "n_requests": n_requests,
            "decoded_tokens": stats["decoded_tokens"],
            "tokens_per_s": round(stats["tokens_per_s"], 2),
            "latency_p50_ms": round(stats["latency_p50_ms"], 2),
            "latency_p99_ms": round(stats["latency_p99_ms"], 2),
            "prefill_calls": stats["prefill_calls"],
            "kv_page_bytes": stats["kv_page_bytes"],
            "occupancy_max": round(stats["occupancy_max"], 4),
        }
        records.append(rec)
        emit(
            f"serve.family.{family}", stats["latency_p50_ms"] * 1e3,
            f"tok/s={rec['tokens_per_s']};p99_ms={rec['latency_p99_ms']}"
            f";page_B={rec['kv_page_bytes']};prefills={rec['prefill_calls']}",
        )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "family",
        "records": records,
    })
    return records


def run_oversubscribe_sweep(*, factors=(0.5, 0.75, 1.0), n_requests=6,
                            max_new=24, slots=2, max_seq=128,
                            out_path: Path | None = None):
    """Pressure sweep: the data-page pool capped at ``factor`` x the
    workload's worst-case concurrent page demand (the top-``slots``
    per-request page totals), run under ``reserve_policy="expected"`` with
    the most aggressive quantile (0.0 — reserve only what is certain).
    Undersized cells force preemption-by-rematerialization; every cell also
    runs once against an ample pool and checks the outputs are bitwise
    identical (docs/SERVING.md §10), with the invariant auditor on every
    cycle."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plens = [34, 48, 40, 44, 36, 46]

    def _reqs(rng):
        return [
            Request(
                uid=i,
                prompt=rng.integers(
                    0, cfg.vocab, plens[i % len(plens)]).astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(n_requests)
        ]

    import math
    import time as _time

    # worst-case concurrent demand: the `slots` largest per-request totals
    per_req = sorted(
        ((p + max_new) // cfg.kv_block for p in plens[:n_requests]),
        reverse=True,
    )
    worst = sum(per_req[:slots])

    # unpressured reference outputs (ample pool, worst-case reservations)
    base = ServeEngine(model, params, slots=slots, max_seq=max_seq)
    base_reqs = _reqs(np.random.default_rng(zlib.crc32(b"oversub")))
    for r in base_reqs:
        base.submit(r)
    base.run()
    base_out = {r.uid: list(r.out_tokens) for r in base_reqs}

    records = []
    for factor in factors:
        rng = np.random.default_rng(zlib.crc32(b"oversub"))
        n_pages = slots + math.ceil(factor * worst)
        engine = ServeEngine(
            model, params, slots=slots, max_seq=max_seq, n_pages=n_pages,
            reserve_policy="expected", expected_quantile=0.0, audit_every=1,
        )
        reqs = _reqs(rng)
        t0 = _time.perf_counter()
        for r in reqs:
            engine.submit(r)
        engine.run()
        stats = engine.summary(wall_s=_time.perf_counter() - t0)
        out = {r.uid: list(r.out_tokens) for r in reqs}
        rec = {
            "oversubscribe": factor,
            "n_pages": n_pages - slots,
            "worst_case_pages": worst,
            "n_requests": n_requests,
            "slots": slots,
            "preempted": stats["preempted"],
            "preemptions_per_request": round(
                stats["preempted"] / n_requests, 4),
            "preempt_remat_tokens": stats["preempt_remat_tokens"],
            "decoded_tokens": stats["decoded_tokens"],
            "tokens_per_s": round(stats["tokens_per_s"], 2),
            "latency_p50_ms": round(stats["latency_p50_ms"], 2),
            "latency_p99_ms": round(stats["latency_p99_ms"], 2),
            "backpressure_events": stats["sched_backpressure_events"],
            "occupancy_max": round(stats["occupancy_max"], 4),
            "audits": stats["audits"],
            "bitwise_match": out == base_out,
        }
        records.append(rec)
        emit(
            f"serve.oversub.x{factor:g}", stats["tokens_per_s"],
            f"preempted={rec['preempted']}"
            f";remat_tok={rec['preempt_remat_tokens']}"
            f";p99_ms={rec['latency_p99_ms']}"
            f";match={rec['bitwise_match']}",
        )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "oversubscribe",
        "records": records,
    })
    return records


def run_spec_decode_sweep(*, spec_ks=(2, 4), spec_bits=(2, 4), n_requests=6,
                          max_new=16, slots=2, max_seq=128,
                          out_path: Path | None = None):
    """Self-speculative decoding sweep (docs/SERVING.md §11): spec_k x
    spec_bits against the sequential ``spec_k=1`` baseline over the same
    workload.  Each cell reports the accepted-token rate (the fraction of
    truncated-bit draft tokens the full-fidelity verify kept), end-to-end
    tokens/s, the speedup over sequential decode, and a bitwise-parity
    check of every output stream — speculation must never change tokens,
    only the number of host round-trips per token."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plens = [34, 48, 40, 44, 36, 46]

    def _reqs():
        rng = np.random.default_rng(zlib.crc32(b"specdec"))
        return [
            Request(
                uid=i,
                prompt=rng.integers(
                    0, cfg.vocab, plens[i % len(plens)]).astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(n_requests)
        ]

    import time as _time

    base = ServeEngine(model, params, slots=slots, max_seq=max_seq)
    base_reqs = _reqs()
    t0 = _time.perf_counter()
    for r in base_reqs:
        base.submit(r)
    base.run()
    base_stats = base.summary(wall_s=_time.perf_counter() - t0)
    base_out = {r.uid: list(r.out_tokens) for r in base_reqs}
    base_tps = base_stats["tokens_per_s"]

    records = [{
        "spec_k": 1,
        "tokens_per_s": round(base_tps, 2),
        "decoded_tokens": base_stats["decoded_tokens"],
        "steps": base_stats["steps"],
    }]
    for k in spec_ks:
        for bits in spec_bits:
            engine = ServeEngine(
                model, params, slots=slots, max_seq=max_seq,
                spec_k=k, spec_bits=bits,
            )
            reqs = _reqs()
            t0 = _time.perf_counter()
            for r in reqs:
                engine.submit(r)
            engine.run()
            stats = engine.summary(wall_s=_time.perf_counter() - t0)
            out = {r.uid: list(r.out_tokens) for r in reqs}
            rec = {
                "spec_k": k,
                "spec_bits": bits,
                "n_requests": n_requests,
                "slots": slots,
                "decoded_tokens": stats["decoded_tokens"],
                "steps": stats["steps"],
                "spec_cycles": stats["spec_cycles"],
                "spec_draft_tokens": stats["spec_draft_tokens"],
                "spec_accepted_tokens": stats["spec_accepted_tokens"],
                "accept_rate": round(stats["spec_accept_rate"], 4),
                "tokens_per_cycle": round(
                    stats["decoded_tokens"] / max(1, stats["steps"]), 3),
                "tokens_per_s": round(stats["tokens_per_s"], 2),
                "speedup_vs_sequential": round(
                    stats["tokens_per_s"] / max(base_tps, 1e-9), 3),
                "bitwise_match": out == base_out,
            }
            records.append(rec)
            emit(
                f"serve.spec.k{k}.b{bits}", stats["tokens_per_s"],
                f"accept={rec['accept_rate']}"
                f";tok/cyc={rec['tokens_per_cycle']}"
                f";speedup={rec['speedup_vs_sequential']}"
                f";match={rec['bitwise_match']}",
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "spec_decode",
        "records": records,
    })
    return records


def run_async_sweep(*, rates=(2.0, 8.0, 16.0), n_requests=8, max_new=12,
                    slots=4, max_seq=256, time_scale=1.0,
                    out_path: Path | None = None):
    """Async-vs-sync offered-load curve (docs/SERVING.md §13): each rate
    cell drives the identical workload through the synchronous cycle and
    the overlapped runtime, recording tokens/s and ``host_stall_fraction``
    for both, a bitwise-parity check of the streams, and the async-side
    pipeline counters (in-flight window depth, discarded steps, starvation
    seconds).  The ISSUE 9 acceptance bar reads straight off these rows:
    async ``host_stall_fraction`` strictly below sync on the same cell."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mix_name, mix = _MIXES[1]  # the mixed prompt-length workload

    import time as _time

    records = []
    for rate in rates:
        outs = {}
        for runtime in ("sync", "async"):
            rng = np.random.default_rng(
                zlib.crc32(f"async:{mix_name}:{rate}".encode())
            )
            reqs = _make_requests(
                n_requests, mix, max_new, cfg.vocab, rate, rng
            )
            engine = ServeEngine(
                model, params, slots=slots, max_seq=max_seq,
                async_runtime=(runtime == "async"),
            )
            pending = sorted(reqs, key=lambda r: r.arrival_s)
            t0 = _time.perf_counter()
            cycles = 0
            while pending or engine._has_work():
                now = (_time.perf_counter() - t0) * time_scale
                while pending and pending[0].arrival_s <= now:
                    engine.submit(pending.pop(0))
                if not engine._has_work():
                    if pending:
                        engine.submit(pending.pop(0))
                    continue
                engine.step()
                cycles += 1
                if cycles > 20_000:
                    break
            stats = engine.summary(wall_s=_time.perf_counter() - t0)
            engine.close()
            outs[runtime] = {r.uid: list(r.out_tokens) for r in reqs}
            rec = {
                "mix": mix_name,
                "offered_rate_rps": rate,
                "runtime": runtime,
                "n_requests": n_requests,
                "slots": slots,
                "decoded_tokens": stats["decoded_tokens"],
                "tokens_per_s": round(stats["tokens_per_s"], 2),
                "host_stall_fraction": round(
                    stats["host_stall_fraction"], 4),
                "ttft_p50_ms": round(stats["ttft_p50_ms"], 2),
                "tpot_p50_ms": round(stats["tpot_p50_ms"], 3),
            }
            if runtime == "async":
                rec["discarded_steps"] = stats["discarded_steps"]
                rec["completions_enqueued"] = stats["completions_enqueued"]
                rec["device_starved_s"] = round(
                    engine.metrics.hist("device_starved_s").total, 5)
                rec["bitwise_match"] = outs["async"] == outs["sync"]
            records.append(rec)
            emit(
                f"serve.async.rps{rate:g}.{runtime}",
                stats["tokens_per_s"],
                f"host_stall={rec['host_stall_fraction']}"
                f";tpot_p50_ms={rec['tpot_p50_ms']}"
                + (f";match={rec['bitwise_match']}"
                   f";discarded={rec['discarded_steps']}"
                   if runtime == "async" else ""),
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "async_runtime",
        "records": records,
    })
    return records


def run_tenant_churn_sweep(*, n_tenants=3, rounds=2, max_new=26, slots=2,
                           max_seq=256, out_path: Path | None = None):
    """Tenant-churn sweep (docs/SERVING.md §14): ``n_tenants`` tenants, each
    with a fixed two-block system prompt, rotate short sessions through the
    engine one at a time — every session fully departs before the tenant's
    next one arrives, so without retention the shared prompt is re-prefilled
    every visit.  The rotation is skewed (tenant 0 returns between every
    other tenant's session — the popular-system-prompt shape), which under
    the tight pool keeps the hot tenant's retained set MRU while the cold
    tenants' sets are LRU-reclaimed: the tight cell shows *graceful*
    degradation (partial hit rate, nonzero reclaims, zero preemptions)
    rather than all-or-nothing.  Each pool cell runs the identical session
    stream with retention off and on, recording the prefix hit rate,
    prefill tokens computed vs. saved, retained-hit and retained-reclaim
    counts.

    Bitwise claims recorded per the §14 oracle doctrine (§9: sharing itself
    is not bitwise vs. a cold raw-bf16 prefill, so ON-vs-OFF full-stream
    identity is not the bar): cold first visits are identical with
    retention on and off, and a retained hit emits exactly the tokens of a
    *live* hit on the same prompt (donor still resident)."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    blk = cfg.kv_block

    # hot/cold rotation: [0, 1, 0, 2, ..., 0, n-1] per round
    schedule = []
    for _ in range(rounds):
        for cold in range(1, n_tenants):
            schedule += [0, cold]
    max_occ = max(schedule.count(t) for t in range(n_tenants))

    rng = np.random.default_rng(zlib.crc32(b"tenant-churn"))
    system = [
        rng.integers(0, cfg.vocab, 2 * blk).astype(np.int32)
        for _ in range(n_tenants)
    ]
    tails = [
        [rng.integers(0, cfg.vocab, 8 + 3 * o).astype(np.int32)
         for o in range(max_occ)]
        for _ in range(n_tenants)
    ]

    def sessions():
        """Churn stream: (uid, tenant, occurrence, prompt)."""
        occ = [0] * n_tenants
        for uid, t in enumerate(schedule):
            yield uid, t, occ[t], np.concatenate([system[t], tails[t][occ[t]]])
            occ[t] += 1

    def churn_run(retain, n_pages):
        engine = ServeEngine(
            model, params, slots=slots, max_seq=max_seq, n_pages=n_pages,
            retain_prefix=retain,
        )
        import time as _time

        outs = {}
        t0 = _time.perf_counter()
        for uid, t, o, prompt in sessions():
            req = Request(uid=uid, prompt=prompt, max_new_tokens=max_new)
            engine.submit(req)
            engine.run()  # session completes and departs before the next
            outs[(t, o)] = list(req.out_tokens)
        return engine.summary(wall_s=_time.perf_counter() - t0), outs

    # live-hit oracle for tenant 0's round-1 session: round 0 still
    # resident (long decode) when the round-1 prompt admits, retention off
    live = ServeEngine(model, params, slots=slots, max_seq=max_seq)
    la = Request(uid=0, prompt=np.concatenate([system[0], tails[0][0]]),
                 max_new_tokens=48)
    lb = Request(uid=1, prompt=np.concatenate([system[0], tails[0][1]]),
                 max_new_tokens=max_new)
    live.submit(la)
    live.step()
    live.submit(lb)
    live.run()
    live_hit_tokens = list(lb.out_tokens)

    # tight pool: capacity equals the aggregate retained footprint (2 full
    # system blocks per tenant); each session's decode crosses into a third
    # block (max_new spans a block boundary), so admissions on a fully
    # populated tier must reclaim LRU retained pages first
    tight = slots + 2 * n_tenants
    records = []
    for pool_name, n_pages in (("ample", None), ("tight", tight)):
        cell = {}
        for retain in (False, True):
            stats, outs = churn_run(retain, n_pages)
            cell[retain] = (stats, outs)
            rec = {
                "pool": pool_name,
                "retention": retain,
                "n_tenants": n_tenants,
                "rounds": rounds,
                "sessions": len(schedule),
                "prefix_hit_rate": round(stats["prefix_hit_rate"], 4),
                "prefill_tokens": stats["prefill_tokens"],
                "prefill_tokens_saved": stats["prefill_tokens_saved"],
                "prefix_retained_hits": stats["sched_prefix_retained_hits"],
                "retained_reclaims": stats["retained_reclaims"],
                "pool_pages_retained": stats["pool_pages_retained"],
                "preempted": stats["preempted"],
                "tokens_per_s": round(stats["tokens_per_s"], 2),
            }
            if retain:
                off_stats, off_outs = cell[False]
                on_outs = outs
                rec["hit_rate_gain"] = round(
                    stats["prefix_hit_rate"] - off_stats["prefix_hit_rate"],
                    4)
                rec["prefill_tokens_delta"] = (
                    stats["prefill_tokens"] - off_stats["prefill_tokens"])
                # cold first visits identical with retention on and off
                rec["first_visit_bitwise_match"] = all(
                    on_outs[(t, 0)] == off_outs[(t, 0)]
                    for t in range(n_tenants)
                )
                if pool_name == "ample":
                    # retained hit == live hit, bitwise (§14 oracle)
                    rec["retained_hit_matches_live_hit"] = (
                        on_outs[(0, 1)] == live_hit_tokens
                    )
            records.append(rec)
            emit(
                f"serve.churn.{pool_name}.{'on' if retain else 'off'}",
                stats["prefill_tokens"],
                f"hit_rate={rec['prefix_hit_rate']}"
                f";saved={rec['prefill_tokens_saved']}"
                f";retained_hits={rec['prefix_retained_hits']}"
                f";reclaims={rec['retained_reclaims']}",
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "tenant_churn",
        "records": records,
    })
    return records


def run_pool_gauge_bench(*, n_pages=258, n_scratch=2, iters=5000,
                         out_path: Path | None = None):
    """Host-side micro-bench of ``PagePool._update_gauges``: a pure-python
    reserve/alloc/free round-trip per iteration (three gauge refreshes)
    under ``gauge_mode="incremental"`` (cached instrument handles,
    skip-if-unchanged) vs. ``"full"`` (re-resolve every gauge by name,
    re-set all five).  The allocator runs on the host inside every decode
    cycle, so this overhead lands directly on the schedule phase."""
    import time as _time

    from repro.serve import PagePool
    from repro.serve.telemetry import MetricsRegistry

    results = {}
    for mode in ("incremental", "full"):
        pool = PagePool(n_pages, n_scratch=n_scratch,
                        metrics=MetricsRegistry(), gauge_mode=mode)
        # warm-up so both modes measure steady state, not first-touch
        for _ in range(100):
            pool.reserve(1)
            pool.free(pool.alloc())
        t0 = _time.perf_counter()
        for _ in range(iters):
            pool.reserve(1)
            pool.free(pool.alloc())
        results[mode] = (_time.perf_counter() - t0) / iters
    rec = {
        "iters": iters,
        "n_pages": n_pages,
        "incremental_us_per_op": round(results["incremental"] * 1e6, 3),
        "full_us_per_op": round(results["full"] * 1e6, 3),
        "speedup": round(results["full"] / max(results["incremental"], 1e-12),
                         3),
    }
    emit(
        "serve.pool_gauges", results["incremental"] * 1e6,
        f"full_us={rec['full_us_per_op']};speedup={rec['speedup']}",
    )
    out_path = _BENCH_SERVE if out_path is None else out_path
    _append(out_path, {
        "backend": jax.default_backend(),
        "sweep": "pool_gauges",
        "records": [rec],
    })
    return [rec]


def run():
    run_serve_sweep(phase_breakdown=True)
    run_shared_prefix_sweep()
    run_family_sweep()
    run_oversubscribe_sweep()
    run_spec_decode_sweep()
    run_async_sweep()
    run_tenant_churn_sweep()
    run_pool_gauge_bench()


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run only the shared-prefix grid")
    ap.add_argument("--family", nargs="*", choices=sorted(_FAMILY_ARCHS),
                    default=None,
                    help="run only the cache-family sweep (optionally a "
                         "subset of families)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="run only the pool-pressure sweep (0.5x/0.75x/1.0x "
                         "of worst-case page demand)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="run only the self-speculative decoding sweep "
                         "(spec_k x spec_bits vs the sequential baseline)")
    ap.add_argument("--async-sweep", action="store_true",
                    help="run only the async-vs-sync offered-load curve "
                         "(tokens/s + host_stall_fraction per runtime)")
    ap.add_argument("--tenant-churn", action="store_true",
                    help="run only the tenant-churn sweep (rotating "
                         "sessions over shared system prompts, retention "
                         "off vs on, ample vs tight pool)")
    ap.add_argument("--pool-gauges", action="store_true",
                    help="run only the PagePool gauge-mode micro-bench "
                         "(incremental vs full _update_gauges)")
    ap.add_argument("--phase-breakdown", action="store_true",
                    help="add per-phase seconds (schedule/prefill/"
                         "decode_dispatch/device_wait/advance) to every "
                         "offered-load record (docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="trace the first offered-load cell and write a "
                         "Chrome trace_event JSON (open in Perfetto)")
    args = ap.parse_args()
    if args.shared_prefix:
        run_shared_prefix_sweep()
    elif args.oversubscribe:
        run_oversubscribe_sweep()
    elif args.spec_decode:
        run_spec_decode_sweep()
    elif args.async_sweep:
        run_async_sweep()
    elif args.tenant_churn:
        run_tenant_churn_sweep()
    elif args.pool_gauges:
        run_pool_gauge_bench()
    elif args.family is not None:
        run_family_sweep(
            families=tuple(args.family) if args.family else
            ("attn", "mla", "hybrid"))
    elif args.phase_breakdown or args.trace_out:
        run_serve_sweep(
            phase_breakdown=args.phase_breakdown,
            trace_out=Path(args.trace_out) if args.trace_out else None,
        )
    else:
        run()
