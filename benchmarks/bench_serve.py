"""Serving-throughput sweep for the paged continuous-batching engine.

Offered-load model: requests arrive on a virtual clock (the measured engine
wall time) at a configured rate with a prompt-length mix; the engine admits
them through the scheduler as slots and pool pages free up.  Each
(rate x mix) cell reports end-to-end tokens/s, per-token latency percentiles
(p50/p99 over per-cycle wall time attributed to every token decoded in that
cycle), scheduler backpressure counts, and page-pool occupancy — the
serving-throughput trajectory is appended to BENCH_serve.json so future PRs
can track it.

CPU smoke scale by default; the same sweep runs unchanged on TPU.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine

_BENCH_SERVE = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# prompt-length mixes: (name, [(length, weight), ...])
_MIXES = [
    ("short", [(8, 0.7), (24, 0.3)]),
    ("mixed", [(8, 0.5), (48, 0.35), (96, 0.15)]),
]


def _make_requests(n, mix, max_new, vocab, rate_rps, rng):
    lengths = [l for l, _ in mix]
    weights = np.asarray([w for _, w in mix])
    weights = weights / weights.sum()
    # deterministic arrival spacing at the offered rate, jittered
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        plen = int(rng.choice(lengths, p=weights))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=max_new,
            arrival_s=float(arrivals[i]),
        ))
    return reqs


def run_serve_sweep(*, n_requests=8, max_new=8, slots=4, max_seq=256,
                    rates=(2.0, 16.0), out_path: Path | None = None,
                    time_scale=1.0):
    """Offered-load sweep: rate (requests/s on the virtual clock) x prompt
    mix.  ``time_scale`` stretches the virtual clock (CPU cycles are slow;
    scale keeps arrival dynamics interesting at smoke sizes)."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    records = []
    for mix_name, mix in _MIXES:
        for rate in rates:
            # deterministic per-cell seed (str hash is salted per process)
            rng = np.random.default_rng(zlib.crc32(f"{mix_name}:{rate}".encode()))
            reqs = _make_requests(n_requests, mix, max_new, cfg.vocab, rate, rng)
            engine = ServeEngine(model, params, slots=slots, max_seq=max_seq)
            pending = sorted(reqs, key=lambda r: r.arrival_s)
            import time as _time

            t0 = _time.perf_counter()
            cycles = 0
            while pending or engine._has_work():
                now = (_time.perf_counter() - t0) * time_scale
                while pending and pending[0].arrival_s <= now:
                    engine.submit(pending.pop(0))
                if not engine._has_work():
                    # idle gap before the next arrival: jump the virtual clock
                    if pending:
                        engine.submit(pending.pop(0))
                    continue
                engine.step()
                cycles += 1
                if cycles > 20_000:
                    break
            stats = engine.summary(wall_s=_time.perf_counter() - t0)
            rec = {
                "mix": mix_name,
                "offered_rate_rps": rate,
                "n_requests": n_requests,
                "slots": slots,
                "decoded_tokens": stats["decoded_tokens"],
                "tokens_per_s": round(stats["tokens_per_s"], 2),
                "latency_p50_ms": round(stats["latency_p50_ms"], 2),
                "latency_p99_ms": round(stats["latency_p99_ms"], 2),
                "prefill_calls": stats["prefill_calls"],
                "backpressure_events": stats["sched_backpressure_events"],
                "occupancy_mean": round(stats["occupancy_mean"], 4),
                "occupancy_max": round(stats["occupancy_max"], 4),
            }
            records.append(rec)
            emit(
                f"serve.{mix_name}.rps{rate:g}", stats["latency_p50_ms"] * 1e3,
                f"tok/s={rec['tokens_per_s']};p99_ms={rec['latency_p99_ms']}"
                f";occ_max={rec['occupancy_max']};prefills={rec['prefill_calls']}",
            )
    out_path = _BENCH_SERVE if out_path is None else out_path
    history = []
    if out_path.exists():
        try:
            history = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            history = []
    history.append({"backend": jax.default_backend(), "records": records})
    out_path.write_text(json.dumps(history, indent=2) + "\n")
    return records


def run():
    run_serve_sweep()


if __name__ == "__main__":
    run()
