"""Aggregate the dry-run roofline artifacts into the benchmark CSV (one row
per (arch x shape x mesh) cell) — the §Roofline table source."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def run():
    if not ART.exists():
        emit("roofline.missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in sorted(ART.glob("*.json")):
        rec = json.loads(f.read_text())
        t = rec["roofline"]
        emit(
            f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
            t["bound_s"] * 1e6,
            f"dom={t['dominant']};comp={t['compute_s']:.2e};"
            f"mem={t['memory_s']:.2e};coll={t['collective_s']:.2e};"
            f"useful={rec['useful_flops_ratio']:.2f}",
        )


if __name__ == "__main__":
    run()
