"""MoE dispatch correctness: capacity-based group-local top-k routing vs a
dense per-expert loop reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models import moe
from repro.models.params import init_tree


def _cfg(cf=8.0):
    return smoke_config("qwen3-moe-235b-a22b").with_(
        d_model=32, n_experts=4, top_k=2, d_expert=16, capacity_factor=cf
    )


def _dense_reference(p, cfg, x):
    """Same routing math, no capacity, explicit per-expert loop."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, cfg.top_k)
    if cfg.router_norm_topk:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)
    out = jnp.zeros_like(x, jnp.float32)
    for e in range(cfg.n_experts):
        h = jnp.einsum("bsd,df->bsf", x, p["wi"][e])
        u, g = jnp.split(h, 2, axis=-1)
        y = jnp.einsum("bsf,fd->bsd", u * jax.nn.silu(g), p["wo"][e]).astype(jnp.float32)
        w = jnp.sum(jnp.where(top_e == e, top_w, 0.0), axis=-1)
        out = out + w[..., None] * y
    return out


def test_moe_matches_dense_reference():
    cfg = _cfg(cf=8.0)  # capacity high enough that nothing drops
    p = init_tree(moe.moe_def(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.bfloat16)
    out, aux = moe.moe_ffn(p, cfg, x)
    ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=5e-2, atol=5e-2
    )
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    cfg = _cfg(cf=0.5)  # tight capacity: some tokens must drop, output finite
    p = init_tree(moe.moe_def(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.bfloat16)
    out, _ = moe.moe_ffn(p, cfg, x)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    # dropped tokens contribute zero, so norm is below the no-drop reference
    ref = _dense_reference(p, cfg, x)
    assert np.linalg.norm(np.asarray(out, np.float32)) <= np.linalg.norm(np.asarray(ref)) * 1.2


def test_moe_shared_expert():
    cfg = _cfg(cf=8.0).with_(n_shared_experts=1)
    p = init_tree(moe.moe_def(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.bfloat16)
    out, _ = moe.moe_ffn(p, cfg, x)
    assert out.shape == x.shape
