"""Per-kernel allclose tests: bitdecode Pallas kernel vs pure-jnp oracle,
plus fidelity vs the exact fp16 attention (paper Table I analogue)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.kv_quant import ref as kq_ref


def _make_case(key, *, b, h, g, d_k, d_v, nb, block_n, res_n, bits, k_gran,
               pack_blocks, res_len):
    ks = jax.random.split(key, 6)
    s_pack = nb * block_n
    k_full = jax.random.normal(ks[0], (b, h, s_pack, d_k), jnp.float32)
    k_full += 3.0 * jax.random.normal(ks[5], (d_k,), jnp.float32)  # outlier channels
    v_full = jax.random.normal(ks[1], (b, h, s_pack, d_v), jnp.float32)
    q = (jax.random.normal(ks[2], (b, h, g, d_k), jnp.float32) / d_k**0.25).astype(jnp.bfloat16)
    k_res = jax.random.normal(ks[3], (b, h, res_n, d_k), jnp.float32).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, res_n, d_v), jnp.float32).astype(jnp.bfloat16)

    kw, ksc, kzp = kq_ref.quantize_kv_ref(k_full.astype(jnp.bfloat16), bits, k_gran, block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(v_full.astype(jnp.bfloat16), bits, "tensor", block_n=block_n)
    pb = jnp.asarray(pack_blocks, jnp.int32)
    rl = jnp.asarray(res_len, jnp.int32)
    return dict(q=q, kw=kw, k_scale=ksc, k_zero=kzp, vw=vw, v_scale=vsc,
                v_zero=vzp, k_res=k_res, v_res=v_res, pack_blocks=pb, res_len=rl), \
           (k_full, v_full)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
@pytest.mark.parametrize("g", [1, 4, 16])
@pytest.mark.parametrize("d", [128, 256])
def test_bitdecode_pallas_matches_ref(bits, k_gran, g, d):
    b, h, nb, block_n = 2, 2, 3, 128
    case, _ = _make_case(
        jax.random.PRNGKey(0), b=b, h=h, g=g, d_k=d, d_v=d, nb=nb,
        block_n=block_n, res_n=block_n, bits=bits, k_gran=k_gran,
        pack_blocks=[nb, nb - 1], res_len=[37, 0],
    )
    fn = functools.partial(bd_ops.bitdecode_attention, bits=bits, block_n=block_n,
                           k_gran=k_gran, return_lse=True)
    out_p, lse_p = fn(**case, impl="pallas")
    out_r, lse_r = fn(**case, impl="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("g,d", [(3, 64), (7, 192)])
def test_bitdecode_unaligned_shapes(g, d):
    """Padding path: g not multiple of 8, d not multiple of 128."""
    bits, k_gran, b, h, nb, block_n = 4, "channel", 1, 2, 2, 128
    case, _ = _make_case(
        jax.random.PRNGKey(1), b=b, h=h, g=g, d_k=d, d_v=d, nb=nb,
        block_n=block_n, res_n=block_n, bits=bits, k_gran=k_gran,
        pack_blocks=[nb], res_len=[5],
    )
    fn = functools.partial(bd_ops.bitdecode_attention, bits=bits, block_n=block_n,
                           k_gran=k_gran, return_lse=True)
    out_p, lse_p = fn(**case, impl="pallas")
    out_r, lse_r = fn(**case, impl="xla")
    assert out_p.shape == (b, h, g, d)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r), rtol=1e-3, atol=1e-3)


def test_bitdecode_shared_kv_mla_mode():
    """MLA latent-cache mode: V = first d_v channels of the dequantized K."""
    bits, b, h, g, d_k, d_v, nb, block_n = 4, 1, 1, 16, 256, 128, 2, 128
    case, _ = _make_case(
        jax.random.PRNGKey(2), b=b, h=h, g=g, d_k=d_k, d_v=d_v, nb=nb,
        block_n=block_n, res_n=block_n, bits=bits, k_gran="channel",
        pack_blocks=[nb], res_len=[17],
    )
    case = dict(case)
    case["vw"] = case["v_scale"] = case["v_zero"] = None
    case["v_res"] = None
    # residual V must be the slice of residual K for shared mode
    fn = functools.partial(bd_ops.bitdecode_attention, bits=bits, block_n=block_n,
                           k_gran="channel", shared_kv=True, d_v=d_v, return_lse=True)
    out_p, lse_p = fn(**case, impl="pallas")
    out_r, lse_r = fn(**{**case, "v_res": case["k_res"][..., :d_v]}, impl="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits,max_err", [(8, 0.02), (4, 0.12), (2, 0.50)])
def test_bitdecode_fidelity_vs_fp16(bits, max_err):
    """Quantized attention tracks exact fp16 attention (Table I analogue).

    Thresholds are calibrated for iid-Gaussian K/V with outlier channels —
    near worst-case for quantization (real LLM keys are low-rank/structured
    and quantize far better, cf. KIVI).  The benchmark suite reports the
    measured fidelity curve; here we pin sane magnitudes and the 8<4<2-bit
    error ordering.
    """
    b, h, g, d, nb, block_n = 1, 4, 4, 128, 4, 128
    case, (k_full, v_full) = _make_case(
        jax.random.PRNGKey(3), b=b, h=h, g=g, d_k=d, d_v=d, nb=nb,
        block_n=block_n, res_n=block_n, bits=bits, k_gran="channel",
        pack_blocks=[nb], res_len=[64],
    )
    out_q = bd_ops.bitdecode_attention(**case, bits=bits, block_n=block_n,
                                       k_gran="channel", impl="xla")
    # exact fp16 oracle over the same tokens
    k_all = jnp.concatenate([k_full, case["k_res"][:, :, :64].astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([v_full, case["v_res"][:, :, :64].astype(jnp.float32)], axis=2)
    s = jnp.einsum("bhgd,bhtd->bhgt", case["q"].astype(jnp.float32), k_all) / d**0.5
    p = jax.nn.softmax(s, axis=-1)
    out_f = jnp.einsum("bhgt,bhtd->bhgd", p, v_all)
    rel = np.linalg.norm(np.asarray(out_q) - np.asarray(out_f)) / np.linalg.norm(np.asarray(out_f))
    assert rel < max_err, f"relative error {rel:.4f} exceeds {max_err}"
