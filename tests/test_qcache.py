"""Cache invariants: append/flush/prefill vs a plain fp16 history oracle.

Property tests (hypothesis) over lengths: for any number of appended tokens,
attention through the quantized cache tracks exact attention over the same
history, and the packed/residual partition always satisfies the paper's
invariants (res_len < N_r, length = pack_blocks * N_r + res_len).
"""
import functools

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as catt
from repro.core import qcache

jax.config.update("jax_platform_name", "cpu")

B, H, D, BLOCK = 2, 2, 64, 128
MAXSEQ = 4 * BLOCK


def _history(key, n):
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (B, H, n, D), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (B, H, n, D), jnp.float32).astype(jnp.bfloat16)
    return k, v


def _oracle(q, k, v):
    s = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32), k.astype(jnp.float32))
    p = jax.nn.softmax(s / q.shape[-1] ** 0.5, axis=-1)
    return jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))


@jax.jit
def _append_all(cache, k, v):
    def body(c, kv):
        kn, vn = kv
        return qcache.append_decode(c, kn[:, :, None], vn[:, :, None]), None

    cache, _ = jax.lax.scan(body, cache, (k.transpose(2, 0, 1, 3), v.transpose(2, 0, 1, 3)))
    return cache


@hypothesis.given(n=st.integers(min_value=1, max_value=3 * BLOCK + 17))
@hypothesis.settings(max_examples=12, deadline=None)
def test_append_matches_history_oracle(n):
    k, v = _history(jax.random.PRNGKey(n), n)
    cache = qcache.init_cache(B, H, D, MAXSEQ, bits=8, block_n=BLOCK)
    cache = _append_all(cache, k, v)

    # occupancy invariants (paper partition X = X_pack ∪ X_res)
    assert int(cache.res_len[0]) < BLOCK or BLOCK == int(cache.res_len[0]) == 0
    np.testing.assert_array_equal(np.asarray(cache.length), n)
    assert int(cache.pack_blocks[0]) == n // BLOCK

    q = (jax.random.normal(jax.random.PRNGKey(7 * n + 1), (B, 1, H * 2, D))).astype(jnp.bfloat16)
    out = catt.decode_attention(q, cache, impl="xla")
    # oracle over the exact same history, GQA expanded (g_q = 2)
    qt = q.reshape(B, H, 2, D)
    ref = _oracle(qt, k, v)
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, H, 2, D)), np.asarray(ref), rtol=0.08, atol=0.08
    )


@hypothesis.given(n=st.integers(min_value=1, max_value=MAXSEQ - BLOCK))
@hypothesis.settings(max_examples=10, deadline=None)
def test_prefill_equals_incremental_append(n):
    """prefill(L) and L × append produce identical attention outputs."""
    k, v = _history(jax.random.PRNGKey(1000 + n), n)
    c_inc = _append_all(qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK), k, v)
    c_pre = qcache.prefill(
        qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK), k, v, quant_impl="xla"
    )
    np.testing.assert_array_equal(np.asarray(c_inc.pack_blocks), np.asarray(c_pre.pack_blocks))
    np.testing.assert_array_equal(np.asarray(c_inc.res_len), np.asarray(c_pre.res_len))
    q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, H, D)).astype(jnp.bfloat16)
    o_inc = catt.decode_attention(q, c_inc, impl="xla")
    o_pre = catt.decode_attention(q, c_pre, impl="xla")
    np.testing.assert_allclose(np.asarray(o_inc), np.asarray(o_pre), rtol=1e-5, atol=1e-5)


def test_blockwise_attention_matches_naive():
    b, s, hq, hkv, d = 2, 192, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    out = catt.blockwise_attention(q, k, v, causal=True, block_k=64)
    # naive causal reference with GQA expansion
    kx = jnp.repeat(k, hq // hkv, axis=2)
    vx = jnp.repeat(v, hq // hkv, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, kx) / d**0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e37)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, axis=-1), vx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_mla_shared_cache_roundtrip():
    """Latent (shared_kv) cache: decode matches oracle on the latent stream."""
    d_lat, d_v, n = 128, 128, 200
    k = jax.random.normal(
        jax.random.PRNGKey(5), (B, H, n, d_lat), jnp.float32
    ).astype(jnp.bfloat16)
    cache = qcache.init_cache(B, H, d_lat, MAXSEQ, bits=8, block_n=BLOCK, shared_kv=True)

    def body(c, kn):
        return qcache.append_decode(c, kn[:, :, None], None), None

    cache, _ = jax.lax.scan(body, cache, k.transpose(2, 0, 1, 3))
    q = jax.random.normal(jax.random.PRNGKey(6), (B, 1, H * 4, d_lat)).astype(jnp.bfloat16)
    out = catt.decode_attention(q, cache, d_v=d_v, impl="xla")
    qt = q.reshape(B, H, 4, d_lat)
    ref = _oracle(qt, k, k[..., :d_v])
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, H, 4, d_v)), np.asarray(ref), rtol=0.08, atol=0.08
    )
