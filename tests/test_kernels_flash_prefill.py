"""Flash-prefill kernel vs oracle: shape/dtype sweep, GQA index-map mapping,
causal masking at block boundaries, non-causal (encoder) mode."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import ops as fp_ops
from repro.kernels.flash_prefill import ref as fp_ref


def _case(key, b, hq, hkv, s, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(jnp.bfloat16)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (4, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("s", [256, 384, 500])  # aligned, multi-block, ragged
def test_flash_prefill_matches_ref(hq, hkv, s):
    q, k, v = _case(jax.random.PRNGKey(0), 1, hq, hkv, s, 128)
    fn = functools.partial(fp_ops.flash_prefill_attention, bq=128, bk=128,
                           return_lse=True)
    out_p, lse_p = fn(q, k, v, impl="pallas")
    out_r, lse_r = fn(q, k, v, impl="xla")
    np.testing.assert_allclose(
        np.asarray(out_p, np.float32), np.asarray(out_r, np.float32),
        rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r),
                               rtol=1e-3, atol=1e-3)


def test_flash_prefill_non_causal():
    q, k, v = _case(jax.random.PRNGKey(1), 2, 4, 4, 256, 64)
    fn = functools.partial(fp_ops.flash_prefill_attention, causal=False,
                           bq=128, bk=128)
    out_p = fn(q, k, v, impl="pallas")
    out_r = fn(q, k, v, impl="xla")
    np.testing.assert_allclose(
        np.asarray(out_p, np.float32), np.asarray(out_r, np.float32),
        rtol=3e-2, atol=3e-2)


def test_flash_prefill_ref_matches_naive_f32():
    """The oracle itself against a plain f32 softmax attention."""
    b, h, s, d = 1, 2, 192, 64
    q, k, v = _case(jax.random.PRNGKey(2), b, h, h, s, d)
    out, _ = fp_ref.flash_prefill_ref(q, k, v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / d**0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    p = jax.nn.softmax(jnp.where(mask[None, None], scores, -1e37), axis=-1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)
