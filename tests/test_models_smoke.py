"""Per-architecture smoke tests: reduced same-family configs, one train step
+ prefill + decode on CPU; asserts output shapes and finiteness, plus
prefill↔decode logit consistency for cache-bearing archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import _REGISTRY, smoke_config
from repro.models.zoo import build_model

ARCHS = [n for n in _REGISTRY]


def make_batch(cfg, B=2, S=40, key=1):
    t = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)
    b = {"tokens": t, "labels": t, "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.encdec:
        b["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, 24, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_stub:
        b["patches"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch} bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 40
    batch = make_batch(cfg, B, S)

    logits, state = jax.jit(lambda p, b: m.prefill(p, b, 192))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    step = jax.jit(m.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, state = step(params, state, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch} decode logits not finite"
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", ["llama3_8b", "deepseek_v3_671b", "qwen2_vl_7b"])
def test_prefill_decode_consistency(arch):
    """Last-token logits via prefill(S) == prefill(S-1) + decode_step(token).

    With S < kv_block the history sits in the bf16 residual, so the decode
    path must agree with the fp16 prefill attention almost exactly."""
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 40
    batch = make_batch(cfg, B, S)
    lf, _ = jax.jit(lambda p, b: m.prefill(p, b, 128))(params, batch)

    bm1 = dict(batch)
    bm1["tokens"] = batch["tokens"][:, :-1]
    bm1["labels"] = batch["labels"][:, :-1]
    bm1["loss_mask"] = batch["loss_mask"][:, :-1]
    lp, state = jax.jit(lambda p, b: m.prefill(p, b, 128))(params, bm1)
    ld, _ = jax.jit(m.decode_step)(params, state, batch["tokens"][:, -1:])
    np.testing.assert_allclose(
        np.asarray(lf[:, 0]), np.asarray(ld[:, 0]), rtol=2e-2, atol=3e-1
    )
