"""Data pipeline determinism + serve engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec, smoke_config
from repro.data.pipeline import Prefetcher, batch_dims, batch_specs, make_batch
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine


def test_pipeline_deterministic():
    cfg = smoke_config("llama3-8b")
    shape = ShapeSpec("t", 32, 4, "train")
    b1 = make_batch(cfg, shape, step=3, seed=7)
    b2 = make_batch(cfg, shape, step=3, seed=7)
    b3 = make_batch(cfg, shape, step=4, seed=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert (np.asarray(b1["tokens"]) < cfg.vocab).all()


def test_pipeline_shapes_cover_families():
    shape = ShapeSpec("t", 64, 2, "train")
    for arch in ("llama3-8b", "qwen2-vl-7b", "seamless-m4t-medium"):
        cfg = smoke_config(arch)
        dims = batch_dims(cfg, shape)
        assert "tokens" in dims and "labels" in dims
        if cfg.vision_stub:
            assert dims["patches"][0][1] == cfg.n_patches
        if cfg.encdec:
            assert "frames" in dims
        specs = batch_specs(cfg, shape)
        assert set(specs) == set(dims)


def test_prefetcher():
    cfg = smoke_config("llama3-8b")
    shape = ShapeSpec("t", 16, 2, "train")
    pre = Prefetcher(cfg, shape, depth=2, start_step=5)
    try:
        s0, b0 = pre.next()
        s1, b1 = pre.next()
        assert (s0, s1) == (5, 6)
        assert b0["tokens"].shape == (2, 16)
    finally:
        pre.close()


def test_engine_serves_all_requests():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=2, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert stats["decoded_tokens"] == 20
