"""Checkpoint manager: atomic save/restore round-trip, keep-k GC, and
reshard-on-restore (different device layout via overlapping shard files)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "a": jax.random.normal(k, (16, 8), jnp.float32),
        "nested": {"b": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                   "c": jnp.float32(3.5)},
        "none_leaf": None,
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(7, t)
    restored, step = mgr.restore(None, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert sorted(mgr.all_steps()) == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save_async(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_into_struct(tmp_path):
    """Restore using only ShapeDtypeStructs as the target (fresh process)."""
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(1, t)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype) if x is not None else None,
        t, is_leaf=lambda x: x is None or hasattr(x, "shape"),
    )
    restored, _ = mgr.restore(1, target)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_reshard_restore(tmp_path):
    """Saved shards reassemble into a different slicing of the same array."""
    mgr = CheckpointManager(tmp_path, keep=2)
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, t)
    # simulate a resharded target by requesting regions directly
    import json
    files = list((tmp_path / "step_1").glob("*.npy"))
    assert files
    restored, _ = mgr.restore(1, t)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
