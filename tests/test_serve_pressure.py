"""Pressure-hardened serving: preemption-by-rematerialization under an
oversubscribed pool, request lifecycle guards (reject/cancel/expire/error
isolation), PagePool hardening, the invariant auditor, and the deterministic
fault-injection harness.

The acceptance bar (ISSUE 6): with the pool at half the worst-case
provisioning and ``reserve_policy="expected"``, every submitted request
completes with output tokens bitwise-identical to an unpressured run; every
injected fault (alloc-fail, forced-preempt, delayed-release, poisoned
logits row) recovers without crashing the engine, the auditor finds zero
violations at drain, and each scenario replays exactly from its seed.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve import (
    AuditError,
    FaultPlan,
    PagePool,
    Phase,
    Request,
    ServeEngine,
    audit_engine,
)

BLOCK = 32


# --------------------------------------------------------------------------
# PagePool hardening: every accounting breach raises at the faulting call
# --------------------------------------------------------------------------

def test_pagepool_free_scratch_page_raises():
    pool = PagePool(6, n_scratch=2)
    with pytest.raises(ValueError, match="scratch page 1"):
        pool.free(1)


def test_pagepool_double_free_raises_with_page_id():
    pool = PagePool(6, n_scratch=2)
    pool.reserve(1)
    page = pool.alloc()
    pool.free(page)
    with pytest.raises(ValueError, match=f"double free of page {page}"):
        pool.free(page)


def test_pagepool_free_by_non_holder_raises_naming_holder():
    pool = PagePool(6, n_scratch=2)
    pool.reserve(1, owner="alice")
    page = pool.alloc(owner="alice")
    with pytest.raises(ValueError, match="non-holder 'mallory'"):
        pool.free(page, owner="mallory")
    assert pool.refcount(page) == 1  # the bad call changed nothing
    pool.free(page, owner="alice")
    assert pool.n_free == pool.capacity


def test_pagepool_double_release_raises_naming_owner():
    pool = PagePool(8, n_scratch=2)
    assert pool.reserve(2, owner=7)
    pool.reserve(3)  # anonymous units stay lenient
    pool.release(2, owner=7)
    with pytest.raises(ValueError, match="double release: owner 7"):
        pool.release(1, owner=7)
    assert pool.reserved == 3


def test_pagepool_release_underflow_raises():
    pool = PagePool(8, n_scratch=2)
    pool.reserve(1)
    with pytest.raises(ValueError, match="exceeds reserved"):
        pool.release(2)


def test_pagepool_owner_alloc_beyond_its_reservation_raises():
    pool = PagePool(8, n_scratch=2)
    pool.reserve(1, owner="a")
    pool.reserve(1, owner="b")
    pool.alloc(owner="a")
    with pytest.raises(RuntimeError, match="owner 'a' exceeds"):
        pool.alloc(owner="a")  # would spend b's promised unit


def test_pagepool_retain_free_holder_tracking():
    pool = PagePool(6, n_scratch=2)
    pool.reserve(1, owner=1)
    page = pool.alloc(owner=1)
    pool.retain(page, owner=2)
    assert pool.holders(page) == [1, 2]
    pool.free(page, owner=1)
    assert pool.holders(page) == [2]
    pool.free(page, owner=2)
    assert pool.holders(page) == []


# --------------------------------------------------------------------------
# FaultPlan: seeded, replayable, per-site independent streams
# --------------------------------------------------------------------------

def test_faultplan_replays_bitwise_from_seed():
    a = FaultPlan(seed=13, alloc_fail=0.4, poison_logits=0.2)
    b = FaultPlan(seed=13, alloc_fail=0.4, poison_logits=0.2)
    seq_a = [(a.fires("alloc_fail", cycle=c), a.fires("poison_logits", cycle=c))
             for c in range(50)]
    seq_b = [(b.fires("alloc_fail", cycle=c), b.fires("poison_logits", cycle=c))
             for c in range(50)]
    assert seq_a == seq_b
    assert a.log == b.log
    assert any(x for x, _ in seq_a) and any(y for _, y in seq_a)


def test_faultplan_sites_are_independent_streams():
    """A site's decisions depend only on its own consultation count —
    consulting another site in between must not perturb them."""
    a = FaultPlan(seed=4, alloc_fail=0.5)
    pure = [a.fires("alloc_fail", cycle=c) for c in range(20)]
    b = FaultPlan(seed=4, alloc_fail=0.5, forced_preempt=0.5)
    mixed = []
    for c in range(20):
        b.fires("forced_preempt", cycle=c)  # interleaved consultation
        mixed.append(b.fires("alloc_fail", cycle=c))
    assert pure == mixed


def test_faultplan_fire_at_and_max_fires():
    fp = FaultPlan(seed=0, fire_at={"delayed_release": (2, 5)},
                   max_fires={"delayed_release": 1})
    hits = [fp.fires("delayed_release", cycle=c) for c in range(8)]
    assert hits == [False, False, True, False, False, False, False, False]
    assert fp.fired("delayed_release") == 1
    assert fp.consulted("delayed_release") == 8


def test_faultplan_rejects_bad_inputs():
    with pytest.raises(ValueError, match="outside"):
        FaultPlan(alloc_fail=1.5)
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(fire_at={"nonsense": (0,)})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan().fires("nonsense", cycle=0)


# --------------------------------------------------------------------------
# Engine fixtures and the canonical workload
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, n=5):
    """Deterministic mixed workload: multi-block prompts whose decode spans
    block boundaries (so flush-time page allocation — the preemption site —
    actually fires)."""
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(34, 48))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(24, 32)),
        ))
    return reqs


@pytest.fixture(scope="module")
def baseline_outputs(small_model):
    """Unpressured reference run: ample pages, worst-case reservations."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128)
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    return {r.uid: list(r.out_tokens) for r in reqs}


# --------------------------------------------------------------------------
# Tentpole: oversubscribed pool -> preemption -> bitwise-identical outputs
# --------------------------------------------------------------------------

def test_oversubscribed_pool_preempts_and_matches_baseline(
        small_model, baseline_outputs):
    """Half the worst-case provisioning, expected-case reservations: the
    engine must preempt (pool pressure is real), every request must still
    complete, and every output token must equal the unpressured run."""
    cfg, model, params = small_model
    # worst case for 2 concurrent requests of this workload: 2 slots x
    # ceil((48+32)/32) = 6 pages -> 0.5x = 3
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         n_pages=2 + 3, reserve_policy="expected",
                         expected_quantile=0.0, audit_every=1)
    reqs = _workload(cfg)
    for r in reqs:
        assert engine.submit(r)
    stats = engine.run()  # audit_every=1: every cycle cross-checked
    assert all(r.done for r in reqs), [r.phase for r in reqs]
    assert stats["preempted"] > 0, "no pressure exercised — test is vacuous"
    assert stats["preempt_remat_tokens"] > 0
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid], (
            f"request {r.uid} diverged after {r.preemptions} preemption(s)"
        )
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert audit_engine(engine).ok


def test_worst_case_policy_unchanged_no_preemption(small_model,
                                                   baseline_outputs):
    """``reserve_policy="worst_case"`` (the default) keeps the PR 3-5
    behavior bit for bit: backpressure instead of preemption, zero pressure
    stats, identical outputs."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128, n_pages=2 + 6)
    assert engine.sched.reserve_policy == "worst_case"
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    assert all(r.done for r in reqs)
    assert stats["preempted"] == 0
    assert stats["preempt_remat_tokens"] == 0
    assert all(r.preemptions == 0 for r in reqs)
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]


def test_expected_reservation_admits_more_concurrently(small_model):
    """The point of expected-case admission: a pool too small for two
    worst-case reservations still runs two requests concurrently."""
    cfg, model, params = small_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, 40).astype(np.int32)
               for _ in range(2)]
    # worst case 2 pages eac h ((40+24)//32); capacity 3 < 4
    mk = lambda: [Request(uid=i, prompt=p.copy(), max_new_tokens=24)
                  for i, p in enumerate(prompts)]

    wc = ServeEngine(model, params, slots=2, max_seq=128, n_pages=2 + 3)
    for r in (wc_reqs := mk()):
        wc.submit(r)
    wc.step()
    assert len(wc.sched.active) == 1  # head reserves 2, second can't

    ex = ServeEngine(model, params, slots=2, max_seq=128, n_pages=2 + 3,
                     reserve_policy="expected", expected_quantile=0.0)
    for r in (ex_reqs := mk()):
        ex.submit(r)
    ex.step()
    assert len(ex.sched.active) == 2  # both admitted under expectation
    wc.run()
    ex.run()
    assert all(r.done for r in wc_reqs) and all(r.done for r in ex_reqs)
    for a, b in zip(wc_reqs, ex_reqs):
        assert a.out_tokens == b.out_tokens


# --------------------------------------------------------------------------
# Lifecycle guards: reject, cancel, expire, poisoned-step isolation
# --------------------------------------------------------------------------

def test_submit_rejects_gracefully_and_strict_raises(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=64)
    bad = Request(uid=0, prompt=np.zeros(60, np.int32), max_new_tokens=32)
    assert engine.submit(bad) is False
    assert bad.phase == Phase.REJECTED and bad.finished
    assert "max_seq" in bad.error
    assert engine.sched.stats["rejected"] == 1
    tiny_pool = ServeEngine(model, params, slots=2, max_seq=128,
                            n_pages=2 + 1)  # capacity 1
    huge = Request(uid=1, prompt=np.zeros(40, np.int32), max_new_tokens=30)
    assert tiny_pool.submit(huge) is False  # needs 2 pages, pool holds 1
    assert "never be admitted" in huge.error
    assert not engine.sched.waiting and not tiny_pool.sched.waiting

    strict = ServeEngine(model, params, slots=2, max_seq=64, strict=True)
    with pytest.raises(ValueError, match="max_seq"):
        strict.submit(Request(uid=2, prompt=np.zeros(60, np.int32),
                              max_new_tokens=32))


def test_cancel_waiting_and_active_requests(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=1, max_seq=128)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
                    max_new_tokens=8) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.step()  # uid 0 active, uid 1/2 waiting
    got = engine.cancel(1)  # cancel while WAITING
    assert got is reqs[1] and got.phase == Phase.CANCELLED
    got = engine.cancel(0)  # cancel while DECODE: pages must come back
    assert got is reqs[0] and got.phase == Phase.CANCELLED
    assert engine.cancel(99) is None
    engine.run()
    assert reqs[2].done and len(reqs[2].out_tokens) == 8
    assert engine.stats["cancelled"] == 2
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_deadline_expires_waiting_and_active(small_model):
    cfg, model, params = small_model
    now = [0.0]
    engine = ServeEngine(model, params, slots=1, max_seq=128,
                         clock=lambda: now[0])
    rng = np.random.default_rng(4)
    mk = lambda uid, ttl: Request(
        uid=uid, prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
        max_new_tokens=8, deadline_s=ttl)
    a, b, c = mk(0, None), mk(1, 5.0), mk(2, 1000.0)
    for r in (a, b, c):
        engine.submit(r)
    engine.step()  # a active; b, c waiting
    now[0] = 10.0  # b's TTL passes while it waits
    engine.run()
    assert a.done and c.done
    assert b.phase == Phase.EXPIRED and "deadline_s" in b.error
    assert engine.stats["expired"] == 1
    # an *active* request expires mid-decode too
    d = mk(3, 2.0)
    engine.submit(d)
    engine.step()
    assert d in engine.sched.active.values()
    now[0] = 100.0
    engine.run()
    assert d.phase == Phase.EXPIRED
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_poisoned_logits_row_is_isolated(small_model, baseline_outputs):
    """A non-finite logits row retires only its own request (ERRORED, error
    recorded); every other request completes with baseline outputs."""
    cfg, model, params = small_model
    plan = FaultPlan(seed=1, fire_at={"poison_logits": (3,)},
                     max_fires={"poison_logits": 1})
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         faults=plan, audit_every=2)
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    engine.run()
    errored = [r for r in reqs if r.phase == Phase.ERRORED]
    assert len(errored) == 1
    assert "non-finite logits" in errored[0].error
    assert engine.stats["errored"] == 1
    for r in reqs:
        if r is errored[0]:
            continue
        assert r.done
        assert r.out_tokens == baseline_outputs[r.uid]
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


# --------------------------------------------------------------------------
# Fault scenarios: recover without crash, clean audit at drain, replayable
# --------------------------------------------------------------------------

def _run_faulted(small_model, plan, **engine_kw):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         faults=plan, audit_every=1, **engine_kw)
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    engine.run()
    return engine, reqs


def test_fault_alloc_fail_recovers_with_parity(small_model,
                                               baseline_outputs):
    plan = FaultPlan(seed=5, alloc_fail=0.3)
    engine, reqs = _run_faulted(small_model, plan)
    assert all(r.done for r in reqs), [r.phase for r in reqs]
    assert plan.fired("alloc_fail") > 0
    assert engine.stats["preempted"] > 0  # the recovery path actually ran
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok
    # reproducible from the seed: identical fault log AND outputs
    plan2 = FaultPlan(seed=5, alloc_fail=0.3)
    engine2, reqs2 = _run_faulted(small_model, plan2)
    assert plan2.log == plan.log
    assert [r.out_tokens for r in reqs2] == [r.out_tokens for r in reqs]
    assert engine2.stats["preempted"] == engine.stats["preempted"]


def test_fault_forced_preempt_recovers_with_parity(small_model,
                                                   baseline_outputs):
    plan = FaultPlan(seed=7, forced_preempt=0.15)
    engine, reqs = _run_faulted(small_model, plan)
    assert all(r.done for r in reqs)
    assert plan.fired("forced_preempt") > 0
    assert engine.stats["preempted"] >= plan.fired("forced_preempt") > 0
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_fault_delayed_release_drains_clean(small_model, baseline_outputs):
    plan = FaultPlan(seed=9, delayed_release=1.0, delay_cycles=3)
    engine, reqs = _run_faulted(small_model, plan)
    assert all(r.done for r in reqs)
    assert plan.fired("delayed_release") > 0
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]
    # the run loop kept stepping until every parked page was serviced
    assert not engine._deferred
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_fault_storm_under_oversubscription(small_model, baseline_outputs):
    """Everything at once: oversubscribed pool, expected reservations,
    prefix retention, and random alloc-fail + forced-preempt +
    delayed-release + evict-storm — the union of recovery paths still
    yields bitwise-identical outputs and a clean drain."""
    plan = FaultPlan(seed=21, alloc_fail=0.1, forced_preempt=0.1,
                     delayed_release=0.5, delay_cycles=2,
                     evict_storm=0.2, storm_pages=2)
    engine, reqs = _run_faulted(
        small_model, plan, n_pages=2 + 4,
        reserve_policy="expected", expected_quantile=0.25,
        retain_prefix=True,
    )
    assert all(r.done for r in reqs), [r.phase for r in reqs]
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]
    # retained pages are drained-but-resident: the tier plus the free list
    # must account for every capacity page, none reserved
    assert engine.pool.n_free + engine.pool.n_retained == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert plan.fired("evict_storm") > 0
    assert audit_engine(engine).ok


# --------------------------------------------------------------------------
# The auditor itself: seeded corruptions must each be named
# --------------------------------------------------------------------------

@pytest.fixture()
def drained_engine(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128)
    rng = np.random.default_rng(8)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                    max_new_tokens=4) for i in range(2)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    report = audit_engine(engine)
    assert report.ok, report.violations  # clean before corruption
    return engine


def test_audit_detects_leaked_page(drained_engine):
    engine = drained_engine
    pool = engine.pool
    page = pool._free.popleft()  # vanish a free page: held by nobody live
    pool._refcount[page] = 1
    pool._holders[page] = ["ghost"]
    report = audit_engine(engine)
    assert not report.ok
    assert any("leaked page" in v and str(page) in v
               for v in report.violations), report.violations
    with pytest.raises(AuditError, match="leaked page"):
        report.raise_if_violations()


def test_audit_detects_dangling_index_node(drained_engine):
    engine = drained_engine
    index = engine.sched.index
    page = engine.pool.n_scratch  # free at drain
    digest = b"\x01" * 20
    index._page_of[digest] = page
    index._meta[page] = (digest, index.root, np.zeros(BLOCK, np.int32))
    index._children.setdefault(index.root, []).append(page)
    report = audit_engine(engine)
    assert any("dangling prefix-index node" in v and str(page) in v
               for v in report.violations), report.violations


def test_audit_detects_table_pointing_at_freed_page(drained_engine):
    engine = drained_engine
    page = engine.pool.n_scratch + 1  # free at drain
    engine._table[0, 0] = page
    report = audit_engine(engine)
    assert any("points at freed page" in v and str(page) in v
               for v in report.violations), report.violations


def test_audit_detects_refcount_holder_drift(drained_engine):
    engine = drained_engine
    pool = engine.pool
    pool.reserve(1, owner="x")
    page = pool.alloc(owner="x")
    pool._refcount[page] = 2  # drift: refcount says 2, holders list says 1
    report = audit_engine(engine)
    assert any("holder" in v and str(page) in v
               for v in report.violations), report.violations
    pool._refcount[page] = 1  # restore so teardown stays sane


def test_audit_clean_on_live_engine_every_cycle(small_model):
    """audit_every=1 runs the cross-check between every decode step of a
    prefix-sharing COW workload — any transient desync would raise."""
    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab, 40).astype(np.int32)
    engine = ServeEngine(model, params, slots=2, max_seq=128, audit_every=1)
    reqs = []
    for i in range(4):  # shared 40-token prefix, divergent tails -> COW
        tail = rng.integers(0, cfg.vocab, 6).astype(np.int32)
        reqs.append(Request(uid=i,
                            prompt=np.concatenate([base, tail]),
                            max_new_tokens=6))
    for r in reqs:
        engine.submit(r)
    stats = engine.run()  # raises AuditError on any violation
    assert all(r.done for r in reqs)
    assert stats["audits"] >= stats["steps"]


# --------------------------------------------------------------------------
# Lifecycle edges: cancellation mid-admission, colliding retirement causes
# --------------------------------------------------------------------------

def test_cancel_while_waiting_and_mid_prefill(small_model):
    """cancel() must clean up a request at every pre-decode stage: still
    WAITING in the queue, and already admitted to a slot (phase PREFILL,
    pages reserved) but not yet prefilled/adopted."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=1, max_seq=128)
    rng = np.random.default_rng(13)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    # drive admission without the rest of the cycle: uid 0 lands in the
    # slot in phase PREFILL, uid 1/2 stay WAITING
    engine.sched.admit()
    assert reqs[0].phase == Phase.PREFILL
    assert reqs[1].phase == Phase.WAITING

    got = engine.cancel(1)  # WAITING: dequeue + retire, no resources held
    assert got is reqs[1] and got.phase == Phase.CANCELLED
    assert not got.pages and got.reserved_pages == 0

    got = engine.cancel(0)  # mid-PREFILL: slot + reservation must return
    assert got is reqs[0] and got.phase == Phase.CANCELLED
    assert engine.pool.owner_reserved(0) == 0
    assert 0 not in {r.uid for r in engine.sched.active.values()}
    audit_engine(engine).raise_if_violations()

    engine.run()  # uid 2 proceeds through the freed slot
    assert reqs[2].done and len(reqs[2].out_tokens) == 6
    assert engine.stats["cancelled"] == 2
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert audit_engine(engine).ok


def test_deadline_expiry_same_cycle_as_forced_preempt(small_model):
    """A deadline that lapses on the very cycle a forced-preempt fault
    fires: expiry runs first (the request retires EXPIRED, never preempted),
    the preemption then picks its victim among the survivors, and the run
    still drains clean with every survivor completing."""
    cfg, model, params = small_model
    now = [0.0]
    # forced_preempt is consulted once per cycle from cycle 1, so the
    # 0-based consultation index 3 is cycle 4 — the expiry cycle below
    plan = FaultPlan(seed=17, fire_at={"forced_preempt": (3,)},
                     max_fires={"forced_preempt": 1})
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         faults=plan, audit_every=1, clock=lambda: now[0])
    rng = np.random.default_rng(17)
    mk = lambda uid, ttl: Request(
        uid=uid, prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
        max_new_tokens=12, deadline_s=ttl)
    doomed, survivor_a, survivor_b = mk(0, 5.0), mk(1, None), mk(2, None)
    for r in (doomed, survivor_a, survivor_b):
        engine.submit(r)
    for _ in range(3):
        engine.step()
    assert doomed.phase == Phase.DECODE
    now[0] = 10.0  # doomed's TTL lapses; cycle 4 also fires forced_preempt
    engine.run()
    assert doomed.phase == Phase.EXPIRED
    assert doomed.preemptions == 0  # expiry won the cycle, preempt skipped it
    assert plan.fired("forced_preempt") == 1
    assert engine.stats["expired"] == 1
    assert survivor_a.done and survivor_b.done
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_poison_fault_on_retirement_cycle(small_model):
    """The fault fires on the exact cycle the request would retire DONE at
    its token budget: the poisoned-step check precedes the budget check, so
    the request retires ERRORED (not DONE), counts in ``errored`` only, and
    still records the token that produced the poisoned row."""
    cfg, model, params = small_model
    rng = np.random.default_rng(19)
    req = Request(uid=0,
                  prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                  max_new_tokens=4)
    # cycle 1 = admit + first decoded token; the budget's 4th token lands
    # on cycle 4 = the site's 4th consultation (0-based index 3)
    plan = FaultPlan(seed=23, fire_at={"poison_logits": (3,)},
                     max_fires={"poison_logits": 1})
    engine = ServeEngine(model, params, slots=1, max_seq=128,
                         faults=plan, audit_every=1)
    engine.submit(req)
    engine.run()
    assert plan.fired("poison_logits") == 1
    assert req.phase == Phase.ERRORED
    assert "non-finite logits" in req.error
    assert len(req.out_tokens) == 4  # the poisoned cycle's token is kept
    assert engine.stats["errored"] == 1
    assert engine.stats["budget_retired"] == 0  # ERRORED, not budget DONE
    assert engine.pool.n_free == engine.pool.capacity
    assert audit_engine(engine).ok


def test_identically_seeded_runs_are_deterministic(small_model):
    """Two engines built from the same params, workload seed, and FaultPlan
    seed must produce identical token streams, fault logs, and summaries
    (timing fields excluded — everything counted must replay exactly)."""
    cfg, model, params = small_model

    def one_run():
        plan = FaultPlan(seed=31, alloc_fail=0.2, forced_preempt=0.1)
        engine = ServeEngine(model, params, slots=2, max_seq=128,
                             n_pages=2 + 4, reserve_policy="expected",
                             expected_quantile=0.25, faults=plan,
                             audit_every=1)
        reqs = _workload(cfg)
        for r in reqs:
            engine.submit(r)
        summary = engine.run()
        return plan, reqs, summary

    plan1, reqs1, sum1 = one_run()
    plan2, reqs2, sum2 = one_run()
    assert plan1.log == plan2.log
    assert [r.out_tokens for r in reqs1] == [r.out_tokens for r in reqs2]
    assert [r.phase for r in reqs1] == [r.phase for r in reqs2]
    from repro.serve import TIMING_SUMMARY_KEYS
    strip = lambda s: {k: v for k, v in s.items()
                       if k not in TIMING_SUMMARY_KEYS}
    assert strip(sum1) == strip(sum2)
