"""Docs exist and don't drift: the README kernel-inventory table must track
src/repro/kernels/*/ (scripts/check_docs.py), and the first-class docs
surface must be present."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_surface_exists():
    for rel in ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md",
                "docs/SERVING.md"):
        path = REPO / rel
        assert path.exists(), f"missing {rel}"
        assert path.stat().st_size > 500, f"{rel} is a stub"


def test_kernel_inventory_in_sync():
    mod = _load_check_docs()
    errors = mod.check()
    assert not errors, "\n".join(errors)


def test_check_docs_detects_drift():
    """The checker actually fails when a family is undocumented (guards
    against a regex rot that silently matches nothing)."""
    mod = _load_check_docs()
    documented = mod.documented_families((REPO / "README.md").read_text())
    assert "residual_flush" in documented
    assert "bitdecode" in documented
    broken = (REPO / "README.md").read_text().replace("`residual_flush`", "`x`")
    assert mod.documented_families(broken) != documented


def test_serving_doc_symbols_resolve_and_drift_detected():
    """docs/SERVING.md's dotted repro.* references resolve; a bogus symbol,
    flag, or counter is caught (guards the new serving-doc checks against
    regex rot)."""
    mod = _load_check_docs()
    text = (REPO / "docs" / "SERVING.md").read_text()
    syms = mod.serving_symbols(text)
    assert "repro.serve.scheduler.PrefixIndex" in syms
    assert "repro.core.qcache.copy_pages" in syms
    assert not mod.check_serving(text)
    assert mod.check_serving(text + "\nsee `repro.serve.engine.NoSuchThing`")
    assert mod.check_serving(
        text.replace("| `share_prefix` |", "| `share_prefixes` |"))
    assert mod.check_serving(
        text.replace("| `cow_copies` |", "| `cow_copy_total` |"))


def test_serving_doc_flags_match_engine_signature():
    """Every ServeEngine sharing-related flag is documented: the doc's flag
    table must include the knobs the tests exercise."""
    mod = _load_check_docs()
    text = (REPO / "docs" / "SERVING.md").read_text()
    flags = mod.table_rows(text, "Engine flags")
    assert {"share_prefix", "spec_tail", "paged", "n_pages"} <= flags
