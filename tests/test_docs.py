"""Docs exist and don't drift: the README kernel-inventory table must track
src/repro/kernels/*/ (scripts/check_docs.py), and the first-class docs
surface must be present."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_surface_exists():
    for rel in ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md"):
        path = REPO / rel
        assert path.exists(), f"missing {rel}"
        assert path.stat().st_size > 500, f"{rel} is a stub"


def test_kernel_inventory_in_sync():
    mod = _load_check_docs()
    errors = mod.check()
    assert not errors, "\n".join(errors)


def test_check_docs_detects_drift():
    """The checker actually fails when a family is undocumented (guards
    against a regex rot that silently matches nothing)."""
    mod = _load_check_docs()
    documented = mod.documented_families((REPO / "README.md").read_text())
    assert "residual_flush" in documented
    assert "bitdecode" in documented
    broken = (REPO / "README.md").read_text().replace("`residual_flush`", "`x`")
    assert mod.documented_families(broken) != documented
