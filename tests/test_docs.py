"""Docs exist and don't drift: the README kernel-inventory table must track
src/repro/kernels/*/ (scripts/check_docs.py), and the first-class docs
surface must be present."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_surface_exists():
    for rel in ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md",
                "docs/SERVING.md", "docs/OBSERVABILITY.md"):
        path = REPO / rel
        assert path.exists(), f"missing {rel}"
        assert path.stat().st_size > 500, f"{rel} is a stub"


def test_kernel_inventory_in_sync():
    mod = _load_check_docs()
    errors = mod.check()
    assert not errors, "\n".join(errors)


def test_check_docs_detects_drift():
    """The checker actually fails when a family is undocumented (guards
    against a regex rot that silently matches nothing)."""
    mod = _load_check_docs()
    documented = mod.documented_families((REPO / "README.md").read_text())
    assert "residual_flush" in documented
    assert "bitdecode" in documented
    broken = (REPO / "README.md").read_text().replace("`residual_flush`", "`x`")
    assert mod.documented_families(broken) != documented


def test_serving_doc_symbols_resolve_and_drift_detected():
    """docs/SERVING.md's dotted repro.* references resolve; a bogus symbol,
    flag, or counter is caught (guards the new serving-doc checks against
    regex rot)."""
    mod = _load_check_docs()
    text = (REPO / "docs" / "SERVING.md").read_text()
    syms = mod.serving_symbols(text)
    assert "repro.serve.scheduler.PrefixIndex" in syms
    assert "repro.core.qcache.copy_pages" in syms
    assert not mod.check_serving(text)
    assert mod.check_serving(text + "\nsee `repro.serve.engine.NoSuchThing`")
    assert mod.check_serving(
        text.replace("| `share_prefix` |", "| `share_prefixes` |"))
    assert mod.check_serving(
        text.replace("| `cow_copies` |", "| `cow_copy_total` |"))


def test_serving_doc_flags_match_engine_signature():
    """Every ServeEngine sharing-related flag is documented: the doc's flag
    table must include the knobs the tests exercise."""
    mod = _load_check_docs()
    text = (REPO / "docs" / "SERVING.md").read_text()
    flags = mod.table_rows(text, "Engine flags")
    assert {"share_prefix", "spec_tail", "paged", "n_pages",
            "trace", "metrics_every"} <= flags


def test_observability_doc_in_sync_and_drift_detected():
    """docs/OBSERVABILITY.md's metric catalog and event schema track the
    code: a renamed metric, a ghost event, or an undocumented engine
    counter all fail (guards the checker itself against regex rot)."""
    mod = _load_check_docs()
    text = (REPO / "docs" / "OBSERVABILITY.md").read_text()
    assert not mod.check_observability(text)
    metrics = mod.table_rows(text, "Metric catalog")
    assert {"ttft_s", "tpot_s", "cycle_s", "phase_device_wait_s",
            "pool_occupancy", "sched_backpressure_events",
            "faults_injected"} <= metrics
    events = mod.table_rows(text, "Event schema")
    assert {"queue", "prefill", "decode", "preempt", "cow", "fault",
            "spec_verify"} <= events
    # a documented metric the code never emits
    assert mod.check_observability(
        text.replace("| `ttft_s` |", "| `ttft_seconds_total` |"))
    # a documented event the code never emits
    assert mod.check_observability(
        text.replace("| `cow` |", "| `copy_on_write` |"))
    # an engine counter dropped from the catalog
    assert mod.check_observability(
        text.replace("| `preempted` |", "| |"))
    # a bogus dotted symbol
    assert mod.check_observability(
        text + "\nsee `repro.serve.telemetry.NoSuchThing`")
