"""Residual-flush kernel family: parity, boundary fills, and hot-path gating.

The contract under test (ISSUE 2 / paper §V-B): `append_decode` must produce
caches identical to the old speculative path, the Pallas flush must match the
select-based XLA oracle bitwise, and — the point of the fusion — a non-full
residual append must perform **no** quantize/pack work (the flush runs only
under the `lax.cond` taken when some sequence's residual just filled).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as catt
from repro.core import qcache
from repro.kernels.residual_flush import ops as rf_ops

jax.config.update("jax_platform_name", "cpu")

B, H, D, BLOCK = 2, 2, 64, 32
MAXSEQ = 4 * BLOCK

_CACHE_FIELDS = ("kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero",
                 "k_res", "v_res", "pack_blocks", "res_len")


def _tokens(n, d=D, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    k = jax.random.normal(ks[0], (B, H, n, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (B, H, n, d), jnp.float32).astype(jnp.bfloat16)
    return k, v


def _assert_caches_equal(a, b):
    for f in _CACHE_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=f)


def _append_n(cache, k, v, n, fn):
    for i in range(n):
        vi = None if cache.shared_kv else v[:, :, i : i + 1]
        cache = fn(cache, k[:, :, i : i + 1], vi)
    return cache


# ---------------------------------------------------------------- op parity


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
@pytest.mark.parametrize("shared_kv", [False, True])
def test_flush_op_pallas_matches_xla(bits, k_gran, shared_kv):
    """residual_flush: Pallas (interpret) == select-based XLA oracle, with a
    mixed full/not-full batch so both kernel branches execute."""
    cache = qcache.init_cache(
        B, H, D, MAXSEQ, bits=bits, block_n=BLOCK, k_gran=k_gran,
        shared_kv=shared_kv,
    )
    k, v = _tokens(BLOCK, key=bits)
    kres = k
    vres = None if shared_kv else v
    full = jnp.array([1, 0], jnp.int32)
    dest = jnp.array([1, 2], jnp.int32)
    args = (cache.kw, cache.k_scale, cache.k_zero, cache.vw, cache.v_scale,
            cache.v_zero, kres, vres, full, dest)
    kw = dict(bits=bits, block_n=BLOCK, k_gran=k_gran, shared_kv=shared_kv)
    ref = rf_ops.residual_flush(*args, impl="xla", **kw)
    out = rf_ops.residual_flush(*args, impl="pallas", **kw)
    for r, o, name in zip(ref, out, ("kw", "ks", "kz", "vw", "vs", "vz")):
        if r is None:
            assert o is None
            continue
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o), err_msg=name)
    # the not-full sequence's cache must be untouched
    np.testing.assert_array_equal(np.asarray(out[0][1]), np.asarray(cache.kw[1]))
    # the full sequence committed a non-trivial block at dest
    assert np.asarray(out[0][0, :, 1]).any()


# ------------------------------------------------------- append boundaries


@pytest.mark.parametrize("quant_impl", ["xla", "pallas"])
@pytest.mark.parametrize("n", [0, 1, BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK])
def test_append_fill_boundaries(n, quant_impl):
    """Fill counts {0, 1, N_r-1, N_r} plus flush-immediately-followed-by-
    append: gated append == speculative oracle, field for field."""
    k, v = _tokens(max(n, 1), key=7 * n + 1)
    gated = jax.jit(functools.partial(qcache.append_decode, quant_impl=quant_impl))
    spec = jax.jit(
        functools.partial(qcache.append_decode_speculative, quant_impl="xla")
    )
    c_g = _append_n(
        qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK), k, v, n, gated
    )
    c_s = _append_n(
        qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK), k, v, n, spec
    )
    _assert_caches_equal(c_g, c_s)
    assert int(c_g.pack_blocks[0]) == n // BLOCK
    assert int(c_g.res_len[0]) == n % BLOCK
    np.testing.assert_array_equal(np.asarray(c_g.length), n)


def test_flush_then_append_attention_parity():
    """Attention over a cache that flushed and then appended again matches
    the fp16 history oracle."""
    n = BLOCK + 3
    k, v = _tokens(n, key=11)
    cache = qcache.init_cache(B, H, D, MAXSEQ, bits=8, block_n=BLOCK)
    cache = _append_n(
        cache, k, v, n, functools.partial(qcache.append_decode, quant_impl="xla")
    )
    q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, H * 2, D)).astype(jnp.bfloat16)
    out = catt.decode_attention(q, cache, impl="xla")
    qt = q.reshape(B, H, 2, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", qt, k.astype(jnp.float32))
    p = jax.nn.softmax(s / D**0.5, axis=-1)
    ref = jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, H, 2, D)), np.asarray(ref), rtol=0.08, atol=0.08
    )


def test_append_2bit_channel_shared_kv():
    """2-bit channel-wise latent (shared_kv) cache across a flush boundary:
    gated == speculative and occupancy invariants hold."""
    n = BLOCK + 2
    k, _ = _tokens(n, d=D, key=13)
    gated = jax.jit(functools.partial(qcache.append_decode, quant_impl="pallas"))
    spec = jax.jit(
        functools.partial(qcache.append_decode_speculative, quant_impl="xla")
    )
    mk = functools.partial(
        qcache.init_cache, B, H, D, MAXSEQ, bits=2, block_n=BLOCK,
        k_gran="channel", shared_kv=True,
    )
    c_g = _append_n(mk(), k, None, n, gated)
    c_s = _append_n(mk(), k, None, n, spec)
    _assert_caches_equal(c_g, c_s)
    assert int(c_g.pack_blocks[0]) == 1 and int(c_g.res_len[0]) == 2


def test_staggered_flush_across_batch():
    """Sequences flushing on different steps (per-sequence res_len) stay
    consistent with the speculative oracle."""
    k, v = _tokens(BLOCK, key=17)
    pre_k, pre_v = _tokens(3, key=19)
    base = qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK)
    # stagger: sequence 0 starts 3 tokens ahead (per-row prefill splice)
    def stagger(c):
        filled = qcache.prefill(
            qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK),
            pre_k, pre_v, quant_impl="xla",
        )
        return dataclasses.replace(
            c,
            k_res=c.k_res.at[0].set(filled.k_res[0]),
            v_res=c.v_res.at[0].set(filled.v_res[0]),
            res_len=c.res_len.at[0].set(3),
        )

    gated = jax.jit(functools.partial(qcache.append_decode, quant_impl="xla"))
    spec = jax.jit(
        functools.partial(qcache.append_decode_speculative, quant_impl="xla")
    )
    c_g = _append_n(stagger(base), k, v, BLOCK, gated)
    c_s = _append_n(stagger(base), k, v, BLOCK, spec)
    _assert_caches_equal(c_g, c_s)
    # sequence 0 flushed 3 tokens earlier
    assert int(c_g.pack_blocks[0]) == 1 and int(c_g.res_len[0]) == 3
    assert int(c_g.pack_blocks[1]) == 1 and int(c_g.res_len[1]) == 0


# ---------------------------------------------------------------- gating


def _collect_prims(jaxpr, into):
    import jax.core as jc

    for e in jaxpr.eqns:
        into.add(e.primitive.name)
        for val in e.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for w in vals:
                if isinstance(w, jc.ClosedJaxpr):
                    _collect_prims(w.jaxpr, into)
    return into


@pytest.mark.parametrize("quant_impl", ["xla", "pallas"])
def test_hot_path_does_no_quant_work(quant_impl):
    """The acceptance criterion: quantize/pack work lives exclusively inside
    the flush branch of a single `cond`; the per-token path traced at the
    top level carries none of it."""
    cache = qcache.init_cache(B, H, D, MAXSEQ, bits=4, block_n=BLOCK)
    k, v = _tokens(1)
    jaxpr = jax.make_jaxpr(
        functools.partial(qcache.append_decode, quant_impl=quant_impl)
    )(cache, k, v)
    quant_marker = "pallas_call" if quant_impl == "pallas" else "shift_left"
    top = [e.primitive.name for e in jaxpr.jaxpr.eqns]
    assert "cond" in top
    assert quant_marker not in top and "round" not in top
    (cond_eqn,) = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "cond"]
    branch_has_quant = [
        quant_marker in _collect_prims(br.jaxpr, set())
        for br in cond_eqn.params["branches"]
    ]
    assert sum(branch_has_quant) == 1, branch_has_quant
