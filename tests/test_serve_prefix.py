"""Prefix-sharing paged serving: index hit/miss at block boundaries,
refcounted page lifecycle across completion, reservation accounting that
never double-charges, suffix-only prefill, and copy-on-write with bitwise
decode parity vs. the no-sharing oracle (the PR's acceptance criteria)."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.core import attention as catt
from repro.core import qcache
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.pages import PagePool
from repro.serve.scheduler import PrefixIndex, Scheduler

BLOCK = 32


# --------------------------------------------------------------------------
# PrefixIndex units: hash chain, block-boundary hit/miss, spec tail
# --------------------------------------------------------------------------

def _idx():
    return PrefixIndex("ns", BLOCK)


def test_index_hit_miss_at_block_boundaries():
    idx = _idx()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 100, 3 * BLOCK + 5).astype(np.int32)
    chain = idx.chain(prompt)
    assert len(chain) == 3  # full blocks only; the 5-token tail hashes never
    idx.register(chain, [10, 11, 12], prompt)
    assert len(idx) == 3

    # exact prefix at a block boundary: full-run hit
    assert idx.lookup(idx.chain(prompt[: 2 * BLOCK])) == [10, 11]
    # one token past the boundary changes nothing (partial chunks don't hash)
    assert idx.lookup(idx.chain(prompt[: 2 * BLOCK + 1])) == [10, 11]
    # one token short of the boundary drops the block
    assert idx.lookup(idx.chain(prompt[: 2 * BLOCK - 1])) == [10]
    # divergence inside block 1 stops the walk after block 0
    mid = prompt[: 2 * BLOCK].copy()
    mid[BLOCK + 3] += 1
    assert idx.lookup(idx.chain(mid)) == [10]
    # a different first block misses entirely
    other = prompt.copy()
    other[0] += 1
    assert idx.lookup(idx.chain(other)) == []


def test_index_spec_tail_and_forget():
    idx = _idx()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 100, 2 * BLOCK).astype(np.int32)
    chain = idx.chain(prompt)
    idx.register(chain, [7, 8], prompt)
    # the mid-block tail of a strict prompt prefix matches the donor block
    assert idx.spec_tail(chain[0], prompt[BLOCK : BLOCK + 9]) == 8
    assert idx.spec_tail(idx.root, prompt[:5]) == 7
    # a diverged tail does not
    tail = prompt[BLOCK : BLOCK + 9].copy()
    tail[4] += 1
    assert idx.spec_tail(chain[0], tail) is None
    assert idx.spec_tail(chain[0], np.asarray([], np.int32)) is None
    # forgetting a page removes its node and its spec-tail discoverability
    idx.forget_page(8)
    assert idx.lookup(chain) == [7]
    assert idx.spec_tail(chain[0], prompt[BLOCK : BLOCK + 9]) is None
    idx.forget_page(8)  # idempotent


def test_index_registration_first_writer_wins():
    idx = _idx()
    prompt = np.arange(BLOCK, dtype=np.int32)
    chain = idx.chain(prompt)
    idx.register(chain, [5], prompt)
    idx.register(chain, [6], prompt)  # same content elsewhere: no-op
    assert idx.lookup(chain) == [5]


# --------------------------------------------------------------------------
# Commitment accounting: shared pages counted once, donor-first retirement
# --------------------------------------------------------------------------

def test_pool_commitment_counts_shared_pages_once():
    pool = PagePool(6, n_scratch=2)  # capacity 4
    assert pool.reserve(1)
    donor_page = pool.alloc()
    pool.retain(donor_page)           # a sharer joins: no new commitment
    assert pool.committed == 1
    # the donor retires first: the page stays committed via the sharer
    pool.free(donor_page)
    assert pool.n_used == 1 and pool.committed == 1
    # a newcomer can only reserve what is genuinely uncommitted
    assert pool.reserve(3)
    assert not pool.reserve(1)
    # last holder drops the page -> the commitment finally returns
    pool.free(donor_page)
    assert pool.committed == 3
    assert pool.reserve(1)


def test_scheduler_admission_does_not_double_charge_shared_pages():
    """Two identical 2-block prompts into a 3-page pool: the second request
    shares block 0 (the cap keeps block 1 private for its logits), so its
    reservation is 1 page, not 2 — without the discount the pool could not
    admit it."""
    pool = PagePool(3 + 2, n_scratch=2)  # capacity 3
    sched = Scheduler(slots=2, pool=pool, block_n=BLOCK, max_seq=256,
                      namespace="t")
    prompt = np.arange(2 * BLOCK, dtype=np.int32)
    a = Request(uid=0, prompt=prompt, max_new_tokens=4)
    b = Request(uid=1, prompt=prompt.copy(), max_new_tokens=4)
    sched.submit(a)
    (bucket, (got_a,)), = sched.admit().items()
    assert got_a is a and a.shared_pages == []
    # adopt A's prefill: two fresh pages (owner-tagged, as the engine's
    # `_alloc_page` does), registered for later arrivals
    pages_a = [pool.alloc(owner=a.uid), pool.alloc(owner=a.uid)]
    a.pages.extend(pages_a)
    a.reserved_pages -= 2
    sched.register_prefix(a, pages_a)

    sched.submit(b)
    (bucket_b, (got_b,)), = sched.admit().items()
    assert got_b is b
    assert b.shared_pages == [pages_a[0]]
    assert pool.refcount(pages_a[0]) == 2
    assert b.reserved_pages == 1  # (64 + 4)//32 - 1 shared
    assert bucket_b == 32  # divergent suffix only: one block, not two
    # full budget: 2 allocated (A) + A's remaining 0 + B's 1 = 3 == capacity
    assert pool.committed == 3
    sched.complete(a)
    # shared page survives A via B's reference and stays committed
    assert pool.refcount(pages_a[0]) == 1
    assert pool.committed == 2  # page 0 (shared) + B's reservation
    sched.complete(b)
    assert pool.committed == 0 and pool.n_free == pool.capacity


# --------------------------------------------------------------------------
# Device ops: copy_pages replication, dequant_prior round-trip,
# prefix_suffix_attention == causal-attention tail
# --------------------------------------------------------------------------

def test_copy_pages_replicates_all_pool_fields():
    pc = qcache.init_paged_cache(8, 2, 2, 64, 4, bits=4, block_n=BLOCK)
    # stack a layer dim like the engine state does
    pc = jax.tree.map(lambda a: jnp.broadcast_to(a, (3, *a.shape)), pc)
    rng = np.random.default_rng(2)
    pc = dataclasses.replace(
        pc,
        kw=jnp.asarray(rng.integers(0, 2**31 - 1, pc.kw.shape), jnp.int32),
        k_scale=jnp.asarray(rng.normal(size=pc.k_scale.shape), jnp.bfloat16),
        v_zero=jnp.asarray(rng.normal(size=pc.v_zero.shape), jnp.bfloat16),
    )
    out = qcache.copy_pages(pc, jnp.asarray([5, 3]), jnp.asarray([6, 7]))
    for f in qcache._PAGED_POOL_FIELDS:
        src_pool = getattr(pc, f)
        dst_pool = getattr(out, f)
        np.testing.assert_array_equal(
            np.asarray(dst_pool[:, 6]), np.asarray(src_pool[:, 5]))
        np.testing.assert_array_equal(
            np.asarray(dst_pool[:, 7]), np.asarray(src_pool[:, 3]))
        # untouched pages identical
        np.testing.assert_array_equal(
            np.asarray(dst_pool[:, :3]), np.asarray(src_pool[:, :3]))


def test_dequant_prior_round_trips_pool_pages():
    from repro.kernels.kv_quant import ref as kq_ref
    from repro.core import quantizer

    H, D = 2, 64
    rng = jax.random.PRNGKey(3)
    k = jax.random.normal(rng, (1, H, BLOCK, D)).astype(jnp.bfloat16)
    kw, ks, kz = kq_ref.quantize_kv_ref(k, 4, "channel", block_n=BLOCK)
    want = quantizer.unpack_and_dequantize(kw, ks, kz, 4, "channel")
    # place the block at pool page 5 (one stacking layer dim, like the engine)
    pc = qcache.init_paged_cache(8, 2, H, D, 4, bits=4, block_n=BLOCK)
    pc = jax.tree.map(lambda a: jnp.broadcast_to(a, (1, *a.shape)), pc)
    pc = dataclasses.replace(
        pc,
        kw=pc.kw.at[:, 5].set(kw[:, :, 0]),
        k_scale=pc.k_scale.at[:, 5].set(ks[:, :, 0]),
        k_zero=pc.k_zero.at[:, 5].set(kz[:, :, 0]),
    )
    kp, vp = qcache.dequant_prior(pc, jnp.asarray([[5]], jnp.int32))
    assert kp.shape == (1, 1, BLOCK, H, D)
    np.testing.assert_allclose(
        np.asarray(kp[0, 0], jnp.float32),
        np.asarray(want[0, :, 0].transpose(1, 0, 2), jnp.float32),
        rtol=0, atol=0,
    )
    assert not np.asarray(vp).any()  # v pools were empty


def test_prefix_suffix_attention_matches_causal_tail():
    """With a raw prior, the suffix attention rows equal the corresponding
    rows of full causal attention over the concatenated sequence — per
    sequence, at ragged prior lengths."""
    B, T, S, HQ, HKV, D = 2, 48, 16, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    kp = jax.random.normal(ks[0], (B, T, HKV, D)).astype(jnp.bfloat16)
    vp = jax.random.normal(ks[1], (B, T, HKV, D)).astype(jnp.bfloat16)
    prior_len = jnp.asarray([48, 17], jnp.int32)
    k = jax.random.normal(ks[2], (B, S, HKV, D)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, HKV, D)).astype(jnp.bfloat16)
    q = jax.random.normal(ks[3], (B, S, HQ, D)).astype(jnp.bfloat16)
    got = catt.prefix_suffix_attention(q, k, v, kp, vp, prior_len)
    for b in range(B):
        pl = int(prior_len[b])
        kc = jnp.concatenate([kp[b : b + 1, :pl], k[b : b + 1]], axis=1)
        vc = jnp.concatenate([vp[b : b + 1, :pl], v[b : b + 1]], axis=1)
        want = catt.blockwise_attention(
            q[b : b + 1], kc, vc, causal=True, q_offset=pl
        )
        np.testing.assert_allclose(
            np.asarray(got[b], jnp.float32), np.asarray(want[0], jnp.float32),
            rtol=2e-2, atol=2e-2,
        )


# --------------------------------------------------------------------------
# Engine end-to-end: shared pages, suffix-only prefill, lifecycle, COW
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, rng, n):
    return rng.integers(0, cfg.vocab, n).astype(np.int32)


def test_shared_prefix_consumes_k_shared_plus_private_suffix_pages(small_model):
    """Acceptance criterion: B sharing A's k-block prefix holds exactly A's k
    pages (refcounted, counted once) plus private pages for its divergent
    suffix, and prefill runs only over the suffix."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=256)
    rng = np.random.default_rng(5)
    pa = _prompt(cfg, rng, 3 * BLOCK)  # 96 tokens = 3 full blocks
    pb = np.concatenate([pa[: 2 * BLOCK], _prompt(cfg, rng, 16)])  # diverges

    a = Request(uid=0, prompt=pa, max_new_tokens=4)
    b = Request(uid=1, prompt=pb, max_new_tokens=4)
    engine.submit(a)
    engine.step()  # A adopted + registered
    used_after_a = engine.pool.n_used
    assert used_after_a == 3
    tokens_after_a = engine.stats["prefill_tokens"]

    engine.submit(b)
    engine.step()
    # B shares A's first two pages...
    assert b.shared_pages == a.pages[:2]
    assert all(engine.pool.refcount(p) == 2 for p in b.shared_pages)
    # ...allocates only its suffix (16 tokens -> 0 full blocks yet)...
    assert engine.pool.n_used == 3
    # ...and prefilled only the 16 divergent tokens
    assert engine.stats["prefill_tokens"] - tokens_after_a == 16
    assert engine.stats["prefill_tokens_saved"] == 2 * BLOCK
    assert engine.sched.stats["prefix_hit_blocks"] == 2
    assert engine.sched.stats["prefix_hit_requests"] == 1

    engine.run()
    assert a.done and b.done
    assert len(a.out_tokens) == 4 and len(b.out_tokens) == 4
    # refcount lifecycle: every page returned, reservations drained,
    # the index forgot the dead pages
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert len(engine.sched.index) == 0
    assert engine.summary()["prefix_hit_rate"] > 0


def test_shared_prefix_outputs_match_unshared_oracle(small_model):
    """Divergence mid-stream: both sharers decode past a flush; the shared
    pages are never written (A's decode output is bitwise the solo run), and
    B's divergent suffix decodes to completion."""
    cfg, model, params = small_model

    def solo(prompt, max_new):
        eng = ServeEngine(model, params, slots=2, max_seq=256,
                          share_prefix=False)
        r = Request(uid=0, prompt=prompt, max_new_tokens=max_new)
        eng.submit(r)
        eng.run()
        return r.out_tokens

    rng = np.random.default_rng(6)
    pa = _prompt(cfg, rng, 2 * BLOCK)
    pb = np.concatenate([pa, _prompt(cfg, rng, 8)])  # extends A by 8 tokens

    engine = ServeEngine(model, params, slots=2, max_seq=256)
    # both decode across a block boundary -> private flush pages
    a = Request(uid=0, prompt=pa, max_new_tokens=BLOCK + 4)
    b = Request(uid=1, prompt=pb.copy(), max_new_tokens=BLOCK + 4)
    engine.submit(a)
    engine.step()
    engine.submit(b)
    engine.step()  # B admitted here: sharing visible before retirement
    assert len(b.shared_pages) == 2
    engine.run()
    assert a.done and b.done
    # A's computation is untouched by sharing: bitwise vs its solo run
    assert a.out_tokens == solo(pa, BLOCK + 4)
    assert len(b.out_tokens) == BLOCK + 4
    assert engine.pool.n_free == engine.pool.capacity


def test_cow_on_spec_tail_bitwise_parity(small_model):
    """Acceptance criterion, COW edition: B's prompt is a strict mid-block
    prefix of A's resident block, so B adopts A's page as its speculative
    flush destination; B's first flush diverges -> copy-on-write gives B a
    private replica and repoints only B's column.  B never *reads* the
    shared page before the COW, so its decode output is bitwise identical
    to an unshared run — and A's page survives untouched."""
    cfg, model, params = small_model
    rng = np.random.default_rng(7)
    pa = _prompt(cfg, rng, BLOCK + 8)  # block 0 committed at adoption
    pb = pa[:8].copy()                 # strict prefix, ends mid-block 0

    def solo_tokens(prompt, max_new):
        eng = ServeEngine(model, params, slots=2, max_seq=256,
                          share_prefix=False)
        r = Request(uid=0, prompt=prompt, max_new_tokens=max_new)
        eng.submit(r)
        eng.run()
        return r.out_tokens

    engine = ServeEngine(model, params, slots=2, max_seq=256)
    a = Request(uid=0, prompt=pa, max_new_tokens=2 * BLOCK)  # stays active
    b = Request(uid=1, prompt=pb, max_new_tokens=BLOCK)      # fills block 0
    engine.submit(a)
    engine.step()
    page_a = a.pages[0]
    engine.submit(b)
    engine.step()
    assert b.spec_page == page_a
    assert engine.pool.refcount(page_a) == 2
    assert engine.sched.stats["spec_tail_adoptions"] == 1
    kw_before = np.asarray(engine.state["caches"][0].kw[:, page_a]).copy()

    engine.run()
    assert engine.stats["cow_copies"] == 1
    assert a.done and b.done
    # bitwise parity vs the no-sharing oracle, for both requests
    assert b.out_tokens == solo_tokens(pb, BLOCK)
    assert a.out_tokens == solo_tokens(pa, 2 * BLOCK)
    assert engine.pool.n_free == engine.pool.capacity


def test_spec_tail_page_freed_without_cow_on_early_exit(small_model):
    """A sharer that retires before its residual fills never COWs: the
    speculative page just drops its extra reference."""
    cfg, model, params = small_model
    rng = np.random.default_rng(8)
    pa = _prompt(cfg, rng, BLOCK + 8)
    engine = ServeEngine(model, params, slots=2, max_seq=256)
    a = Request(uid=0, prompt=pa, max_new_tokens=2 * BLOCK)
    engine.submit(a)
    engine.step()
    b = Request(uid=1, prompt=pa[:8].copy(), max_new_tokens=3)  # exits early
    engine.submit(b)
    engine.step()
    assert b.spec_page is not None
    engine.run()
    assert engine.stats["cow_copies"] == 0
    assert engine.pool.n_free == engine.pool.capacity


def test_sharing_disabled_flag(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=256,
                         share_prefix=False)
    assert engine.sched.index is None
    rng = np.random.default_rng(9)
    pa = _prompt(cfg, rng, 2 * BLOCK)
    a = Request(uid=0, prompt=pa, max_new_tokens=2)
    b = Request(uid=1, prompt=pa.copy(), max_new_tokens=2)
    engine.submit(a)
    engine.step()
    engine.submit(b)
    engine.run()
    assert b.shared_pages == [] and engine.stats["prefill_tokens_saved"] == 0


def test_shared_pages_valid_under_splitkv_table_walk(small_model):
    """Replicated pools + sharded table walk: a sharing run through the
    cross-chip split-KV decode path produces the same tokens as the plain
    path (shared page ids may appear in several table rows — each shard
    walks its columns against the full pools, dist/state_specs.py)."""
    cfg, model, params = small_model
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rng = np.random.default_rng(10)
    pa = _prompt(cfg, rng, 2 * BLOCK)
    pb = np.concatenate([pa, _prompt(cfg, rng, 8)])

    def run(**kw):
        eng = ServeEngine(model, params, slots=2, max_seq=256, **kw)
        a = Request(uid=0, prompt=pa, max_new_tokens=6)
        b = Request(uid=1, prompt=pb.copy(), max_new_tokens=6)
        eng.submit(a)
        eng.step()
        eng.submit(b)
        eng.run()
        return a.out_tokens, b.out_tokens, eng

    base_a, base_b, _ = run()
    sk_a, sk_b, eng = run(mesh=mesh, splitkv="always")
    assert eng.stats["splitkv_steps"] > 0
    assert sk_a == base_a and sk_b == base_b
