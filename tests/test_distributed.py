"""Distributed correctness on 8 fake CPU devices (subprocess so the main
test process keeps 1 device): split-KV decode vs single-device oracle,
small-mesh train-step lowering, gradient compression round-trip."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import NamedSharding, PartitionSpec as PS

    # ---------------- split-KV decode vs oracle ----------------
    from repro.core import qcache, attention as catt
    from repro.dist.splitkv import splitkv_decode_attention

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    B, H, D, BLOCK, NBLK = 1, 2, 128, 128, 8
    S = NBLK * BLOCK + 37
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (B, H, S, D), jnp.float32).astype(jnp.bfloat16)
    q = jax.random.normal(ks[2], (B, 1, H * 2, D), jnp.float32).astype(jnp.bfloat16)
    cache = qcache.init_cache(B, H, D, NBLK * BLOCK, bits=8, block_n=BLOCK)
    cache = qcache.prefill(cache, k, v, quant_impl="xla")

    ref = catt.decode_attention(q, cache, impl="xla")
    with jax.set_mesh(mesh):
        out = splitkv_decode_attention(q, cache, mesh, axis="data", impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)
    print("OK splitkv")

    # ---------------- paged split-KV (sharded page-table walk) ---------
    import dataclasses
    from repro.dist.splitkv import splitkv_paged_decode_attention

    NP = B + B * NBLK
    pcache = qcache.init_paged_cache(NP, B, H, D, NBLK, bits=8, block_n=BLOCK)
    table = np.asarray(pcache.page_table).copy()
    pools = {f: np.asarray(getattr(pcache, f)).copy()
             for f in ("kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero")}
    for b in range(B):
        for j in range(NBLK):
            p = B + b * NBLK + j
            table[b, j] = p
            for f in pools:
                pools[f][p] = np.asarray(getattr(cache, f))[b, :, j]
    pcache = dataclasses.replace(
        pcache, page_table=jnp.asarray(table),
        k_res=cache.k_res, v_res=cache.v_res,
        pack_blocks=cache.pack_blocks, res_len=cache.res_len,
        **{f: jnp.asarray(a) for f, a in pools.items()})
    pref = catt.decode_attention(q, pcache, impl="xla")
    np.testing.assert_allclose(np.asarray(pref), np.asarray(ref), rtol=2e-2, atol=2e-2)
    with jax.set_mesh(mesh):
        pout = splitkv_paged_decode_attention(q, pcache, mesh, axis="data", impl="xla")
        # and through the engine-facing use_splitkv route
        with catt.use_splitkv(mesh, "data"):
            pout2 = catt.decode_attention(q, pcache, impl="xla")
    np.testing.assert_allclose(np.asarray(pout), np.asarray(ref), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(pout2), np.asarray(ref), rtol=2e-2, atol=2e-2)
    print("OK paged splitkv")

    # ------- page-affine pool sharding (ISSUE 10) ----------------------
    # affinity-consistent layout: page for table column j IS page j, so
    # shard j // nb_local owns both the column and its page
    NPA = NBLK  # 8 pages, 2 per "data" shard
    acache = qcache.init_paged_cache(NPA, B, H, D, NBLK, bits=8, block_n=BLOCK)
    apools = {f: np.asarray(getattr(acache, f)).copy()
              for f in ("kw", "k_scale", "k_zero", "vw", "v_scale", "v_zero")}
    for j in range(NBLK):
        for f in apools:
            apools[f][j] = np.asarray(getattr(cache, f))[0, :, j]
    acache = dataclasses.replace(
        acache,
        page_table=jnp.asarray(np.arange(NBLK, dtype=np.int32)[None, :]),
        k_res=cache.k_res, v_res=cache.v_res,
        pack_blocks=cache.pack_blocks, res_len=cache.res_len,
        **{f: jnp.asarray(a) for f, a in apools.items()})
    with jax.set_mesh(mesh):
        aout = splitkv_paged_decode_attention(
            q, acache, mesh, axis="data", impl="xla", page_affine=True)
        with catt.use_splitkv(mesh, "data", page_affine=True):
            aout2 = catt.decode_attention(q, acache, impl="xla")
    np.testing.assert_allclose(np.asarray(aout), np.asarray(ref), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(aout2), np.asarray(ref), rtol=2e-2, atol=2e-2)
    print("OK affine splitkv")

    # ------- mesh-aligned cache allocation (pad-free splitkv path) -----
    from repro.configs.base import smoke_config
    from repro.models.zoo import build_model
    from repro.dist.state_specs import decode_state_specs
    from jax.sharding import NamedSharding

    cfgm = smoke_config("llama3-8b")
    modelm = build_model(cfgm)
    # 5 blocks of kv_block tokens would give nb=5; the data axis (4) must
    # round it to 8 so dist.splitkv's per-call zero-pad is never taken
    stm = modelm.init_decode_state(4, 5 * cfgm.kv_block, mesh=mesh,
                                   splitkv_axis="data")
    nb = stm["caches"][0].kw.shape[3]
    assert nb % mesh.shape["data"] == 0, nb
    # paged state specs are legal NamedShardings (batch/blocks don't collide)
    specs = decode_state_specs(modelm, mesh, global_batch=4, seq_ax="data",
                               paged=True)
    jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else None, specs,
        is_leaf=lambda x: x is None,
    )
    print("OK mesh-aligned alloc")

    # ------- page-affine capacity scales with the mesh -----------------
    # constant per-chip pool bytes: n_pages = per_chip * axis size, the
    # page dim shards along "data", every chip holds exactly per_chip pages
    PER_CHIP = 4
    shard_bytes = {}
    for n_ax in (4, 8):
        msh = jax.make_mesh((n_ax,), ("data",))  # data-only: bytes differ
        # only through the page dim, not a heads (model) split
        specs = decode_state_specs(modelm, msh, global_batch=4, seq_ax="data",
                                   paged=True, n_pages=PER_CHIP * n_ax,
                                   nb_max=8, page_affine=True)
        st = modelm.init_paged_decode_state(4, n_pages=PER_CHIP * n_ax,
                                            nb_max=8)
        st = jax.device_put(st, jax.tree.map(
            lambda s: None if s is None else NamedSharding(msh, s), specs,
            is_leaf=lambda x: x is None))
        kwp = st["caches"][0].kw
        lead = kwp.ndim - 4
        dims = {s.data.shape for s in kwp.addressable_shards}
        assert all(v[lead] == PER_CHIP for v in dims), (n_ax, dims)
        shard_bytes[n_ax] = {s.data.nbytes for s in kwp.addressable_shards}
        assert kwp.shape[lead] == PER_CHIP * n_ax
    # doubling the mesh doubled resident pages at identical per-chip bytes
    assert shard_bytes[4] == shard_bytes[8], shard_bytes
    print("OK affine capacity")

    # ------- page-affine serving: sharing + COW parity, placement ------
    from repro.serve.engine import Request, ServeEngine
    cfgs = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    models = build_model(cfgs)
    prms = models.init(jax.random.PRNGKey(0))
    smesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(7)
    pa = rng.integers(0, cfgs.vocab, 32 + 8).astype(np.int32)
    pb = pa[:8].copy()  # strict mid-block prefix -> spec-tail COW
    pc = rng.integers(0, cfgs.vocab, 3 * 32).astype(np.int32)

    def serve(**kw):
        eng = ServeEngine(models, prms, slots=2, max_seq=256,
                          retain_prefix=True, **kw)
        a = Request(uid=0, prompt=pa.copy(), max_new_tokens=2 * 32)
        b = Request(uid=1, prompt=pb.copy(), max_new_tokens=32)
        eng.submit(a); eng.step(); eng.submit(b); eng.run()
        c = Request(uid=2, prompt=pc.copy(), max_new_tokens=4)
        eng.submit(c); eng.run()
        d = Request(uid=3, prompt=pc.copy(), max_new_tokens=4)  # retained hit
        eng.submit(d); eng.run()
        return eng, [a.out_tokens, b.out_tokens, c.out_tokens, d.out_tokens]

    base_eng, base_out = serve()
    assert base_eng.stats["cow_copies"] == 1
    # oracle: the replicated-pool sharded walk.  (The long decode drifts
    # off the *plain* path eventually — the split-KV lse merge reorders
    # float math — so pool placement is judged against the same walk.)
    sk_eng, sk_out = serve(mesh=smesh, splitkv="always")
    aff_eng, aff_out = serve(mesh=smesh, splitkv="always", page_affine=True)
    assert aff_eng.stats["cow_copies"] == 1      # COW ran shard-local
    assert aff_eng.stats["splitkv_steps"] > 0
    assert aff_eng.sched.stats["prefix_retained_hits"] > 0
    # sharding the pool storage is bitwise invisible to the sharded walk
    assert aff_out == sk_out, (aff_out, sk_out)
    # and the short requests agree with the plain path outright
    assert aff_out[1:] == base_out[1:], (aff_out, base_out)
    assert aff_eng.summary()["pool_shards"] == 8
    kwe = aff_eng.state["caches"][0].kw
    lead = kwe.ndim - 4
    assert all(s.data.shape[lead] == kwe.shape[lead] // 8
               for s in kwe.addressable_shards)
    print("OK affine serving")

    # ---------------- small-mesh train step lowers+compiles -----------
    from repro.configs.base import smoke_config
    from repro.models.zoo import build_model
    from repro.optim import get_optimizer
    from repro.train.step import make_train_step, train_state_shapes
    from repro.dist import sharding as shd
    from repro.data.pipeline import batch_specs
    from repro.configs.base import ShapeSpec

    cfg = smoke_config("llama3-8b")
    model = build_model(cfg)
    opt = get_optimizer("adamw")
    rules = shd.base_rules(cfg)
    shape = ShapeSpec("t", 64, 8, "train")
    with jax.set_mesh(mesh):
        sfn = make_train_step(model, opt)
        st_struct = train_state_shapes(model, opt)
        bsp = batch_specs(cfg, shape, mesh=mesh)
        lowered = jax.jit(sfn).lower(st_struct, bsp)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    print("OK train lower 8dev")

    # ---------------- actually run a sharded train step ----------------
    from repro.train.step import init_train_state
    from repro.data.pipeline import make_batch
    with jax.set_mesh(mesh):
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        batch = make_batch(cfg, shape, mesh=mesh)
        state2, metrics = jax.jit(sfn)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    print("OK train run 8dev", float(metrics["loss"]))

    # ---------------- gradient compression with error feedback --------
    from jax.experimental.shard_map import shard_map
    from repro.optim.grad_compress import compress_allreduce

    pmesh = jax.make_mesh((2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(1), (2, 64), jnp.float32)

    @functools.partial(
        shard_map, mesh=pmesh, in_specs=(PS("pod"), PS("pod")),
        out_specs=(PS("pod"), PS("pod")), check_rep=False)
    def red(gs, es):
        r, e = compress_allreduce(gs[0], es[0], "pod")
        return r[None], e[None]

    err = jnp.zeros_like(g)
    red_g, err = red(g, err)
    true_mean = jnp.mean(g, axis=0)
    got = np.asarray(red_g)[0]
    rel = np.abs(got - np.asarray(true_mean)).max() / (np.abs(np.asarray(true_mean)).max() + 1e-9)
    assert rel < 0.05, f"compressed allreduce error {rel}"
    # error feedback: residuals nonzero and bounded by one quant step
    assert float(jnp.abs(err).max()) < float(jnp.abs(g).max()) / 100
    print("OK grad compression")
    """
)


def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    for marker in ("OK splitkv", "OK paged splitkv", "OK affine splitkv",
                   "OK mesh-aligned alloc", "OK affine capacity",
                   "OK affine serving", "OK train lower 8dev",
                   "OK train run 8dev", "OK grad compression"):
        assert marker in r.stdout, f"missing {marker}:\n{r.stdout}\n{r.stderr}"
