"""Persistent prefix-cache tier (ISSUE 10): the RETAINED page state.

Correctness contract pinned here:

* **survival**: a prefix-registered page whose last holder departs stays
  resident (RETAINED, index entry live) instead of returning to the free
  list, and a later admission over the same prompt hits it exactly like a
  live shared page — bitwise-identical tokens to the live-hit run, and
  retained page *contents* bitwise what a cold re-prefill would commit
  (docs/SERVING.md §9: sharing itself is not bitwise vs a raw-bf16 full
  prefill, so the oracle for a retained hit is the live hit);
* **reclaim ordering**: the retained tier is drained (LRU-first, prefix
  index invalidated atomically) before backpressure is declared or any
  victim is preempted — retention adds capacity, never steals it;
* **invisibility**: with no cross-request prompt reuse, retention changes
  no output under pressure, preemption, async runtime, or spec-decode;
* **auditability**: a seeded retained/index mismatch is detected;
* **fault discipline**: the seeded ``evict_storm`` fault force-reclaims
  retained pages deterministically and replays from its seed.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve import (
    FaultPlan,
    PagePool,
    Request,
    ServeEngine,
    audit_engine,
)
from repro.serve.telemetry import MetricsRegistry

BLOCK = 32


# --------------------------------------------------------------------------
# PagePool units: the third state's accounting
# --------------------------------------------------------------------------

def _retaining_pool(n=10, scratch=2, **kw):
    pool = PagePool(n, n_scratch=scratch, **kw)
    pool.retainable = lambda page: True
    return pool


def test_free_moves_retainable_page_to_retained_tier():
    pool = _retaining_pool()
    released = []
    pool.on_release = released.append
    pool.reserve(2)
    a, b = pool.alloc(), pool.alloc()
    pool.free(a)
    pool.free(b)
    # retained, not free: still counted in n_used, on_release NOT fired
    assert pool.n_retained == 2 and pool.retained_pages() == [a, b]
    assert pool.is_retained(a) and pool.refcount(a) == 0
    assert pool.n_used == 2 and pool.committed == 2
    assert a not in pool.free_pages() and b not in pool.free_pages()
    assert released == []
    # non-retainable pages keep the old lifecycle
    pool.retainable = lambda page: False
    pool.reserve(1)
    c = pool.alloc()
    pool.free(c)
    assert not pool.is_retained(c) and c in pool.free_pages()
    assert released == [c]


def test_retain_promotes_retained_page_back_to_committed():
    pool = _retaining_pool()
    pool.reserve(1, owner="alice")
    a = pool.alloc(owner="alice")
    pool.free(a, owner="alice")
    assert pool.is_retained(a)
    used_before, committed_before = pool.n_used, pool.committed
    promoted = pool.retain(a, owner="bob")
    assert promoted is True  # the scheduler counts these as retained hits
    # budget-neutral: the page was already in n_used
    assert pool.n_used == used_before and pool.committed == committed_before
    assert not pool.is_retained(a)
    assert pool.refcount(a) == 1 and pool.holders(a) == ["bob"]
    # a plain share of a live page is not a promotion
    assert pool.retain(a, owner="carol") is False
    pool.free(a, owner="bob")
    pool.free(a, owner="carol")


def test_reserve_reclaims_lru_retained_before_backpressure():
    metrics = MetricsRegistry()
    pool = _retaining_pool(10, 2, metrics=metrics)  # capacity 8
    released = []
    pool.on_release = released.append
    pool.reserve(3)
    pages = [pool.alloc() for _ in range(3)]
    for p in pages:
        pool.free(p)
    assert pool.n_retained == 3
    # 8 capacity - 3 retained-in-use = 5 guaranteed; asking 7 must reclaim
    # exactly 2 pages, LRU-oldest first, firing on_release for each
    assert pool.reserve(7) is True
    assert pool.n_retained == 1 and pool.retained_pages() == [pages[2]]
    assert released == pages[:2]
    assert pool.reclaim_count == 2
    assert metrics.value("retained_reclaims") == 2
    # over-asking reclaims the rest, then still refuses honestly
    assert pool.reserve(5) is False
    assert pool.n_retained == 0 and released == pages
    assert pool.reserved == 7  # the failed reserve changed no accounting


def test_covered_alloc_reclaims_when_free_list_is_dry():
    pool = _retaining_pool(6, 2)  # capacity 4
    pool.reserve(4)
    pages = [pool.alloc() for _ in range(4)]
    for p in pages:
        pool.free(p)
    assert pool.n_free == 0 and pool.n_retained == 4
    # retained pages count as used, so this reserve must first reclaim
    assert pool.reserve(2)
    got = [pool.alloc(), pool.alloc()]
    assert set(got) == set(pages[:2])  # LRU order: oldest reclaimed first
    assert pool.n_retained == 2
    for p in got:
        pool.free(p)


def test_shard_pinned_alloc_and_shard_local_reclaim():
    pool = PagePool(12, n_scratch=2, shards=3)  # shards: [2,3],[4..7],[8..11]
    pool.retainable = lambda page: True
    assert pool.shard_of(5) == 1 and pool.shard_of(8) == 2
    pool.reserve(4)
    a = pool.alloc(shard=1)
    assert pool.shard_of(a) == 1
    # unpinned allocs round-robin across shards with free pages
    spread = {pool.shard_of(pool.alloc()) for _ in range(3)}
    assert spread == {0, 1, 2}
    # drain shard 1 then retain its last page: a pinned alloc must reclaim
    # in-shard even while other shards have free pages
    while pool.shard_free(1):
        pool.reserve(1)
        pool.alloc(shard=1)
    pool.free(a)
    assert pool.is_retained(a) and not pool.shard_free(1)
    assert pool.shard_available(1)  # reclaimable counts as available
    pool.reserve(1)
    again = pool.alloc(shard=1)
    assert again == a and pool.n_retained == 0
    # now shard 1 is truly dry: pinned alloc raises even with free elsewhere
    pool.reserve(1)
    with pytest.raises(RuntimeError, match="exhausted in shard 1"):
        pool.alloc(shard=1)
    assert not pool.shard_available(1)
    assert pool.n_free > 0  # the pool as a whole was not empty


def test_force_reclaim_is_lru_ordered_and_bounded():
    pool = _retaining_pool(8, 2)
    released = []
    pool.on_release = released.append
    pool.reserve(3)
    pages = [pool.alloc() for _ in range(3)]
    for p in pages:
        pool.free(p)
    assert pool.reclaim_retained(2) == 2
    assert released == pages[:2]  # oldest-departed first
    assert pool.reclaim_retained(99) == 1  # bounded by the tier
    assert pool.reclaim_retained(1) == 0  # empty tier is a safe no-op


def test_incremental_and_full_gauge_modes_agree():
    for mode in ("incremental", "full"):
        metrics = MetricsRegistry()
        pool = _retaining_pool(8, 2, metrics=metrics, gauge_mode=mode)
        pool.reserve(3)
        pages = [pool.alloc() for _ in range(2)]
        pool.free(pages[0])
        assert metrics.value("pool_pages_used") == 2
        assert metrics.value("pool_pages_retained") == 1
        assert metrics.value("pool_pages_reserved") == 1
        assert metrics.value("pool_pages_committed") == 3


# --------------------------------------------------------------------------
# Engine integration
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, rng, n):
    return rng.integers(0, cfg.vocab, n).astype(np.int32)


def test_holder_departure_survival_and_bitwise_readmission(small_model):
    """Tentpole acceptance: A's prefix pages survive A's departure; B's
    re-admission hits them and produces bitwise the tokens of a *live* hit
    (same share structure, donor still resident), and the retained pages'
    contents are bitwise what B's own cold prefill would have committed."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    pa = _prompt(cfg, rng, 3 * BLOCK)

    # oracle 1: live hit — A still resident when B admits
    live = ServeEngine(model, params, slots=2, max_seq=256)
    la = Request(uid=0, prompt=pa.copy(), max_new_tokens=40)
    lb = Request(uid=1, prompt=pa.copy(), max_new_tokens=4)
    live.submit(la)
    live.step()
    live.submit(lb)
    live.run()

    # oracle 2: cold re-prefill — retention off, A fully departed
    cold = ServeEngine(model, params, slots=2, max_seq=256)
    ca = Request(uid=0, prompt=pa.copy(), max_new_tokens=4)
    cold.submit(ca)
    cold.run()
    assert cold.pool.n_retained == 0 and len(cold.sched.index) == 0

    # retention on: A departs, pages move to RETAINED, index stays live
    engine = ServeEngine(model, params, slots=2, max_seq=256,
                         retain_prefix=True)
    a = Request(uid=0, prompt=pa.copy(), max_new_tokens=4)
    engine.submit(a)
    engine.run()
    assert a.done and a.out_tokens == ca.out_tokens
    assert engine.pool.n_retained == 3  # all three full prompt blocks
    assert len(engine.sched.index) == 3
    retained = engine.pool.retained_pages()
    assert all(engine.pool.refcount(p) == 0 for p in retained)
    # retained page contents == the cold engine's committed pages, bitwise
    for blk, (rp, cp) in enumerate(zip(a.pages[:3], ca.pages[:3])):
        ours = np.asarray(engine.state["caches"][0].kw[:, rp])
        theirs = np.asarray(cold.state["caches"][0].kw[:, cp])
        np.testing.assert_array_equal(ours, theirs, err_msg=f"block {blk}")

    prefilled_before = engine.stats["prefill_tokens"]
    b = Request(uid=1, prompt=pa.copy(), max_new_tokens=4)
    engine.submit(b)
    engine.run()
    assert b.done
    # the hit promoted retained pages (capped at one-suffix-token rule)
    assert b.shared_pages == a.pages[:2]
    assert engine.sched.stats["prefix_retained_hits"] == 2
    assert engine.stats["prefill_tokens"] - prefilled_before == BLOCK
    assert engine.stats["prefill_tokens_saved"] == 2 * BLOCK
    # bitwise the live-hit tokens — retention is invisible to the sharer
    assert b.out_tokens == lb.out_tokens
    assert audit_engine(engine).ok
    assert engine.summary()["prefix_hit_rate"] > 0


def _workload(cfg, n=5):
    """Distinct multi-block prompts (no cross-request sharing), decode
    spanning block boundaries — the pressure harness's canonical shape."""
    rng = np.random.default_rng(42)
    return [
        Request(
            uid=i,
            prompt=rng.integers(
                0, cfg.vocab, int(rng.integers(34, 48))).astype(np.int32),
            max_new_tokens=int(rng.integers(24, 32)),
        )
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def baseline_outputs(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128)
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    return {r.uid: list(r.out_tokens) for r in reqs}


def test_reclaim_drains_retained_before_preemption(small_model,
                                                   baseline_outputs):
    """With the pool oversubscribed and every completed prompt leaving
    retained pages behind, admission/extension pressure is served by
    reclaiming the tier — outputs stay bitwise the unpressured run, and a
    retention run never preempts more than the retention-free run."""
    cfg, model, params = small_model

    def run(**kw):
        engine = ServeEngine(model, params, slots=2, max_seq=128,
                             n_pages=2 + 4, reserve_policy="expected",
                             expected_quantile=0.0, audit_every=1, **kw)
        reqs = _workload(cfg)
        for r in reqs:
            engine.submit(r)
        engine.run()
        return engine, reqs

    eng_off, _ = run()
    engine, reqs = run(retain_prefix=True)
    assert all(r.done for r in reqs), [r.phase for r in reqs]
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid]
    # pressure was real and the tier absorbed it
    assert engine.pool.reclaim_count > 0
    assert engine.stats["retained_reclaims"] == engine.pool.reclaim_count
    assert engine.stats["preempted"] <= eng_off.stats["preempted"]
    # drain leaves the survivors retained but accounted: every page is
    # free or retained, nothing leaked, nothing reserved
    assert engine.pool.reserved == 0
    assert engine.pool.n_free + engine.pool.n_retained == engine.pool.capacity
    assert audit_engine(engine).ok


@pytest.mark.parametrize("mode", ["async", "spec", "pressure"])
def test_retention_invisible_across_runtime_matrix(small_model,
                                                   baseline_outputs, mode):
    """No cross-request sharing -> retention must change no output, under
    the async runtime, spec-decode, and pool pressure alike."""
    cfg, model, params = small_model
    kw = {
        "async": dict(async_runtime=True),
        "spec": dict(spec_k=3),
        "pressure": dict(n_pages=2 + 4, reserve_policy="expected",
                         expected_quantile=0.0),
    }[mode]
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         retain_prefix=True, **kw)
    reqs = _workload(cfg)
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs), [r.phase for r in reqs]
    for r in reqs:
        assert r.out_tokens == baseline_outputs[r.uid], mode
    assert engine.pool.n_retained > 0  # the tier was actually populated
    assert audit_engine(engine).ok


def test_retained_readmission_identical_across_runtimes(small_model):
    """The retained-hit path itself is runtime-invariant: sync, async and
    spec-decode re-admissions over a retained prefix emit identical
    streams."""
    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    pa = _prompt(cfg, rng, 3 * BLOCK)

    def run(**kw):
        engine = ServeEngine(model, params, slots=2, max_seq=256,
                             retain_prefix=True, **kw)
        a = Request(uid=0, prompt=pa.copy(), max_new_tokens=4)
        engine.submit(a)
        engine.run()
        b = Request(uid=1, prompt=pa.copy(), max_new_tokens=6)
        engine.submit(b)
        engine.run()
        assert engine.sched.stats["prefix_retained_hits"] > 0
        return list(b.out_tokens)

    sync = run()
    assert run(async_runtime=True) == sync
    assert run(spec_k=3) == sync


def test_auditor_detects_retained_index_mismatch(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=256,
                         retain_prefix=True)
    rng = np.random.default_rng(12)
    a = Request(uid=0, prompt=_prompt(cfg, rng, 2 * BLOCK), max_new_tokens=4)
    engine.submit(a)
    engine.run()
    assert engine.pool.n_retained == 2
    assert audit_engine(engine).ok
    # seed breach 1: index forgets a page the pool still retains
    page = engine.pool.retained_pages()[0]
    engine.sched.index.forget_page(page)
    report = audit_engine(engine)
    assert not report.ok
    assert any("not registered" in v for v in report.violations)
    # seed breach 2: the same page also appears on a free list
    engine.sched.index.register(
        engine.sched.index.chain(a.prompt)[:1], [page], a.prompt
    )
    engine.pool._shard_free[0].append(page)
    report = audit_engine(engine)
    assert not report.ok
    assert any("free" in v and "retained" in v for v in report.violations)


def test_evict_storm_fault_is_deterministic_and_survivable(small_model):
    """The seeded evict_storm force-reclaims retained pages mid-run: the
    victims' index entries invalidate atomically, later admissions just
    re-prefill cold, outputs for untouched requests are unchanged, and the
    whole scenario replays bitwise from its seed."""
    cfg, model, params = small_model
    rng = np.random.default_rng(13)
    pa = _prompt(cfg, rng, 3 * BLOCK)

    def run():
        # fire every cycle: the firing after A's departure (the storm
        # consult precedes admission within a cycle) prunes the tier
        # before B's lookup can hit it
        plan = FaultPlan(seed=3, fire_at={"evict_storm": tuple(range(32))},
                         storm_pages=2)
        engine = ServeEngine(model, params, slots=2, max_seq=256,
                             retain_prefix=True, faults=plan, audit_every=1)
        a = Request(uid=0, prompt=pa.copy(), max_new_tokens=4)
        engine.submit(a)
        engine.run()  # A departs -> 3 retained
        retained_after_a = engine.pool.n_retained
        b = Request(uid=1, prompt=pa.copy(), max_new_tokens=4)
        engine.submit(b)
        engine.run()
        return engine, plan, a, b, retained_after_a

    engine, plan, a, b, retained_after_a = run()
    assert retained_after_a == 3
    assert plan.fired("evict_storm") >= 1
    assert engine.pool.reclaim_count >= 2
    # the storm emptied the leading chain before B admitted: B re-prefilled
    # cold instead of hitting the pruned tier
    assert engine.sched.stats["prefix_retained_hits"] == 0
    assert engine.stats["faults_injected"] >= 1
    assert a.done and b.done
    assert audit_engine(engine).ok
    engine2, plan2, a2, b2, _ = run()
    assert plan2.log == plan.log
    assert a2.out_tokens == a.out_tokens and b2.out_tokens == b.out_tokens


def test_evict_storm_with_empty_tier_is_noop(small_model):
    cfg, model, params = small_model
    plan = FaultPlan(seed=4, evict_storm=1.0, storm_pages=4)
    engine = ServeEngine(model, params, slots=2, max_seq=128, faults=plan)
    reqs = _workload(cfg, n=2)
    for r in reqs:
        engine.submit(r)
    engine.run()  # retention off: the tier is always empty
    assert all(r.done for r in reqs)
    assert plan.fired("evict_storm") > 0
    assert engine.pool.reclaim_count == 0
    assert audit_engine(engine).ok


def test_retain_prefix_off_is_bitwise_seed_behavior(small_model):
    """Default-off: without retain_prefix the pool never retains and drain
    invariants stay exactly the pre-tier contract."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=256)
    rng = np.random.default_rng(14)
    a = Request(uid=0, prompt=_prompt(cfg, rng, 2 * BLOCK), max_new_tokens=4)
    engine.submit(a)
    engine.run()
    assert engine.pool.n_retained == 0
    assert engine.pool.n_free == engine.pool.capacity
    assert len(engine.sched.index) == 0
