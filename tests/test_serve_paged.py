"""Paged serving subsystem: page-pool allocator, scheduler lifecycle,
bucketed prefill compile behaviour, backpressure/reclaim, and full-engine
paged-vs-dense parity (the PR's acceptance criterion)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.core import qcache
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.pages import PagePool
from repro.serve.scheduler import Phase, Scheduler, bucket_for


# --------------------------------------------------------------------------
# PagePool unit behaviour
# --------------------------------------------------------------------------

def test_pagepool_freelist_and_refcounts():
    pool = PagePool(8, n_scratch=2)
    assert pool.capacity == 6 and pool.n_free == 6
    assert pool.reserve(6)
    assert not pool.reserve(1)  # full commitment -> backpressure
    a, b = pool.alloc(), pool.alloc()
    assert a >= 2 and b >= 2 and a != b  # scratch pages never allocated
    assert pool.n_used == 2
    # allocs converted two reserved units into allocated ones; the
    # commitment total is unchanged (shared budget, counted once)
    assert pool.reserved == 4 and pool.committed == 6
    assert not pool.reserve(1)
    pool.retain(a)
    assert pool.refcount(a) == 2
    pool.free(a)
    assert pool.n_used == 2  # refcount 1 left -> not yet returned
    pool.free(a)
    pool.free(b)
    assert pool.n_free == 6
    pool.release(4)  # the never-allocated remainder
    assert pool.reserved == 0
    assert pool.reserve(1)
    with pytest.raises(ValueError):
        pool.free(b)  # double free


def test_pagepool_alloc_without_reservation_guard():
    pool = PagePool(3, n_scratch=1)
    with pytest.raises(RuntimeError):
        pool.alloc()  # covered alloc with no reservation outstanding
    pool.alloc(covered=False)
    pool.alloc(covered=False)
    with pytest.raises(RuntimeError):
        pool.alloc(covered=False)  # exhausted: would over-commit


# --------------------------------------------------------------------------
# Scheduler: admission order, bucketing, backpressure
# --------------------------------------------------------------------------

def _req(uid, plen, max_new=4):
    return Request(uid=uid, prompt=np.zeros(plen, np.int32), max_new_tokens=max_new)


def test_bucket_for_powers_of_two():
    assert bucket_for(1) == 16
    assert bucket_for(16) == 16
    assert bucket_for(17) == 32
    assert bucket_for(100) == 128


def test_admission_fifo_order_and_grouping():
    pool = PagePool(32, n_scratch=4)
    sched = Scheduler(slots=4, pool=pool, block_n=32, max_seq=256)
    for i, plen in enumerate([5, 20, 7, 40, 9]):  # buckets 16,32,16,64,16
        sched.submit(_req(i, plen))
    groups = sched.admit()  # 4 slots -> first four admitted, FIFO
    admitted = [r.uid for g in groups.values() for r in g]
    assert sorted(admitted) == [0, 1, 2, 3]
    # slots assigned in submission order
    assert [sched.active[s].uid for s in sorted(sched.active)] == [0, 1, 2, 3]
    assert [r.uid for r in groups[16]] == [0, 2]
    assert [r.uid for r in groups[32]] == [1]
    assert [r.uid for r in groups[64]] == [3]
    assert all(r.phase == Phase.PREFILL for g in groups.values() for r in g)
    # uid 4 waits for a slot; completing uid 0 frees one
    sched.complete(sched.active[0])
    (g,) = sched.admit().values()
    assert [r.uid for r in g] == [4]


def test_admission_backpressure_is_strict_fifo():
    pool = PagePool(8, n_scratch=2)  # capacity 6
    sched = Scheduler(slots=4, pool=pool, block_n=32, max_seq=1024)
    big = _req(0, 150, max_new=50)  # needs (150+50)//32 = 6 pages
    small = _req(1, 5, max_new=4)   # needs 0 pages
    pool.reserve(1)  # someone already holds a page
    sched.submit(big)
    sched.submit(small)
    groups = sched.admit()
    # head can't reserve -> nothing admitted, nothing overtakes it
    assert groups == {}
    assert sched.stats["backpressure_events"] == 1
    pool.release(1)
    groups = sched.admit()
    admitted = [r.uid for g in groups.values() for r in g]
    assert admitted == [0, 1]
    assert pool.reserved == 6


# --------------------------------------------------------------------------
# Engine: bucketed prefill compiles, backpressure/reclaim, parity
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_prefill_bucketing_one_compile_per_bucket(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=4, max_seq=128, min_bucket=16)
    assert engine.paged
    rng = np.random.default_rng(0)

    def sub(plen, uid):
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=2))

    sub(5, 0)   # bucket 16
    sub(9, 1)   # bucket 16 (same cycle, same call)
    sub(20, 2)  # bucket 32
    engine.step()
    assert engine.stats["prefill_calls"] == 2  # one per bucket this cycle
    assert engine._prefill._cache_size() == 2
    sub(11, 3)  # bucket 16 again, later cycle: new call, NO new compile
    engine.run()
    assert engine.stats["prefill_calls"] == 3
    assert engine._prefill._cache_size() == 2  # jit cache keyed on bucket


def test_page_exhaustion_backpressure_and_reclaim(small_model):
    cfg, model, params = small_model
    # capacity 2 pages: each request needs (30+6)//32 = 1 page -> two in
    # flight, the third waits for a completion to return pages
    engine = ServeEngine(model, params, slots=3, max_seq=64,
                         n_pages=3 + 2)
    assert engine.pool.capacity == 2
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 30).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.step()
    assert len(engine.sched.active) == 2  # third hit backpressure
    assert engine.sched.stats["backpressure_events"] >= 1
    stats = engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    # pages reclaimed, reservations returned
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert stats["sched_completed"] == 3


def test_preempt_free_steady_state(small_model):
    """Admission reservations guarantee decode-time page allocation never
    fails: a saturating mixed workload completes with every allocation
    served from the free list (alloc raises if the invariant breaks)."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128,
                         n_pages=2 + 4)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 60))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 10)))
            for i in range(6)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    assert engine.pool.n_free == engine.pool.capacity


def test_paged_engine_matches_dense_oracle(small_model):
    """Acceptance criterion: a mixed workload (short + multi-block prompts,
    staggered arrivals) through the paged engine produces per-token outputs
    identical to a dense-cache single-request oracle."""
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    specs = [(30, 6), (7, 5), (44, 4)]  # (prompt_len, max_new); 30+6 crosses
    prompts = [rng.integers(0, cfg.vocab, l).astype(np.int32) for l, _ in specs]

    def oracle(prompt, max_new):
        logits, st = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, 128)
        tok = int(np.argmax(np.asarray(logits)[0, -1]))
        step = jax.jit(functools.partial(model.decode_step, impl="auto",
                                         quant_impl="auto"))
        out = []
        for _ in range(max_new):
            out.append(tok)
            logits, st = step(params, st, jnp.asarray([[tok]], jnp.int32))
            tok = int(np.argmax(np.asarray(logits)[0, 0]))
        return out

    want = [oracle(p, mn) for p, (_, mn) in zip(prompts, specs)]

    engine = ServeEngine(model, params, slots=2, max_seq=128)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, specs))]
    engine.submit(reqs[0])  # staggered arrivals
    engine.step()
    engine.submit(reqs[1])
    engine.step()
    engine.submit(reqs[2])
    engine.run()
    for i, (r, w) in enumerate(zip(reqs, want)):
        assert r.done
        assert r.out_tokens == w, f"request {i} diverged from dense oracle"


# --------------------------------------------------------------------------
# Paged append: gated fused flush (jaxpr proof) + cache math
# --------------------------------------------------------------------------

def _collect_prims(jaxpr, into):
    import jax.core as jc

    for e in jaxpr.eqns:
        into.add(e.primitive.name)
        for val in e.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for w in vals:
                if isinstance(w, jc.ClosedJaxpr):
                    _collect_prims(w.jaxpr, into)
    return into


@pytest.mark.parametrize("quant_impl", ["xla", "pallas"])
def test_paged_hot_path_does_no_quant_work(quant_impl):
    """The acceptance criterion's jaxpr proof, paged edition: quantize/pack
    work lives exclusively inside the flush branch of a single `cond`; the
    per-token paged append traced at the top level carries none of it."""
    pc = qcache.init_paged_cache(12, 2, 2, 128, 4, bits=4, block_n=128)
    k = jnp.ones((2, 2, 1, 128), jnp.bfloat16)
    v = jnp.ones((2, 2, 1, 128), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(
        functools.partial(qcache.paged_append_decode, quant_impl=quant_impl)
    )(pc, k, v)
    quant_marker = "pallas_call" if quant_impl == "pallas" else "shift_left"
    top = [e.primitive.name for e in jaxpr.jaxpr.eqns]
    assert "cond" in top
    assert quant_marker not in top and "round" not in top
    (cond_eqn,) = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "cond"]
    branch_has_quant = [
        quant_marker in _collect_prims(br.jaxpr, set())
        for br in cond_eqn.params["branches"]
    ]
    assert sum(branch_has_quant) == 1, branch_has_quant


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_flush_commits_through_table(impl):
    """Filling slot 1's residual commits its quantized block into the pool
    page its table points at; other pool pages (incl. scratch) are unchanged;
    the dense flush of the same content produces bitwise-identical packing."""
    import dataclasses

    from repro.kernels.kv_quant import ref as kq_ref

    B, H, D, BLOCK = 3, 2, 128, 128
    pc = qcache.init_paged_cache(12, B, H, D, 4, bits=4, block_n=BLOCK)
    table = np.asarray(pc.page_table).copy()
    table[1, 0] = 7
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    k = jax.random.normal(ks[0], (B, H, BLOCK, D)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (B, H, BLOCK, D)).astype(jnp.bfloat16)
    pc = dataclasses.replace(
        pc, page_table=jnp.asarray(table),
        k_res=pc.k_res.at[1, :, : BLOCK - 1].set(k[1, :, : BLOCK - 1]),
        v_res=pc.v_res.at[1, :, : BLOCK - 1].set(v[1, :, : BLOCK - 1]),
        res_len=jnp.asarray([3, BLOCK - 1, 0], jnp.int32),
    )
    pc2 = qcache.paged_append_decode(
        pc, k[:, :, BLOCK - 1 : BLOCK], v[:, :, BLOCK - 1 : BLOCK],
        quant_impl=impl,
    )
    assert int(pc2.pack_blocks[1]) == 1 and int(pc2.res_len[1]) == 0
    assert int(pc2.res_len[0]) == 4 and int(pc2.res_len[2]) == 1
    # page 7 now holds the quantized block; parity vs direct quantization
    kw_want, ks_want, kz_want = kq_ref.quantize_kv_ref(
        np.asarray(pc2.k_res[1])[None], 4, "channel", block_n=BLOCK
    )
    np.testing.assert_array_equal(np.asarray(pc2.kw[7]), np.asarray(kw_want)[0, :, 0])
    np.testing.assert_array_equal(
        np.asarray(pc2.k_scale[7]), np.asarray(ks_want)[0, :, 0])
    # untouched pages stay zero (e.g. page 8 and slot 0's scratch page 0)
    assert not np.asarray(pc2.kw[8]).any()
    assert not np.asarray(pc2.kw[3]).any()


def test_ragged_prefill_matches_exact(small_model):
    """Bucket-padded ragged prefill: occupancy + residual + logits equal the
    exact-length prefill per sequence."""
    cfg, model, params = small_model
    rng = np.random.default_rng(4)
    L = 64
    lens = [64, 37, 10]
    toks = np.zeros((3, L), np.int32)
    prompts = []
    for i, l in enumerate(lens):
        p = rng.integers(0, cfg.vocab, l).astype(np.int32)
        prompts.append(p)
        toks[i, :l] = p
    logits_r, st_r = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, L,
        lengths=jnp.asarray(lens, jnp.int32),
    )
    for i, (p, l) in enumerate(zip(prompts, lens)):
        lg, st = model.prefill(params, {"tokens": jnp.asarray(p[None])}, L)
        np.testing.assert_allclose(
            np.asarray(logits_r)[i, 0], np.asarray(lg)[0, 0],
            rtol=2e-3, atol=2e-3)
        c_r, c_1 = st_r["caches"][0], st["caches"][0]
        assert int(c_r.pack_blocks[0, i]) == int(c_1.pack_blocks[0, 0]) == l // cfg.kv_block
        rl = l % cfg.kv_block
        assert int(c_r.res_len[0, i]) == rl
        if rl:
            np.testing.assert_allclose(
                np.asarray(c_r.k_res)[:, i, :, :rl],
                np.asarray(c_1.k_res)[:, 0, :, :rl], rtol=2e-2, atol=2e-2)
        # valid packed blocks are bitwise identical (per-block quantization)
        nblk = l // cfg.kv_block
        if nblk:
            np.testing.assert_array_equal(
                np.asarray(c_r.kw)[:, i, :, :nblk],
                np.asarray(c_1.kw)[:, 0, :, :nblk])
        assert int(st_r["pos"][i]) == l


def test_mesh_aligned_init_cache_block_align():
    c = qcache.init_cache(1, 2, 64, 5 * 128, block_align=4)
    assert c.kw.shape[2] % 4 == 0
    c2 = qcache.init_cache(1, 2, 64, 5 * 128)
    assert c2.kw.shape[2] == 5


def test_mla_serves_paged_by_default():
    """MLA's latent cache now pages (shared_kv pools) — no dense fork."""
    cfg = smoke_config("deepseek-v3-671b").with_(kv_bits=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=2, max_seq=64)
    assert engine.paged and engine.spec.shared_kv
    assert engine.state["caches"][0].vw is None  # no V-side pools at all
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    assert all(r.done for r in reqs)
    assert stats["decoded_tokens"] == 9


def test_nokv_shim_engine_serves_and_accounts():
    """xLSTM (no KV anywhere) serves through the exact-length shim: same
    scheduler, same decode cycle, per-token accounting intact (pos advances
    with every decoded token; budget retirement counted exactly once)."""
    cfg = smoke_config("xlstm-1.3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=2, max_seq=64)
    assert not engine.paged and engine.pool is None
    rng = np.random.default_rng(6)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    assert all(r.done for r in reqs)
    assert all(r.pos == 7 + 3 for r in reqs)  # the dense-shim drift fix
    assert stats["decoded_tokens"] == 9
    assert stats["budget_retired"] == 3  # counted exactly once each


def test_forced_shim_matches_paged_outputs():
    """`paged=False` forces the exact-length shim for a paged-capable model;
    outputs stay bitwise identical to the paged engine."""
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (9, 40)]

    def run(paged):
        engine = ServeEngine(model, params, slots=2, max_seq=128, paged=paged)
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        return [r.out_tokens for r in reqs], engine

    want, shim = run(False)
    assert not shim.paged
    got, paged_eng = run(None)
    assert paged_eng.paged
    assert got == want
