"""Generative invariant testing for the serving engine: random operation
sequences (submit / cancel / step / clock-advance / preempt, under a drawn
fault plan and a drawn speculative config) must keep the four-view page
ownership audit (serve/audit.py) clean after EVERY operation, and every
engine must drain to a fully-returned pool.

This is the property layer on top of the scenario tests
(test_serve_pressure.py, test_serve_spec.py): those pin specific
interleavings; this one searches the interleaving space.  Requires
``hypothesis`` (skipped when absent — CI installs it via
requirements-test.txt).
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs.base import smoke_config  # noqa: E402
from repro.models.zoo import build_model  # noqa: E402
from repro.serve import FaultPlan, Phase, Request, ServeEngine  # noqa: E402
from repro.serve.audit import audit_engine  # noqa: E402

BLOCK = 32


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# one operation = (kind, payload); payloads are drawn small so sequences
# stay inside max_seq=128 and a couple of engine cycles each
_op = st.one_of(
    st.tuples(st.just("submit"),
              st.tuples(st.integers(5, 45),      # prompt length
                        st.integers(2, 12),      # max_new_tokens
                        st.sampled_from([None, 3.0, 50.0]))),  # deadline_s
    st.tuples(st.just("cancel"), st.integers(0, 7)),   # uid (may not exist)
    st.tuples(st.just("step"), st.just(None)),
    st.tuples(st.just("tick"), st.floats(0.5, 4.0)),   # advance fake clock
    st.tuples(st.just("preempt"), st.just(None)),      # forced victim pick
)


@settings(max_examples=12, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(
    ops=st.lists(_op, min_size=3, max_size=10),
    spec_k=st.sampled_from([1, 2, 3]),
    fault_seed=st.integers(0, 2**16),
    alloc_fail=st.sampled_from([0.0, 0.3]),
    n_pages=st.sampled_from([None, 2 + 3]),
)
def test_random_op_sequences_keep_audit_clean(small_model, ops, spec_k,
                                              fault_seed, alloc_fail,
                                              n_pages):
    cfg, model, params = small_model
    now = [0.0]
    plan = (FaultPlan(seed=fault_seed, alloc_fail=alloc_fail,
                      forced_preempt=0.1)
            if alloc_fail else None)
    engine = ServeEngine(
        model, params, slots=2, max_seq=128, spec_k=spec_k,
        n_pages=n_pages, faults=plan, clock=lambda: now[0],
        reserve_policy="expected" if n_pages else "worst_case",
        expected_quantile=0.0,
    )
    rng = np.random.default_rng(fault_seed)
    submitted = {}
    uid = 0
    for kind, payload in ops:
        if kind == "submit":
            plen, max_new, ttl = payload
            req = Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new_tokens=max_new, deadline_s=ttl,
            )
            submitted[uid] = req
            engine.submit(req)
            uid += 1
        elif kind == "cancel":
            engine.cancel(payload)  # unknown uids must be a clean no-op
        elif kind == "step":
            engine.step()
        elif kind == "tick":
            now[0] += payload
        elif kind == "preempt":
            victim = engine._pick_victim()
            if victim is not None:
                engine._preempt(victim)
        audit_engine(engine).raise_if_violations()

    engine.run()
    audit_engine(engine).raise_if_violations()
    # drain invariants: pool fully returned, reservations zero, and every
    # submitted request reached a terminal phase
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0
    assert not engine._deferred
    for req in submitted.values():
        assert req.finished, (req.uid, req.phase)
    s = engine.stats
    assert s["spec_draft_tokens"] == (
        s["spec_accepted_tokens"] + s["spec_rejected_tokens"]
    )
