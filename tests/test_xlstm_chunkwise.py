"""Chunkwise-parallel mLSTM == stabilized sequential cell, exactly.

Property (hypothesis): equality holds for any sequence length / chunk split
and any gate statistics (including large input gates that would overflow an
unstabilized formulation)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import xlstm

B, H, DH = 2, 3, 16


def _seq_reference(q, k, v, i_pre, f_pre, state):
    def step(st, xs):
        return xlstm._mlstm_cell(st, xs)

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state


@hypothesis.given(
    s=st.sampled_from([32, 64, 96]),
    chunk=st.sampled_from([16, 32]),
    gate_scale=st.sampled_from([1.0, 5.0, 20.0]),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_chunkwise_equals_sequential(s, chunk, gate_scale, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, s, H, DH), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, H, DH), jnp.float32) / DH**0.5
    v = jax.random.normal(ks[2], (B, s, H, DH), jnp.float32)
    i_pre = gate_scale * jax.random.normal(ks[3], (B, s, H), jnp.float32)
    f_pre = gate_scale * jax.random.normal(ks[4], (B, s, H), jnp.float32)
    state = xlstm.mlstm_init_state(
        type("cfg", (), {"n_heads": H, "d_model": H * DH})(), B)

    ref, st_ref = _seq_reference(q, k, v, i_pre, f_pre, state)
    out, st_out = xlstm.mlstm_chunkwise(q, k, v, i_pre, f_pre, state, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_out["m"]), np.asarray(st_ref["m"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_out["C"]), np.asarray(st_ref["C"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_out["n"]), np.asarray(st_ref["n"]),
                               rtol=2e-4, atol=2e-4)
