"""Paged (Page-setting) kernel vs oracle, including shared page pools with
scrambled page tables and per-sequence lengths, and the shared_kv (MLA
latent-pool) parity grid against the dense shared_kv oracle."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.kv_quant import ref as kq_ref
from repro.kernels.paged_bitdecode import ops as pg_ops


def _make(key, *, b, h, g, d, n_pages, nb, block_n, bits, k_gran):
    ks = jax.random.split(key, 6)
    # quantize a pool of pages from random K/V content
    k = jax.random.normal(ks[0], (1, h, n_pages * block_n, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (1, h, n_pages * block_n, d), jnp.float32).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(k, bits, k_gran, block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(v, bits, "tensor", block_n=block_n)
    # pools: [P, H, ...]
    pool = lambda x: jnp.moveaxis(x[0], 1, 0)  # noqa: E731
    q = jax.random.normal(ks[2], (b, h, g, d), jnp.float32).astype(jnp.bfloat16)
    k_res = jax.random.normal(ks[3], (b, h, block_n, d), jnp.float32).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, block_n, d), jnp.float32).astype(jnp.bfloat16)
    table = jax.random.permutation(ks[5], n_pages)[: b * nb].reshape(b, nb).astype(jnp.int32)
    return (q, pool(kw), pool(ksc), pool(kzp), pool(vw), pool(vsc), pool(vzp),
            k_res, v_res, table)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
def test_paged_matches_ref(bits, k_gran):
    b, h, g, d, block_n, nb, n_pages = 2, 2, 8, 128, 128, 3, 8
    args = _make(jax.random.PRNGKey(0), b=b, h=h, g=g, d=d, n_pages=n_pages,
                 nb=nb, block_n=block_n, bits=bits, k_gran=k_gran)
    pb = jnp.asarray([nb, nb - 1], jnp.int32)
    rl = jnp.asarray([17, 0], jnp.int32)
    fn = functools.partial(
        pg_ops.paged_bitdecode_attention, bits=bits, block_n=block_n,
        k_gran=k_gran, return_lse=True,
    )
    out_p, lse_p = fn(*args, pb, rl, impl="pallas")
    out_r, lse_r = fn(*args, pb, rl, impl="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r), rtol=1e-3, atol=1e-3)


def test_paged_equals_dense_on_same_blocks():
    """A paged cache with identity page table == the dense kernel."""
    b, h, g, d, block_n, nb = 1, 2, 4, 128, 128, 4
    args = _make(jax.random.PRNGKey(1), b=b, h=h, g=g, d=d, n_pages=nb,
                 nb=nb, block_n=block_n, bits=4, k_gran="channel")
    (q, kwp, ksp, kzp, vwp, vsp, vzp, k_res, v_res, _) = args
    table = jnp.arange(nb, dtype=jnp.int32)[None]
    pb = jnp.asarray([nb], jnp.int32)
    rl = jnp.asarray([9], jnp.int32)
    out_p = pg_ops.paged_bitdecode_attention(
        q, kwp, ksp, kzp, vwp, vsp, vzp, k_res, v_res, table, pb, rl,
        bits=4, block_n=block_n, impl="pallas")
    dense = lambda x: jnp.moveaxis(x, 0, 1)[None]  # noqa: E731
    out_d = bd_ops.bitdecode_attention(
        q, dense(kwp), dense(ksp), dense(kzp), dense(vwp), dense(vsp),
        dense(vzp), k_res, v_res, pb, rl, bits=4, block_n=block_n,
        impl="pallas")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# shared_kv (MLA latent pools): paged walk vs the dense shared_kv oracle
# --------------------------------------------------------------------------

def _make_shared(key, *, b, h, g, d, n_pages, nb, block_n, bits, k_gran):
    """Latent pool set (no V side) + scrambled table + latent residual."""
    ks = jax.random.split(key, 4)
    lat = jax.random.normal(
        ks[0], (1, h, n_pages * block_n, d), jnp.float32).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(lat, bits, k_gran, block_n=block_n)
    pool = lambda x: jnp.moveaxis(x[0], 1, 0)  # noqa: E731
    q = jax.random.normal(ks[1], (b, h, g, d), jnp.float32).astype(jnp.bfloat16)
    k_res = jax.random.normal(
        ks[2], (b, h, block_n, d), jnp.float32).astype(jnp.bfloat16)
    table = jax.random.permutation(ks[3], n_pages)[: b * nb].reshape(b, nb).astype(jnp.int32)
    return q, pool(kw), pool(ksc), pool(kzp), k_res, table


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
@pytest.mark.parametrize("num_splits", [1, 2])
@pytest.mark.parametrize("res_len", [0, 17])  # empty vs partial residual
def test_paged_shared_kv_matches_ref(bits, k_gran, num_splits, res_len):
    """The satellite grid: bits x granularity x num_splits x partial
    residual — paged shared_kv Pallas vs the (dense-ref-backed) oracle."""
    b, h, g, d, dv, block_n, nb, n_pages = 2, 1, 8, 256, 128, 64, 3, 8
    q, kwp, ksp, kzp, k_res, table = _make_shared(
        jax.random.PRNGKey(2), b=b, h=h, g=g, d=d, n_pages=n_pages, nb=nb,
        block_n=block_n, bits=bits, k_gran=k_gran)
    pb = jnp.asarray([nb, nb - 1], jnp.int32)
    rl = jnp.asarray([res_len, 0], jnp.int32)
    fn = functools.partial(
        pg_ops.paged_bitdecode_attention, bits=bits, block_n=block_n,
        k_gran=k_gran, shared_kv=True, d_v=dv, return_lse=True,
    )
    out_p, lse_p = fn(q, kwp, ksp, kzp, None, None, None, k_res, None,
                      table, pb, rl, impl="pallas", num_splits=num_splits)
    out_r, lse_r = fn(q, kwp, ksp, kzp, None, None, None, k_res, None,
                      table, pb, rl, impl="xla", num_splits=num_splits)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r),
                               rtol=1e-3, atol=1e-3)


def test_paged_shared_kv_equals_dense_shared_oracle():
    """Paged shared_kv over a scrambled table == the dense shared_kv kernel
    over the table-gathered blocks (bitwise: same compute, same order)."""
    b, h, g, d, dv, block_n, nb, n_pages = 2, 1, 8, 256, 128, 64, 3, 8
    q, kwp, ksp, kzp, k_res, table = _make_shared(
        jax.random.PRNGKey(3), b=b, h=h, g=g, d=d, n_pages=n_pages, nb=nb,
        block_n=block_n, bits=4, k_gran="channel")
    pb = jnp.asarray([nb, nb - 1], jnp.int32)
    rl = jnp.asarray([9, 0], jnp.int32)
    out_p = pg_ops.paged_bitdecode_attention(
        q, kwp, ksp, kzp, None, None, None, k_res, None, table, pb, rl,
        bits=4, block_n=block_n, shared_kv=True, d_v=dv, impl="pallas")
    gather = lambda x: jnp.moveaxis(jnp.take(x, table, axis=0), 2, 1)  # noqa: E731
    out_d = bd_ops.bitdecode_attention(
        q, gather(kwp), gather(ksp), gather(kzp), None, None, None,
        k_res, None, pb, rl, bits=4, block_n=block_n, shared_kv=True,
        d_v=dv, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_d))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_shared_flush_commits_latent_through_table(impl):
    """Shared-kv paged flush: a filled latent residual commits into the pool
    page its table points at, bitwise-identical packing to the dense shared
    flush of the same content; other pages untouched."""
    import dataclasses

    from repro.core import qcache

    B, H, D, BLOCK = 2, 1, 256, 64
    k = jax.random.normal(jax.random.PRNGKey(4), (B, H, BLOCK, D)).astype(jnp.bfloat16)
    pc = qcache.init_paged_cache(8, B, H, D, 3, bits=4, block_n=BLOCK,
                                 shared_kv=True)
    assert pc.vw is None and pc.v_res is None
    table = np.asarray(pc.page_table).copy()
    table[1, 0] = 5
    pc = dataclasses.replace(
        pc, page_table=jnp.asarray(table),
        k_res=pc.k_res.at[1, :, : BLOCK - 1].set(k[1, :, : BLOCK - 1]),
        res_len=jnp.asarray([3, BLOCK - 1], jnp.int32),
    )
    pc2 = qcache.paged_append_decode(
        pc, k[:, :, BLOCK - 1 : BLOCK], None, quant_impl=impl)
    assert int(pc2.pack_blocks[1]) == 1 and int(pc2.res_len[1]) == 0
    kw_want, ks_want, _ = kq_ref.quantize_kv_ref(
        np.asarray(pc2.k_res[1])[None], 4, "channel", block_n=BLOCK)
    np.testing.assert_array_equal(np.asarray(pc2.kw[5]),
                                  np.asarray(kw_want)[0, :, 0])
    np.testing.assert_array_equal(np.asarray(pc2.k_scale[5]),
                                  np.asarray(ks_want)[0, :, 0])
    assert not np.asarray(pc2.kw[6]).any()  # untouched page
    assert not np.asarray(pc2.kw[0]).any()  # slot 0's scratch page
