"""Paged (Page-setting) kernel vs oracle, including shared page pools with
scrambled page tables and per-sequence lengths."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kv_quant import ref as kq_ref
from repro.kernels.paged_bitdecode import ops as pg_ops


def _make(key, *, b, h, g, d, n_pages, nb, block_n, bits, k_gran):
    ks = jax.random.split(key, 6)
    # quantize a pool of pages from random K/V content
    k = jax.random.normal(ks[0], (1, h, n_pages * block_n, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (1, h, n_pages * block_n, d), jnp.float32).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(k, bits, k_gran, block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(v, bits, "tensor", block_n=block_n)
    # pools: [P, H, ...]
    pool = lambda x: jnp.moveaxis(x[0], 1, 0)  # noqa: E731
    q = jax.random.normal(ks[2], (b, h, g, d), jnp.float32).astype(jnp.bfloat16)
    k_res = jax.random.normal(ks[3], (b, h, block_n, d), jnp.float32).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, block_n, d), jnp.float32).astype(jnp.bfloat16)
    table = jax.random.permutation(ks[5], n_pages)[: b * nb].reshape(b, nb).astype(jnp.int32)
    return (q, pool(kw), pool(ksc), pool(kzp), pool(vw), pool(vsc), pool(vzp),
            k_res, v_res, table)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
def test_paged_matches_ref(bits, k_gran):
    b, h, g, d, block_n, nb, n_pages = 2, 2, 8, 128, 128, 3, 8
    args = _make(jax.random.PRNGKey(0), b=b, h=h, g=g, d=d, n_pages=n_pages,
                 nb=nb, block_n=block_n, bits=bits, k_gran=k_gran)
    pb = jnp.asarray([nb, nb - 1], jnp.int32)
    rl = jnp.asarray([17, 0], jnp.int32)
    fn = functools.partial(
        pg_ops.paged_bitdecode_attention, bits=bits, block_n=block_n,
        k_gran=k_gran, return_lse=True,
    )
    out_p, lse_p = fn(*args, pb, rl, impl="pallas")
    out_r, lse_r = fn(*args, pb, rl, impl="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_r), rtol=1e-3, atol=1e-3)


def test_paged_equals_dense_on_same_blocks():
    """A paged cache with identity page table == the dense kernel."""
    from repro.kernels.bitdecode import ops as bd_ops

    b, h, g, d, block_n, nb = 1, 2, 4, 128, 128, 4
    args = _make(jax.random.PRNGKey(1), b=b, h=h, g=g, d=d, n_pages=nb,
                 nb=nb, block_n=block_n, bits=4, k_gran="channel")
    (q, kwp, ksp, kzp, vwp, vsp, vzp, k_res, v_res, _) = args
    table = jnp.arange(nb, dtype=jnp.int32)[None]
    pb = jnp.asarray([nb], jnp.int32)
    rl = jnp.asarray([9], jnp.int32)
    out_p = pg_ops.paged_bitdecode_attention(
        q, kwp, ksp, kzp, vwp, vsp, vzp, k_res, v_res, table, pb, rl,
        bits=4, block_n=block_n, impl="pallas")
    dense = lambda x: jnp.moveaxis(x, 0, 1)[None]  # noqa: E731
    out_d = bd_ops.bitdecode_attention(
        q, dense(kwp), dense(ksp), dense(kzp), dense(vwp), dense(vsp),
        dense(vzp), k_res, v_res, pb, rl, bits=4, block_n=block_n,
        impl="pallas")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d), rtol=1e-5, atol=1e-5)
