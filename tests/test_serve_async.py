"""Async-vs-sync differential harness (ISSUE 9, docs/SERVING.md §13).

The overlapped runtime (`repro.serve.async_runtime`) restructures the
engine's decode loop — device-resident token feeds, a bounded in-flight
window, dispatch-frontier page allocation, a background completion thread —
and every one of those moving parts is only trustworthy against the
synchronous engine as oracle.  The contract proven here:

* **Bitwise parity**: identical workloads through ``async_runtime=True``
  and ``False`` produce identical token streams and terminal phases across
  cache families (paged attention, MLA, the dense xlstm shim), speculative
  decoding, prefix sharing, oversubscription/preemption, and seeded fault
  injection (schedule-invariant ``fire_at_token`` poison targeting).
* **Liveness**: a randomized admit/cancel/expire/preempt storm against the
  background completion thread under delayed-release faults finishes within
  a bounded wall clock (queue timeouts + the runner watchdog raise
  `repro.serve.async_runtime.DeadlockError` instead of hanging), with the
  invariant auditor clean at drain.
* **Exactly-once completion**: no request is lost and none is
  double-completed — the worker's ledger holds every terminal uid exactly
  once, whatever mix of DONE/CANCELLED/EXPIRED/ERRORED the storm produced.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve import (
    DeadlockError,
    FaultPlan,
    Phase,
    Request,
    ServeEngine,
    audit_engine,
)
from repro.serve.async_runtime import CompletionWorker

BLOCK = 32


def _build(arch, **cfg_kw):
    kw = {"kv_bits": 4, "kv_block": BLOCK}
    kw.update(cfg_kw)
    cfg = smoke_config(arch).with_(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def attn_model():
    return _build("llama3-8b")


@pytest.fixture(scope="module")
def mla_model():
    return _build("deepseek-v3-671b")


@pytest.fixture(scope="module")
def xlstm_model():
    return _build("xlstm-1.3b")


def _workload(cfg, n=5, seed=42, lo=34, hi=48, new_lo=24, new_hi=32):
    """Block-crossing prompts and decodes: flush-time allocation (the
    preemption site) and residual flushes actually fire."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(lo, hi)))
                     .astype(np.int32),
            max_new_tokens=int(rng.integers(new_lo, new_hi)),
        )
        for i in range(n)
    ]


def _run(model, params, reqs, *, async_runtime, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 128)
    engine = ServeEngine(model, params, async_runtime=async_runtime, **kw)
    for r in reqs:
        assert engine.submit(r)
    summary = engine.run()
    engine.close()
    return engine, summary


def _outputs(reqs):
    return {r.uid: list(r.out_tokens) for r in reqs}


def _phases(reqs):
    return {r.uid: r.phase.value for r in reqs}


def _differential(model_fixture, cfg, model, params, **engine_kw):
    """Run the same workload through both runtimes; return
    (sync_reqs, async_reqs, sync_summary, async_summary, async_engine)."""
    rs = _workload(cfg)
    ra = _workload(cfg)
    _, ss = _run(model, params, rs, async_runtime=False, **engine_kw)
    eng, sa = _run(model, params, ra, async_runtime=True, **engine_kw)
    assert _outputs(ra) == _outputs(rs), "async token streams diverged"
    assert _phases(ra) == _phases(rs), "terminal phases diverged"
    return rs, ra, ss, sa, eng


# --------------------------------------------------------------------------
# Tentpole: bitwise parity across families x pressure x faults x speculation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["attn", "mla", "xlstm"])
def test_async_matches_sync_bitwise_per_family(family, request):
    """Plain workload, every cache family (paged attention, paged MLA, the
    dense exact-length shim): identical token streams, every request DONE,
    and the completion ledger holds each uid exactly once."""
    cfg, model, params = request.getfixturevalue(f"{family}_model")
    rs, ra, _ss, sa, eng = _differential(None, cfg, model, params)
    assert all(r.done for r in ra), _phases(ra)
    ledger = eng._completions.records
    assert sorted(ledger) == sorted(r.uid for r in ra)
    assert eng._completions.duplicates == 0
    assert sa["completions_enqueued"] == len(ra)
    for r in ra:
        assert ledger[r.uid].tokens == tuple(r.out_tokens)


@pytest.mark.parametrize("window", [1, 2, 4])
def test_async_parity_any_window_depth(attn_model, window):
    """The in-flight window depth changes only *when* results are consumed,
    never what they are — including window 1 (dispatch/consume lockstep)
    and windows deeper than the retirement lag."""
    cfg, model, params = attn_model
    rs = _workload(cfg)
    ra = _workload(cfg)
    _run(model, params, rs, async_runtime=False)
    _run(model, params, ra, async_runtime=True, async_window=window)
    assert _outputs(ra) == _outputs(rs)


def test_async_parity_under_pool_pressure(attn_model):
    """Half the worst-case provisioning under the expected reservation
    policy: preemption-by-rematerialization fires in both runtimes (the
    async one discovers retirement/preemption late, at the consumption
    boundary) and the streams stay bitwise identical; the auditor
    cross-checks every cycle."""
    cfg, model, params = attn_model
    kw = dict(n_pages=2 + 3, reserve_policy="expected",
              expected_quantile=0.0, audit_every=1)
    _rs, ra, _ss, sa, eng = _differential(None, cfg, model, params, **kw)
    assert all(r.done for r in ra), _phases(ra)
    assert sa["preempted"] > 0, "no pressure exercised — test is vacuous"
    # lagging in-flight steps for retired/preempted slots were recognized
    # and dropped, not misattributed
    assert sa["discarded_steps"] > 0
    assert eng.pool.n_free == eng.pool.capacity
    assert audit_engine(eng).ok


def test_async_parity_under_seeded_faults(attn_model):
    """Seeded chaos, replayed through both runtimes: rate-based alloc-fail /
    forced-preempt / delayed-release faults (output-invariant recovery
    paths) plus a schedule-invariant ``fire_at_token`` poison — the only
    targeting that can hit the *same decode step* under two different
    schedules.  The poisoned request retires ERRORED at the same token in
    both; everyone else completes identically."""
    cfg, model, params = attn_model

    def plan():
        return FaultPlan(
            seed=3, alloc_fail=0.05, forced_preempt=0.05,
            delayed_release=0.3,
            fire_at_token={"poison_logits": {(2, 5)}},
        )

    kw = dict(n_pages=2 + 3, reserve_policy="expected",
              expected_quantile=0.0, audit_every=1)
    rs = _workload(cfg)
    ra = _workload(cfg)
    _, _ = _run(model, params, rs, async_runtime=False, faults=plan(), **kw)
    eng, _ = _run(model, params, ra, async_runtime=True, faults=plan(), **kw)
    assert _outputs(ra) == _outputs(rs)
    assert _phases(ra) == _phases(rs)
    assert _phases(ra)[2] == "errored"
    # the poisoned request's error names its dispatch step deterministically
    assert "non-finite logits row" in ra[2].error
    assert len(ra[2].out_tokens) == 6  # poisoned at progress 5, 6th emitted
    assert audit_engine(eng).ok


def test_async_parity_with_speculative_decode(attn_model):
    """``spec_k > 1`` with ``async_runtime=True``: the speculative cycle
    itself stays unoverlapped (draft+verify already amortize the sync), but
    completions route through the background thread — and the stream equals
    both the sync spec run and the non-speculative oracle."""
    cfg, model, params = attn_model
    r_sync = _workload(cfg)
    r_async = _workload(cfg)
    r_plain = _workload(cfg)
    _run(model, params, r_sync, async_runtime=False, spec_k=2)
    eng, sa = _run(model, params, r_async, async_runtime=True, spec_k=2)
    _run(model, params, r_plain, async_runtime=False)
    assert _outputs(r_async) == _outputs(r_sync) == _outputs(r_plain)
    assert sa["spec_accepted_tokens"] > 0
    assert sorted(eng._completions.records) == [r.uid for r in r_async]


def test_async_parity_with_prefix_sharing(attn_model):
    """B shares A's committed prefix blocks (admitted one step later so the
    index hit is real), decodes across a block boundary (private flush
    pages), and both runtimes emit the same streams as solo runs."""
    cfg, model, params = attn_model
    rng = np.random.default_rng(6)
    pa = rng.integers(0, cfg.vocab, 2 * BLOCK).astype(np.int32)
    pb = np.concatenate(
        [pa, rng.integers(0, cfg.vocab, 8).astype(np.int32)]
    )

    def staged(async_runtime):
        eng = ServeEngine(model, params, slots=2, max_seq=256,
                          async_runtime=async_runtime)
        a = Request(uid=0, prompt=pa.copy(), max_new_tokens=BLOCK + 4)
        b = Request(uid=1, prompt=pb.copy(), max_new_tokens=BLOCK + 4)
        eng.submit(a)
        eng.step()  # A adopted + prefix registered
        eng.submit(b)
        eng.step()  # B admitted: sharing visible before retirement
        assert len(b.shared_pages) == 2
        s = eng.run()
        eng.close()
        assert a.done and b.done
        return _outputs([a, b]), s

    out_async, sa = staged(True)
    out_sync, ss = staged(False)
    assert out_async == out_sync
    assert sa["prefill_tokens_saved"] == ss["prefill_tokens_saved"] > 0


def test_async_preempt_before_first_consumption(attn_model):
    """The nastiest interleaving: a request whose admission first-token is
    still a device array (no consumption boundary reached it) gets
    preempted — the runtime must resolve the lazy token into the parked
    feed, or rematerialization would replay garbage.  Forced preemption on
    the first consulted cycles makes the window deterministic."""
    cfg, model, params = attn_model

    def plan():
        return FaultPlan(fire_at={"forced_preempt": (0, 1, 2)})

    kw = dict(n_pages=2 + 6, audit_every=1, async_window=4)
    rs = _workload(cfg, n=3)
    ra = _workload(cfg, n=3)
    _run(model, params, rs, async_runtime=False, faults=plan(),
         n_pages=2 + 6, audit_every=1)
    eng, sa = _run(model, params, ra, async_runtime=True, faults=plan(),
                   **kw)
    assert _outputs(ra) == _outputs(rs)
    assert sa["preempted"] > 0
    assert audit_engine(eng).ok


# --------------------------------------------------------------------------
# Completion worker: ledger, callbacks, watchdogs (unit level)
# --------------------------------------------------------------------------

class _Req:
    """Minimal retired-request stand-in for worker unit tests."""

    def __init__(self, uid, tokens=(1, 2, 3), phase=Phase.DONE, error=None):
        self.uid = uid
        self.out_tokens = list(tokens)
        self.phase = phase
        self.error = error


def test_completion_worker_detokenizes_and_records_once():
    seen = []
    w = CompletionWorker(
        queue_size=4, watchdog_s=5.0,
        detokenizer=lambda toks: "|".join(map(str, toks)),
        on_complete=lambda rec: seen.append(rec.uid),
    )
    try:
        w.put(_Req(7, (4, 5)))
        w.put(_Req(8, (6,), phase=Phase.ERRORED, error="boom"))
        w.drain()
        assert sorted(w.records) == [7, 8]
        assert w.records[7].text == "4|5"
        assert w.records[7].phase == "done"
        assert w.records[8].error == "boom"
        assert sorted(seen) == [7, 8]
        # a duplicate retirement is counted, never overwrites the ledger
        w.put(_Req(7, (9, 9)))
        w.drain()
        assert w.duplicates == 1
        assert w.records[7].tokens == (4, 5)
    finally:
        w.close()


def test_completion_callback_error_surfaces_at_drain():
    w = CompletionWorker(
        queue_size=4, watchdog_s=5.0,
        on_complete=lambda rec: (_ for _ in ()).throw(ValueError("cb")),
    )
    try:
        w.put(_Req(1))
        with pytest.raises(ValueError, match="cb"):
            w.drain()
        assert 1 in w.records  # the record landed before the callback blew
    finally:
        w.close()


def test_completion_queue_full_raises_deadlock_not_hang():
    """A wedged consumer (detokenizer blocked on an event) must turn a full
    bounded queue into a DeadlockError within ~watchdog_s, not a hang."""
    release = threading.Event()
    w = CompletionWorker(
        queue_size=1, watchdog_s=0.2,
        detokenizer=lambda toks: (release.wait(10), "")[1],
    )
    try:
        w.put(_Req(0))        # worker picks this up and blocks
        time.sleep(0.05)
        w.put(_Req(1))        # fills the queue
        t0 = time.perf_counter()
        with pytest.raises(DeadlockError, match="completion queue full"):
            w.put(_Req(2))
        assert time.perf_counter() - t0 < 5.0
        with pytest.raises(DeadlockError, match="failed to drain"):
            w.drain()
    finally:
        release.set()
        w.close()


def test_engine_close_is_idempotent_and_sync_noop(attn_model):
    cfg, model, params = attn_model
    eng = ServeEngine(model, params, slots=2, max_seq=128)
    eng.close()
    eng.close()
    reqs = _workload(cfg, n=1)
    eng2, _ = _run(model, params, reqs, async_runtime=True)
    eng2.close()  # second close after _run's close


# --------------------------------------------------------------------------
# Concurrency stress + liveness: the storm
# --------------------------------------------------------------------------

def test_storm_admit_cancel_expire_preempt_no_loss_no_double(attn_model):
    """Randomized lifecycle storm against the overlapped runtime: staggered
    submissions, random cancels (waiting and active), short TTLs on an
    injectable clock, forced preemption and delayed page release, over an
    oversubscribed pool — driven step by step with the runner watchdog
    armed.  Liveness is the watchdog plus a bounded outer wall clock; the
    exactly-once contract is checked uid by uid against the worker ledger,
    and the auditor must be clean at drain."""
    cfg, model, params = attn_model
    rng = np.random.default_rng(11)
    now = [0.0]  # injectable TTL clock, advanced by the driver

    plan = FaultPlan(seed=5, forced_preempt=0.08, delayed_release=0.4,
                     delay_cycles=3)
    eng = ServeEngine(
        model, params, slots=2, max_seq=128, n_pages=2 + 3,
        reserve_policy="expected", expected_quantile=0.0,
        faults=plan, audit_every=1, clock=lambda: now[0],
        async_runtime=True, async_window=3, watchdog_s=20.0,
    )
    all_reqs = []
    pending = [
        Request(
            uid=i,
            prompt=rng.integers(
                0, cfg.vocab, int(rng.integers(34, 48))
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(10, 24)),
            # roughly a third get a TTL tight enough to expire mid-flight
            deadline_s=(float(rng.integers(3, 9))
                        if rng.random() < 0.35 else None),
        )
        for i in range(14)
    ]
    deadline = time.perf_counter() + 120.0  # outer liveness bound
    cancelled, submitted = set(), set()
    while eng._has_work() or pending:
        assert time.perf_counter() < deadline, "storm exceeded wall clock"
        # staggered admissions keep the waiting queue churning
        if pending and rng.random() < 0.4:
            req = pending.pop()
            assert eng.submit(req)
            submitted.add(req.uid)
            all_reqs.append(req)
        # random cancels hit waiting and active requests alike
        if submitted and rng.random() < 0.08:
            uid = int(rng.choice(sorted(submitted)))
            got = eng.cancel(uid)
            if got is not None:
                cancelled.add(uid)
        now[0] += 1.0  # TTL clock marches -> some deadlines expire
        if eng._has_work():
            eng.step()
            eng._runner.check_liveness()
    summary = eng.run()  # drain: consumes leftovers, drains completions
    eng.close()

    terminal = {
        Phase.DONE, Phase.CANCELLED, Phase.EXPIRED, Phase.ERRORED,
    }
    assert all(r.phase in terminal for r in all_reqs), _phases(all_reqs)
    # exactly-once: every submitted uid in the ledger, none twice
    ledger = eng._completions.records
    assert sorted(ledger) == sorted(submitted)
    assert eng._completions.duplicates == 0
    assert summary["completions_enqueued"] == len(submitted)
    # the storm actually stormed
    phases = {r.phase for r in all_reqs}
    assert Phase.DONE in phases
    assert cancelled or Phase.EXPIRED in phases
    # every DONE stream matches an unpressured solo decode of that prompt
    # (spot-check two — full parity is the differential suite's job)
    done = [r for r in all_reqs if r.phase is Phase.DONE][:2]
    for r in done:
        solo_eng = ServeEngine(model, params, slots=2, max_seq=128)
        solo = Request(uid=0, prompt=np.asarray(r.prompt).copy(),
                       max_new_tokens=r.max_new_tokens)
        solo_eng.submit(solo)
        solo_eng.run()
        assert list(r.out_tokens) == list(solo.out_tokens), r.uid
    # resources drained, invariants hold
    assert eng.pool.n_free == eng.pool.capacity
    assert eng.pool.reserved == 0
    assert audit_engine(eng).ok


def test_runner_watchdog_raises_on_stall(attn_model):
    """The liveness watchdog itself: a runner whose clock says no progress
    happened for longer than watchdog_s must raise DeadlockError, not spin."""
    cfg, model, params = attn_model
    eng = ServeEngine(model, params, slots=2, max_seq=128,
                      async_runtime=True, watchdog_s=0.05)
    try:
        reqs = _workload(cfg, n=1)
        for r in reqs:
            eng.submit(r)
        eng.step()  # real work: dispatch one step
        eng._runner.last_progress -= 10.0  # simulate a wedged pipeline
        with pytest.raises(DeadlockError, match="no progress"):
            eng._runner.check_liveness()
        # finishing the workload normally still works after the scare
        eng._runner.last_progress = time.perf_counter()
        eng.run()
        assert all(r.done for r in reqs)
    finally:
        eng.close()
