"""Unified paged decode across cache families (the PR's acceptance
criteria): MLA latent paging and HybridLM mixed per-layer states decode
through kernels/paged_bitdecode bitwise-identically to their dense-slot
oracles, prefix sharing + COW work on the latent pools, and a jaxpr taint
proof shows hybrid SSM layers carry no page-table work."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve.engine import Request, ServeEngine

BLOCK = 32


def _model(arch):
    cfg = smoke_config(arch).with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    return _model("deepseek-v3-671b")


@pytest.fixture(scope="module")
def hybrid_model():
    return _model("zamba2-7b")


def _oracle(model, params, prompt, max_new, max_seq=128):
    """Dense-slot reference: exact-length prefill + jitted decode loop."""
    logits, st = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                               max_seq)
    tok = int(np.argmax(np.asarray(logits)[0, -1]))
    step = jax.jit(functools.partial(model.decode_step, impl="auto",
                                     quant_impl="auto"))
    out = []
    for _ in range(max_new):
        out.append(tok)
        logits, st = step(params, st, jnp.asarray([[tok]], jnp.int32))
        tok = int(np.argmax(np.asarray(logits)[0, 0]))
    return out


def _engine_vs_oracle(cfg, model, params):
    """Mixed workload (short + block-crossing prompts, staggered arrivals)
    through the paged engine vs the dense oracle, bitwise."""
    rng = np.random.default_rng(3)
    specs = [(30, 6), (7, 5), (44, 4)]  # 30+6 and 44 cross block boundaries
    prompts = [rng.integers(0, cfg.vocab, l).astype(np.int32) for l, _ in specs]
    want = [_oracle(model, params, p, mn) for p, (_, mn) in zip(prompts, specs)]

    engine = ServeEngine(model, params, slots=2, max_seq=128)
    assert engine.paged
    reqs = [Request(uid=i, prompt=p, max_new_tokens=mn)
            for i, (p, (_, mn)) in enumerate(zip(prompts, specs))]
    engine.submit(reqs[0])
    engine.step()
    engine.submit(reqs[1])
    engine.step()
    engine.submit(reqs[2])
    engine.run()
    for i, (r, w) in enumerate(zip(reqs, want)):
        assert r.done
        assert r.out_tokens == w, f"request {i} diverged from dense oracle"
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0


def test_mla_paged_engine_matches_dense_oracle(mla_model):
    """Acceptance criterion: MLA requests decode through the shared_kv
    latent page pools, bitwise-identical to the dense-slot outputs —
    prefix sharing and COW enabled (engine defaults)."""
    cfg, model, params = mla_model
    _engine_vs_oracle(cfg, model, params)


def test_hybrid_paged_engine_matches_dense_oracle(hybrid_model):
    """Acceptance criterion: HybridLM's attention caches page; its SSM
    side-state splices per slot; outputs bitwise match the dense oracle."""
    cfg, model, params = hybrid_model
    _engine_vs_oracle(cfg, model, params)


def test_hybrid_exact_prefill_grouping(hybrid_model):
    """Recurrent side-state tolerates no right-padding: admission groups
    are exact suffix lengths, and same-length prompts still batch into one
    prefill call."""
    cfg, model, params = hybrid_model
    engine = ServeEngine(model, params, slots=4, max_seq=128)
    assert engine.spec.exact_prefill and engine.sched.exact_buckets
    rng = np.random.default_rng(4)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                    max_new_tokens=2)
            for i, plen in enumerate([9, 9, 20])]
    for r in reqs:
        engine.submit(r)
    engine.step()
    # two groups: the two 9-token prompts batch, the 20-token one is alone
    assert engine.stats["prefill_calls"] == 2
    engine.run()
    assert all(r.done for r in reqs)


def test_unserveable_family_refused_at_construction():
    """paged_spec() is None (enc-dec: prefill needs frame embeddings the
    Request cannot carry) -> the engine refuses at __init__, for the forced
    shim too — not with an obscure error mid-prefill."""
    cfg = smoke_config("seamless-m4t-medium")
    model = build_model(cfg)
    assert model.paged_spec() is None
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="serveable cache family"):
        ServeEngine(model, params, slots=2, max_seq=64)
    with pytest.raises(ValueError, match="serveable cache family"):
        ServeEngine(model, params, slots=2, max_seq=64, paged=False)


# --------------------------------------------------------------------------
# MLA prefix sharing + COW on the latent pools
# --------------------------------------------------------------------------

def test_mla_prefix_sharing_suffix_prefill(mla_model):
    """A sharer of a resident latent-chain prefix holds the donor's pages
    (refcounted) and prefills only its divergent suffix — the suffix attends
    the dequantized latent prior through each layer's up-projections."""
    cfg, model, params = mla_model
    engine = ServeEngine(model, params, slots=2, max_seq=256)
    rng = np.random.default_rng(5)
    pa = rng.integers(0, cfg.vocab, 3 * BLOCK).astype(np.int32)
    pb = np.concatenate([pa[: 2 * BLOCK],
                         rng.integers(0, cfg.vocab, 16).astype(np.int32)])
    a = Request(uid=0, prompt=pa, max_new_tokens=4)
    b = Request(uid=1, prompt=pb, max_new_tokens=4)
    engine.submit(a)
    engine.step()
    tokens_after_a = engine.stats["prefill_tokens"]
    engine.submit(b)
    engine.step()
    assert b.shared_pages == a.pages[:2]
    assert all(engine.pool.refcount(p) == 2 for p in b.shared_pages)
    assert engine.stats["prefill_tokens"] - tokens_after_a == 16
    assert engine.stats["prefill_tokens_saved"] == 2 * BLOCK
    engine.run()
    assert a.done and b.done
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.summary()["prefix_hit_rate"] > 0


def test_mla_sharing_donor_bitwise_and_cow(mla_model):
    """Sharing never perturbs the donor (bitwise vs solo), and a spec-tail
    sharer copy-on-writes its first divergent flush on the latent pools —
    nothing shared is ever read, so the sharer is bitwise too."""
    cfg, model, params = mla_model

    def solo(prompt, max_new):
        eng = ServeEngine(model, params, slots=2, max_seq=256,
                          share_prefix=False)
        r = Request(uid=0, prompt=prompt, max_new_tokens=max_new)
        eng.submit(r)
        eng.run()
        return r.out_tokens

    rng = np.random.default_rng(7)
    pa = rng.integers(0, cfg.vocab, BLOCK + 8).astype(np.int32)
    pb = pa[:8].copy()  # strict mid-block prefix -> speculative tail

    engine = ServeEngine(model, params, slots=2, max_seq=256)
    a = Request(uid=0, prompt=pa, max_new_tokens=2 * BLOCK)
    b = Request(uid=1, prompt=pb, max_new_tokens=BLOCK)
    engine.submit(a)
    engine.step()
    page_a = a.pages[0]
    engine.submit(b)
    engine.step()
    assert b.spec_page == page_a
    assert engine.pool.refcount(page_a) == 2
    engine.run()
    assert engine.stats["cow_copies"] == 1
    assert b.out_tokens == solo(pb, BLOCK)
    assert a.out_tokens == solo(pa, 2 * BLOCK)
    assert engine.pool.n_free == engine.pool.capacity


# --------------------------------------------------------------------------
# jaxpr proof: hybrid SSM layers carry no page-table work
# --------------------------------------------------------------------------

def _propagate(jaxpr, tainted):
    """Forward taint within one (sub)jaxpr: returns (tainted set including
    derived vars, [scan eqns whose inputs are tainted]).

    Scans do NOT forward taint to their outputs: the question is which scans
    *receive the table* (page-table work), not which values are downstream
    of attention results (ordinary data flow — the tail Mamba scan of course
    consumes attention activations)."""
    tainted = set(tainted)
    tainted_scans = []
    for eqn in jaxpr.eqns:
        hit = any((not isinstance(v, jax.extend.core.Literal)) and v in tainted
                  for v in eqn.invars)
        if eqn.primitive.name == "scan":
            if hit:
                tainted_scans.append(eqn)
            continue
        if hit:
            tainted.update(eqn.outvars)
    return tainted, tainted_scans


def test_hybrid_ssm_layers_carry_no_page_table_work(hybrid_model):
    """Trace the hybrid paged decode step as a function of the page table
    and follow the table's taint through the jaxpr:

    * at top level, exactly ONE scan consumes table-derived values — the
      super-block scan that owns the shared-attention invocations; the tail
      Mamba scan never sees the table;
    * inside that scan's body, the inner Mamba-group scan does not consume
      table-derived values either.

    Together: paging work attaches only to the attention layers; the SSM
    recurrent updates carry zero page-table work.
    """
    cfg, model, params = hybrid_model
    assert model.tail, "smoke config should have a tail mamba stack"
    state = model.init_paged_decode_state(2, n_pages=8, nb_max=2)
    tokens = jnp.zeros((2, 1), jnp.int32)

    def f(table):
        caches = [dataclasses.replace(state["caches"][0], page_table=table)]
        st = dict(state, caches=caches)
        return model.decode_step(params, st, tokens)

    jaxpr = jax.make_jaxpr(f)(state["caches"][0].page_table).jaxpr
    (table_var,) = jaxpr.invars

    tainted, tainted_scans = _propagate(jaxpr, {table_var})
    all_scans = [e for e in jaxpr.eqns if e.primitive.name == "scan"]
    assert len(all_scans) >= 2  # super-block scan + tail mamba scan
    assert len(tainted_scans) == 1, (
        f"expected exactly one table-consuming scan, got {len(tainted_scans)}"
    )
    super_scan = tainted_scans[0]
    # the tail scan is one of the untainted ones by the assertion above

    # descend: map tainted outer invars onto the body's invars
    body = super_scan.params["jaxpr"].jaxpr
    inner_taint = {
        body.invars[i]
        for i, v in enumerate(super_scan.invars)
        if not isinstance(v, jax.extend.core.Literal) and v in tainted
    }
    assert inner_taint, "table must enter the super-block scan body"
    _, inner_tainted_scans = _propagate(body, inner_taint)
    assert not inner_tainted_scans, (
        "the inner Mamba-group scan must not consume page-table-derived "
        "values — SSM layers carry no page-table work"
    )
