"""Property test: the split-KV logsumexp merge is exactly equivalent to
unsplit softmax attention, for any partition of the sequence (pure math, no
mesh)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np


def _partial(q, k, v):
    """Per-shard flash partials (o, lse) as the kernel computes them."""
    s = (q @ k.T) / q.shape[-1] ** 0.5
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = (p / l) @ v
    return o, (m + jnp.log(l))[:, 0]


def _merge(parts):
    lses = jnp.stack([lse for _, lse in parts])  # [n, g]
    m = jnp.max(lses, axis=0)
    w = jnp.exp(lses - m[None])  # [n, g]
    num = sum(w[i][:, None] * parts[i][0] for i in range(len(parts)))
    den = jnp.sum(w, axis=0)
    return num / den[:, None]


@hypothesis.given(
    n_shards=st.integers(2, 5),
    seed=st.integers(0, 2**16),
    g=st.sampled_from([1, 4]),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_merge_equals_unsplit(n_shards, seed, g):
    d, s = 32, 64 * n_shards
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (g, d))
    k = jax.random.normal(ks[1], (s, d))
    v = jax.random.normal(ks[2], (s, d))
    full, _ = _partial(q, k, v)
    bounds = np.linspace(0, s, n_shards + 1).astype(int)
    parts = [
        _partial(q, k[a:b], v[a:b]) for a, b in zip(bounds[:-1], bounds[1:])
    ]
    merged = _merge(parts)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_merge_handles_empty_shard():
    """A shard with zero tokens (lse -> -inf proxy) contributes nothing."""
    g, d, s = 2, 16, 48
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (g, d))
    k = jax.random.normal(ks[1], (s, d))
    v = jax.random.normal(ks[2], (s, d))
    full, _ = _partial(q, k, v)
    empty = (jnp.zeros((g, d)), jnp.full((g,), -1e37))
    merged = _merge([_partial(q, k, v), empty])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
