"""Optimizers: descent on a quadratic, state shapes, lr schedule sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import get_optimizer


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = get_optimizer(name, lr=0.05, warmup=1, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)
    params = {"w": jnp.zeros((8, 16), jnp.float32), "b": jnp.zeros((16,), jnp.float32)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] + p["b"][None, :] - target) ** 2)

    losses = []
    for step in range(60):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state = opt.update(grads, state, params, step)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0], losses[::10]


def test_adafactor_state_is_factored():
    opt = get_optimizer("adafactor")
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st["w"]["row"].shape == (64,)
    assert st["w"]["col"].shape == (32,)
    assert st["b"]["v"].shape == (32,)
    # factored state is ~(m+n) not m*n — the 671B-config memory argument
    total = sum(x.size for x in jax.tree.leaves(st))
    assert total == 64 + 32 + 32


def test_adamw_warmup_schedule():
    opt = get_optimizer("adamw", lr=1e-3, warmup=10, total_steps=100)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    g = {"w": jnp.ones((4, 4))}
    u0, state = opt.update(g, state, params, 0)
    u9, _ = opt.update(g, state, params, 9)
    # warmup: step-0 update much smaller than step-9
    assert float(jnp.abs(u0["w"]).mean()) < 0.3 * float(jnp.abs(u9["w"]).mean())
