"""blockwise_attention(impl='pallas') == impl='xla' (the flash_prefill
kernel wired through the model-facing entry point)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as catt


def test_blockwise_pallas_matches_xla():
    b, s, hq, hkv, d = 1, 256, 4, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, hkv, d)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, hkv, d)).astype(jnp.bfloat16)
    out_p = catt.blockwise_attention(q, k, v, impl="pallas")
    out_x = catt.blockwise_attention(q, k, v, impl="xla", block_k=128)
    np.testing.assert_allclose(
        np.asarray(out_p, np.float32), np.asarray(out_x, np.float32),
        rtol=3e-2, atol=3e-2,
    )
