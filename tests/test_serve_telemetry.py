"""Serving telemetry (ISSUE 8): the metrics registry, the structured event
tracer, the engine's phase-timing breakdown, and — decisively — proof that
telemetry never changes a computed value: bitwise output parity with
tracing on vs. off across cache families, pool pressure with faults, and
self-speculative decoding.

Also pins the two satellite bug fixes: page-pool occupancy is sampled at
the cycle peak (post-admission, pre-release — short workloads used to read
0.0), and ``summary()`` without an explicit ``wall_s`` measures the real
first-work -> last-work window instead of fabricating a throughput from
summed per-token latencies.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.zoo import build_model
from repro.serve import (
    FaultPlan,
    MetricsRegistry,
    Request,
    ServeEngine,
    Tracer,
    audit_engine,
    validate_events,
)
from repro.serve.engine import PHASE_METRICS, STAT_COUNTERS
from repro.serve.telemetry import Histogram

BLOCK = 32


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("llama3-8b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    cfg = smoke_config("deepseek-v3-671b").with_(kv_bits=4, kv_block=BLOCK)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, n=5, seed=42, lo=34, hi=48, new_lo=10, new_hi=16):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(lo, hi))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(new_lo, new_hi)),
        ))
    return reqs


def _run(model, params, reqs, **kw):
    engine = ServeEngine(model, params, slots=2, max_seq=128, **kw)
    for r in reqs:
        engine.submit(r)
    engine.run()
    engine.close()  # async runtime: stop the completion thread (sync no-op)
    return engine


# --------------------------------------------------------------------------
# Histogram: log buckets vs the numpy oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_percentiles_match_numpy_oracle(dist):
    """p50/p90/p99 within one log-bucket width (relative error growth-1,
    ~9%) of numpy's exact percentiles, across nine decades of scale."""
    rng = np.random.default_rng(7)
    if dist == "lognormal":
        xs = rng.lognormal(mean=-6.0, sigma=2.0, size=4000)
    elif dist == "uniform":
        xs = rng.uniform(1e-6, 10.0, size=4000)
    else:
        # 30/70 mode split keeps every tested quantile inside a mode (a
        # quantile landing in the inter-mode gap is ill-posed for any
        # histogram: numpy interpolates across the gap, buckets cannot)
        xs = np.concatenate([
            rng.normal(2e-4, 2e-5, 1200).clip(1e-9),
            rng.normal(5e-2, 5e-3, 2800).clip(1e-9),
        ])
    h = Histogram("t")
    for x in xs:
        h.record(float(x))
    tol = 2 * (h.growth - 1.0)  # one bucket width, either side
    for q in (50, 90, 99):
        exact = float(np.percentile(xs, q))
        est = h.percentile(q)
        assert abs(est - exact) <= tol * exact + 1e-12, (dist, q, est, exact)


def test_histogram_extremes_exact_and_empty_safe():
    h = Histogram("t")
    assert h.percentile(50) == 0.0  # empty
    for v in (0.2, 0.5, 0.9):
        h.record(v)
    assert h.percentile(0) == pytest.approx(0.2)
    assert h.percentile(100) == pytest.approx(0.9)
    s = h.summary()
    assert s["count"] == 3
    assert s["min"] == pytest.approx(0.2)
    assert s["max"] == pytest.approx(0.9)
    assert s["mean"] == pytest.approx(1.6 / 3)


def test_histogram_bucket_edges_partition_the_line():
    h = Histogram("t")
    for v in (0.0, 1e-9, h.lo, h.lo * 1.0000001, 0.1, 3.7, 1e4):
        i = h._bucket(v)
        assert v <= h.bucket_edge(i)
        if i > 0:
            assert v > h.bucket_edge(i - 1)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_counter_monotone_and_type_clash():
    m = MetricsRegistry()
    m.inc("a", 3)
    m.inc("a")
    assert m.value("a") == 4
    with pytest.raises(ValueError, match="negative increment"):
        m.inc("a", -1)
    with pytest.raises(ValueError, match="different kind"):
        m.gauge("a")
    with pytest.raises(ValueError, match="different kind"):
        m.histogram("a")


def test_registry_gauge_watermarks():
    m = MetricsRegistry()
    for v in (5, 2, 9, 4):
        m.set_gauge("g", v)
    g = m.gauge("g")
    assert (g.value, g.hi, g.lo) == (4, 9, 2)


def test_registry_snapshot_and_prometheus_exposition():
    m = MetricsRegistry(namespace="ns")
    m.inc("reqs", 2)
    m.set_gauge("occ", 0.5)
    m.observe("lat", 0.01)
    m.observe("lat", 0.02)
    snap = m.snapshot()
    assert snap["counters"]["reqs"] == 2
    assert snap["gauges"]["occ"]["value"] == 0.5
    assert snap["histograms"]["lat"]["count"] == 2
    text = m.to_prometheus()
    assert "# TYPE ns_reqs counter" in text
    assert "ns_reqs 2" in text.splitlines()
    assert "# TYPE ns_occ gauge" in text
    assert "# TYPE ns_lat histogram" in text
    assert 'ns_lat_bucket{le="+Inf"} 2' in text
    assert "ns_lat_count 2" in text
    # cumulative bucket counts are non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("ns_lat_bucket")]
    assert cums == sorted(cums)


# --------------------------------------------------------------------------
# tracer: span discipline + schema validation
# --------------------------------------------------------------------------

def test_tracer_span_discipline():
    t = Tracer()
    t.begin("queue", uid=1)
    with pytest.raises(ValueError, match="begun twice"):
        t.begin("queue", uid=1)
    with pytest.raises(ValueError, match="never begun"):
        t.end("decode", uid=1)
    assert t.end_open(uid=1) == ["queue"]
    assert t.open_spans() == []
    assert validate_events(t.events) == []


def test_validate_events_catches_each_breach_class():
    def evs(*tail):
        return [{"ph": "B", "name": "queue", "cat": "request", "ts_us": 0,
                 "uid": 1, "args": None}, *tail]

    e = {"ph": "E", "name": "queue", "cat": "request", "ts_us": 5, "uid": 1,
         "args": None}
    assert validate_events(evs(e)) == []
    # dangling span
    assert any("never ended" in v for v in validate_events(evs()))
    # end before begin
    bad = dict(e, ts_us=-3)
    assert any("before its begin" in v for v in validate_events(evs(bad)))
    # unknown uid reference
    ghost = {"ph": "i", "name": "cow", "cat": "event", "ts_us": 1, "uid": 9}
    assert any("unknown request uid 9" in v
               for v in validate_events(evs(e, ghost)))
    # rejected is explicitly unspanned
    rej = {"ph": "i", "name": "rejected", "cat": "request", "ts_us": 1,
           "uid": 9}
    assert validate_events(evs(e, rej)) == []
    # non-alternating lifecycle events (B B after the closed queue span)
    b2 = {"ph": "B", "name": "prefill", "cat": "request", "ts_us": 6,
          "uid": 1}
    b3 = {"ph": "B", "name": "decode", "cat": "request", "ts_us": 7,
          "uid": 1}
    assert any("alternate" in v for v in validate_events(evs(e, b2, b3)))
    # timestamp regression within a request's lifecycle stream
    late = {"ph": "B", "name": "prefill", "cat": "request", "ts_us": 2,
            "uid": 1}
    assert any("regress" in v for v in validate_events(evs(e, late)))
    # missing field
    assert any("missing field" in v
               for v in validate_events([{"ph": "i", "name": "x"}]))


def test_tracer_chrome_trace_structure(tmp_path):
    t = Tracer()
    t.begin("queue", uid=3)
    t.end("queue", uid=3)
    t.complete("schedule", t0=t.clock(), dur_s=0.001, cat="engine")
    t.instant("audit", cat="engine", args={"violations": 0})
    ct = t.chrome_trace()
    evs = ct["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {"engine", "requests"}
    req_evs = [e for e in evs if e.get("pid") == 1 and e["ph"] != "M"]
    assert req_evs and all(e["tid"] == 3 for e in req_evs)
    assert all("(req 3)" in e["name"] for e in req_evs)
    x = next(e for e in evs if e["ph"] == "X")
    assert x["pid"] == 0 and x["dur"] >= 0
    # file round-trips
    chrome = t.write_chrome(tmp_path / "trace.json")
    assert json.loads(chrome.read_text())["traceEvents"]
    jsonl = t.write_jsonl(tmp_path / "trace.jsonl")
    lines = [json.loads(l) for l in jsonl.read_text().splitlines()]
    assert lines == t.events


# --------------------------------------------------------------------------
# engine integration: schema-valid traces, phase breakdown, split latencies
# --------------------------------------------------------------------------

@pytest.mark.parametrize("async_runtime", [False, True])
def test_engine_trace_schema_valid_and_lifecycle_complete(small_model,
                                                          async_runtime):
    cfg, model, params = small_model
    reqs = _workload(cfg)
    engine = _run(model, params, reqs, trace=True, audit_every=2,
                  async_runtime=async_runtime)
    errs = validate_events(engine.tracer.events)
    assert errs == [], errs
    # every request walked queue -> prefill -> decode -> done ("prefill" is
    # also an engine phase record, so count only request-cat events)
    req_names = [e["name"] for e in engine.tracer.events
                 if e["cat"] == "request"]
    for span in ("queue", "prefill", "decode"):
        assert req_names.count(span) == 2 * len(reqs), span  # B + E each
    assert req_names.count("done") == len(reqs)
    # per-cycle phase records are present for every phase
    phase_names = {e["name"] for e in engine.tracer.events
                   if e["cat"] == "engine"}
    assert set(PHASE_METRICS) <= phase_names
    assert engine.tracer.open_spans() == []
    assert audit_engine(engine).ok


@pytest.mark.parametrize("async_runtime", [False, True])
def test_engine_phase_breakdown_and_host_stall(small_model, async_runtime):
    """Structural invariants only — never wall-clock magnitudes: phases are
    sub-intervals of their cycles (they sum to at most the cycle total,
    whichever runtime attributed them — under the async runtime device_wait
    moves to the consumption boundary), the stall fraction is a fraction,
    and the idle-gap series matches the cycle series sample for sample."""
    cfg, model, params = small_model
    engine = _run(model, params, _workload(cfg, n=3), trace=True,
                  async_runtime=async_runtime)
    s = engine.summary()
    phases = s["phase_s"]
    assert set(PHASE_METRICS) <= set(phases)
    assert phases["cycle"] > 0
    # phases partition the cycle (minus untimed glue)
    assert sum(v for k, v in phases.items() if k != "cycle") \
        <= phases["cycle"] * 1.05
    assert 0.0 <= s["host_stall_fraction"] <= 1.0
    assert engine.metrics.hist("device_idle_gap_s").n \
        == engine.metrics.hist("cycle_s").n


def test_ttft_tpot_split_latency_series(small_model):
    cfg, model, params = small_model
    reqs = _workload(cfg, n=4)
    engine = _run(model, params, reqs)
    # one TTFT sample per completed request; everything else is TPOT
    assert engine.metrics.hist("ttft_s").n == len(reqs)
    decoded = engine.stats["decoded_tokens"]
    assert engine.metrics.hist("tpot_s").n == decoded - len(reqs)
    assert engine.metrics.hist("queue_wait_s").n == len(reqs)
    assert engine.metrics.hist("e2e_latency_s").n == len(reqs)
    s = engine.summary()
    # structural only — the split exists and both series carry real samples;
    # comparing TTFT/TPOT *magnitudes* is a wall-clock race (scheduler noise
    # or the async runtime's pipelined latencies can flip either way)
    assert s["ttft_p50_ms"] > 0.0
    assert s["tpot_p50_ms"] > 0.0
    assert s["e2e_p99_ms"] > 0.0


def test_stats_property_remains_dict_compatible(small_model):
    cfg, model, params = small_model
    engine = _run(model, params, _workload(cfg, n=2))
    stats = engine.stats
    assert set(stats) == set(STAT_COUNTERS)
    assert all(isinstance(v, int) for v in stats.values())
    assert stats["decoded_tokens"] > 0
    assert stats["budget_retired"] == 2


# --------------------------------------------------------------------------
# satellite fixes: occupancy sampling + the wall_s work window
# --------------------------------------------------------------------------

def test_occupancy_sampled_at_cycle_peak_not_after_release(small_model):
    """Regression: occupancy was sampled after ``_advance`` released the
    retiring requests' pages, so a workload whose requests all retire
    within a few cycles of first allocating reported 0.0 forever."""
    cfg, model, params = small_model
    # prompts just over one block, one decoded token: pages live briefly
    reqs = _workload(cfg, n=2, lo=BLOCK + 2, hi=BLOCK + 6,
                     new_lo=1, new_hi=2)
    engine = _run(model, params, reqs)
    s = engine.summary()
    assert s["occupancy_max"] > 0.0
    assert s["occupancy_mean"] > 0.0
    # the gauge high-water mark agrees with the sampled series
    assert engine.metrics.gauge("pool_occupancy").hi >= s["occupancy_max"]


def test_pool_gauges_track_usage_and_drain(small_model):
    cfg, model, params = small_model
    engine = _run(model, params, _workload(cfg, n=3))
    used = engine.metrics.gauge("pool_pages_used")
    assert used.hi > 0       # pages were allocated at some point
    assert used.value == 0   # and all returned at drain
    assert engine.metrics.gauge("pool_pages_committed").value == 0


def test_wall_s_measures_work_window_not_fabricated(small_model):
    """Regression: ``summary()`` without wall_s derived throughput from
    summed per-token latencies / slots — a fabrication once TTFT includes
    queue wait.  Now it reports the first-work -> last-work window."""
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=128)
    s0 = engine.summary()
    assert s0["wall_s"] == 0.0 and s0["tokens_per_s"] == 0.0  # no work yet
    for r in _workload(cfg, n=2):
        engine.submit(r)
    while engine._has_work():
        engine.step()
    s = engine.summary()
    assert s["wall_s"] > 0.0
    assert s["decoded_tokens"] / s["wall_s"] == pytest.approx(
        s["tokens_per_s"])
    # an explicit wall time still wins
    assert engine.summary(wall_s=100.0)["tokens_per_s"] == pytest.approx(
        s["decoded_tokens"] / 100.0)


# --------------------------------------------------------------------------
# metrics sink + fault observer
# --------------------------------------------------------------------------

def test_metrics_every_feeds_sink_each_n_cycles(small_model):
    cfg, model, params = small_model
    seen = []
    engine = _run(model, params, _workload(cfg, n=2),
                  metrics_every=2, metrics_sink=seen.append)
    assert len(seen) == engine._cycle // 2
    assert all("counters" in snap for snap in seen)
    # snapshots are monotone in decoded tokens
    tok = [snap["counters"]["decoded_tokens"] for snap in seen]
    assert tok == sorted(tok)


def test_fault_firings_count_and_trace(small_model):
    cfg, model, params = small_model
    plan = FaultPlan(seed=3, fire_at={"forced_preempt": (4,)})
    reqs = _workload(cfg)
    engine = _run(model, params, reqs, trace=True, faults=plan,
                  n_pages=2 + 6, reserve_policy="expected",
                  expected_quantile=0.25, audit_every=1)
    assert engine.stats["faults_injected"] == len(plan.log) == 1
    faults = [e for e in engine.tracer.events if e["name"] == "fault"]
    assert [f["args"]["site"] for f in faults] == ["forced_preempt"]
    # the preemption shows in the trace too: preempt instant + re-queue
    names = [e["name"] for e in engine.tracer.events]
    assert "preempt" in names
    assert validate_events(engine.tracer.events) == []
    assert all(r.done for r in reqs)


# --------------------------------------------------------------------------
# the decisive bar: telemetry never changes a computed value
# --------------------------------------------------------------------------

def _outputs(engine_reqs):
    return {r.uid: list(r.out_tokens) for r in engine_reqs}


@pytest.mark.parametrize("family", ["attn", "mla"])
def test_tracing_is_bitwise_invisible_per_family(
        family, small_model, mla_model):
    cfg, model, params = small_model if family == "attn" else mla_model
    base = _workload(cfg)
    traced = _workload(cfg)
    _run(model, params, base)
    engine = _run(model, params, traced, trace=True, audit_every=2,
                  metrics_every=3, metrics_sink=lambda snap: None)
    assert _outputs(traced) == _outputs(base)
    assert validate_events(engine.tracer.events) == []


def test_tracing_is_bitwise_invisible_under_pressure(small_model):
    cfg, model, params = small_model
    kw = dict(n_pages=2 + 3, reserve_policy="expected",
              expected_quantile=0.0, audit_every=1)
    base = _workload(cfg, new_lo=24, new_hi=32)
    traced = _workload(cfg, new_lo=24, new_hi=32)
    ref = _run(model, params, base, **kw)
    engine = _run(model, params, traced, trace=True, **kw)
    assert ref.stats["preempted"] > 0  # pressure actually happened
    assert engine.stats["preempted"] == ref.stats["preempted"]
    assert _outputs(traced) == _outputs(base)
    assert validate_events(engine.tracer.events) == []


def test_tracing_is_bitwise_invisible_with_speculation(small_model):
    cfg, model, params = small_model
    base = _workload(cfg)
    traced = _workload(cfg)
    ref = _run(model, params, base, spec_k=2, spec_bits=2)
    engine = _run(model, params, traced, spec_k=2, spec_bits=2, trace=True,
                  audit_every=2)
    assert engine.stats["spec_draft_tokens"] == ref.stats["spec_draft_tokens"]
    assert _outputs(traced) == _outputs(base)
    errs = validate_events(engine.tracer.events)
    assert errs == [], errs
    names = {e["name"] for e in engine.tracer.events}
    assert {"spec_draft", "spec_verify"} <= names
