"""Per-kernel allclose tests: kv_quant Pallas kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout, quantizer
from repro.kernels.kv_quant import kernel as kq_kernel
from repro.kernels.kv_quant import ref as kq_ref


def _rand(key, shape, dtype=jnp.bfloat16):
    # heavy-tailed, per-channel offset — realistic K statistics (outlier channels)
    k1, k2 = jax.random.split(key)
    base = jax.random.normal(k1, shape, jnp.float32)
    chan = 4.0 * jax.random.normal(k2, shape[-1:], jnp.float32)
    return (base + chan).astype(dtype)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("granularity", ["channel", "tensor"])
@pytest.mark.parametrize("d", [64, 128, 256])
@pytest.mark.parametrize("block_n", [128, 256])
def test_kvquant_matches_ref(bits, granularity, d, block_n):
    b, h, nb = 2, 3, 2
    s = nb * block_n
    x = _rand(jax.random.PRNGKey(42), (b, h, s, d))
    w_k, s_k, z_k = kq_kernel.quantize_kv_pallas(
        x, bits=bits, granularity=granularity, block_n=block_n, interpret=True
    )
    ref_jit = jax.jit(
        kq_ref.quantize_kv_ref, static_argnums=(1, 2), static_argnames=("block_n",)
    )
    w_r, s_r, z_r = ref_jit(x, bits, granularity, block_n=block_n)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    np.testing.assert_allclose(
        np.asarray(s_k, np.float32), np.asarray(s_r, np.float32), rtol=1e-2, atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(z_k, np.float32), np.asarray(z_r, np.float32), rtol=1e-2, atol=1e-3
    )


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("granularity", ["channel", "tensor"])
def test_roundtrip_error_bound(bits, granularity):
    """Dequantized values are within scale/2 of the originals (+param rounding)."""
    b, h, s, d = 1, 2, 256, 128
    x = _rand(jax.random.PRNGKey(0), (b, h, s, d))
    w, sc, zp = kq_ref.quantize_kv_ref(x, bits, granularity, param_dtype=jnp.float32)
    x_hat = kq_ref.dequantize_kv_ref(w, sc, zp, bits, granularity, dtype=jnp.float32)
    xf = np.asarray(x, np.float32)
    err = np.abs(np.asarray(x_hat) - xf)
    if granularity == "channel":
        bound = np.asarray(sc, np.float32).reshape(b, h, -1, 1, d)
        bound = np.broadcast_to(bound, (b, h, s // 128, 128, d)).reshape(b, h, s, d)
    else:
        bound = np.asarray(sc, np.float32).reshape(b, h, s, 1)
        bound = np.broadcast_to(bound, (b, h, s, d))
    # round-to-nearest: |err| <= scale/2 (+ bf16 rounding of inputs)
    assert np.all(err <= 0.5 * bound + 0.05 * np.abs(xf) + 1e-2)


def test_strided_pack_natural_order():
    """Unpack(pack(q)) is the identity — the induced-layout property."""
    rng = np.random.default_rng(7)
    for bits in (2, 4, 8):
        q = jnp.asarray(rng.integers(0, layout.qmax(bits) + 1, (3, 128, 64)), jnp.int32)
        w = layout.pack_strided(q, bits)
        assert w.shape == (3, 128 // layout.packing_ratio(bits), 64)
        np.testing.assert_array_equal(np.asarray(layout.unpack_strided(w, bits)), np.asarray(q))
