"""Split-KV decode parity: the two-phase (per-split partials + lse merge)
kernel is policy-equivalent to the unsplit kernel and the ref oracle for any
num_splits — across bits, K-param granularity, shared-KV (MLA) mode, splits
that cover zero valid blocks (finalize's l=0 / lse=-inf guard), and a
partially filled residual."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.kv_quant import ref as kq_ref


def _make_case(key, *, b, h, g, d_k, d_v, nb, block_n, bits, k_gran,
               pack_blocks, res_len):
    ks = jax.random.split(key, 6)
    s_pack = nb * block_n
    k_full = jax.random.normal(ks[0], (b, h, s_pack, d_k), jnp.float32)
    k_full += 2.0 * jax.random.normal(ks[5], (d_k,), jnp.float32)
    v_full = jax.random.normal(ks[1], (b, h, s_pack, d_v), jnp.float32)
    q = (jax.random.normal(ks[2], (b, h, g, d_k), jnp.float32) / d_k**0.25
         ).astype(jnp.bfloat16)
    k_res = jax.random.normal(ks[3], (b, h, block_n, d_k), jnp.float32
                              ).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, block_n, d_v), jnp.float32
                              ).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(
        k_full.astype(jnp.bfloat16), bits, k_gran, block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(
        v_full.astype(jnp.bfloat16), bits, "tensor", block_n=block_n)
    return dict(q=q, kw=kw, k_scale=ksc, k_zero=kzp, vw=vw, v_scale=vsc,
                v_zero=vzp, k_res=k_res, v_res=v_res,
                pack_blocks=jnp.asarray(pack_blocks, jnp.int32),
                res_len=jnp.asarray(res_len, jnp.int32))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k_gran", ["channel", "tensor"])
@pytest.mark.parametrize("num_splits", [2, 4])
def test_split_matches_unsplit_and_ref(bits, k_gran, num_splits):
    """num_splits in {2, 4} vs the unsplit kernel and the ref oracle.

    pack_blocks=[4, 2] with nb=4: at num_splits=4 the second sequence's
    upper splits own zero valid blocks, exercising the empty-split guard;
    res_len=[37, 0] covers a partially filled and an empty residual."""
    b, h, g, d, nb, block_n = 2, 2, 4, 128, 4, 128
    case = _make_case(
        jax.random.PRNGKey(0), b=b, h=h, g=g, d_k=d, d_v=d, nb=nb,
        block_n=block_n, bits=bits, k_gran=k_gran,
        pack_blocks=[nb, nb - 2], res_len=[37, 0],
    )
    fn = functools.partial(bd_ops.bitdecode_attention, bits=bits,
                           block_n=block_n, k_gran=k_gran, return_lse=True)
    out_1, lse_1 = fn(**case, impl="pallas", num_splits=1)
    out_s, lse_s = fn(**case, impl="pallas", num_splits=num_splits)
    out_r, lse_r = fn(**case, impl="xla", num_splits=1)
    # split vs unsplit: same policy, only fp reassociation differs
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_1),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_1),
                               rtol=1e-3, atol=1e-3)
    # split vs the oracle
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_r),
                               rtol=1e-3, atol=1e-3)


def test_split_ref_oracle_matches_unsplit_ref():
    """The split-aware ref path (per-split partials + merge) is the oracle
    for the kernel's phase-2 merge: must agree with the single-pass ref."""
    case = _make_case(
        jax.random.PRNGKey(1), b=1, h=2, g=4, d_k=128, d_v=128, nb=6,
        block_n=128, bits=4, k_gran="channel", pack_blocks=[5], res_len=[19],
    )
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4, block_n=128,
                           k_gran="channel", impl="xla", return_lse=True)
    out_1, lse_1 = fn(**case, num_splits=1)
    for s in (2, 3, 6):
        out_s, lse_s = fn(**case, num_splits=s)
        # bf16 PV matmuls run per split, so reassociation noise is the bound
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_1),
                                   rtol=1e-2, atol=1e-3)
        np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_1),
                                   rtol=1e-4, atol=1e-4)


def test_split_all_splits_empty_but_residual():
    """pack_blocks=0: every split owns zero valid blocks; only the residual
    (owned by the last split) contributes.  Exercises lse=-inf partials for
    all non-last splits."""
    case = _make_case(
        jax.random.PRNGKey(2), b=1, h=1, g=4, d_k=128, d_v=128, nb=4,
        block_n=128, bits=4, k_gran="channel", pack_blocks=[0], res_len=[7],
    )
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4, block_n=128,
                           k_gran="channel", return_lse=True)
    out_1, lse_1 = fn(**case, impl="pallas", num_splits=1)
    out_4, lse_4 = fn(**case, impl="pallas", num_splits=4)
    np.testing.assert_allclose(np.asarray(out_4), np.asarray(out_1),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(lse_4), np.asarray(lse_1),
                               rtol=1e-3, atol=1e-3)
    assert np.isfinite(np.asarray(out_4)).all()


def test_split_shared_kv_mla_mode():
    """MLA latent-cache split: V is a channel slice of dequantized K."""
    b, h, g, d_k, d_v, nb, block_n = 1, 1, 16, 256, 128, 4, 128
    case = _make_case(
        jax.random.PRNGKey(3), b=b, h=h, g=g, d_k=d_k, d_v=d_v, nb=nb,
        block_n=block_n, bits=4, k_gran="channel",
        pack_blocks=[3], res_len=[17],
    )
    case["vw"] = case["v_scale"] = case["v_zero"] = None
    case["v_res"] = None
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4, block_n=block_n,
                           k_gran="channel", shared_kv=True, d_v=d_v,
                           return_lse=True)
    out_1, lse_1 = fn(**case, impl="pallas", num_splits=1)
    out_s, lse_s = fn(**case, impl="pallas", num_splits=2)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_1),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_1),
                               rtol=1e-3, atol=1e-3)


def test_paged_split_matches_unsplit():
    """Paged kernel: num_splits walks page-table ranges; parity with the
    unsplit paged kernel on a shuffled page table."""
    from repro.kernels.paged_bitdecode import ops as pg_ops

    b, h, g, d, nb, block_n, bits = 2, 2, 4, 128, 4, 128, 4
    n_pages = b * nb + 3
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    k = jax.random.normal(ks[0], (1, h, n_pages * block_n, d), jnp.float32
                          ).astype(jnp.bfloat16)
    v = jax.random.normal(ks[1], (1, h, n_pages * block_n, d), jnp.float32
                          ).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(k, bits, "channel", block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(v, bits, "tensor", block_n=block_n)
    pool = lambda x: jnp.moveaxis(x[0], 1, 0)  # noqa: E731  [P, H, ...]
    table = jax.random.permutation(ks[5], n_pages)[: b * nb].reshape(b, nb)
    k_res = jax.random.normal(ks[3], (b, h, block_n, d), jnp.float32
                              ).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, block_n, d), jnp.float32
                              ).astype(jnp.bfloat16)
    q = (jax.random.normal(ks[2], (b, h, g, d), jnp.float32) / d**0.25
         ).astype(jnp.bfloat16)
    args = (q, pool(kw), pool(ksc), pool(kzp), pool(vw), pool(vsc), pool(vzp),
            k_res, v_res, jnp.asarray(table, jnp.int32),
            jnp.asarray([nb, nb - 1], jnp.int32),
            jnp.asarray([21, 0], jnp.int32))
    fn = functools.partial(pg_ops.paged_bitdecode_attention, bits=bits,
                           block_n=block_n, k_gran="channel", return_lse=True)
    out_1, lse_1 = fn(*args, impl="pallas", num_splits=1)
    out_s, lse_s = fn(*args, impl="pallas", num_splits=2)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_1),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_1),
                               rtol=1e-3, atol=1e-3)


def test_auto_heuristic_targets_small_batch_long_context():
    """auto splits exactly when B*H_kv underfills the cores and the packed
    sequence is long; each split must own >= 2 blocks."""
    assert bd_ops.auto_num_splits(8, 8, 64) == 1      # batch-heavy: never
    assert bd_ops.auto_num_splits(1, 2, 2) == 1       # too short
    s = bd_ops.auto_num_splits(1, 2, 64)              # B=1 GQA at 8K
    assert s > 1 and s * 2 <= 64
    assert bd_ops.auto_num_splits(1, 1, 6) <= 3       # >= 2 blocks per split
    assert bd_ops.resolve_num_splits("auto", 1, 2, 64) == s
    assert bd_ops.resolve_num_splits(3, 1, 2, 64) == 3
    assert bd_ops.resolve_num_splits(100, 1, 1, 4) == 4  # clamped to nb
