"""The trip-count-aware HLO cost model: validated against programs with
known analytic FLOPs (matmul chains inside scans) and known collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.utils import hlo_cost


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    n_outer = 8
    d = 256

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        c, _ = lax.scan(body, x, None, length=n_outer)
        return c

    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    res = hlo_cost.analyze(_compile_text(f, x, w))
    expect = n_outer * 2 * d**3
    assert abs(res["flops"] - expect) / expect < 0.01, (res["flops"], expect)


def test_nested_scan_flops():
    d = 128

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = lax.scan(inner, c, None, length=3)
            return ci, None

        c, _ = lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    res = hlo_cost.analyze(_compile_text(f, x, w))
    expect = 5 * 3 * 2 * d**3
    assert abs(res["flops"] - expect) / expect < 0.01


def test_dot_general_batched_flops():
    b, m, k, n = 4, 64, 128, 32

    def f(x, y):
        return lax.dot_general(x, y, (((2,), (1,)), ((0,), (0,))))

    x = jax.ShapeDtypeStruct((b, m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((b, k, n), jnp.float32)
    res = hlo_cost.analyze(_compile_text(f, x, y))
    expect = 2 * b * m * n * k
    assert abs(res["flops"] - expect) / expect < 0.01


def test_bytes_reasonable_for_elementwise():
    n = 1 << 20

    def f(x):
        return x * 2.0 + 1.0

    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    res = hlo_cost.analyze(_compile_text(f, x))
    # one fused op: read 4MB, write 4MB
    assert 0.5 * 8e6 <= res["bytes"] <= 3 * 8e6, res["bytes"]


def test_collectives_counted_with_trip_count():
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax import lax
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.utils import hlo_cost

        mesh = jax.make_mesh((4,), ("data",))
        sh = NamedSharding(mesh, PS(None, "data"))

        def f(x):
            def body(c, _):
                # forces an all-reduce each iteration
                s = jnp.sum(c, axis=1, keepdims=True)
                return c + s, None
            c, _ = lax.scan(body, x, None, length=6)
            return jnp.sum(c)

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32, sharding=sh)
        txt = jax.jit(f).lower(x).compile().as_text()
        res = hlo_cost.analyze(txt)
        # 6 iterations x all-reduce of a (128,1) f32 = 6*512B (+ final sum)
        assert res["collective_bytes"] >= 6 * 128 * 4, res
        print("COLL_OK", res["collective_bytes"])
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0 and "COLL_OK" in r.stdout, r.stdout + r.stderr
