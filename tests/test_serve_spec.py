"""Self-speculative decoding on the hierarchical quantized cache
(docs/SERVING.md §11): the draft pass reads the *same* pools at a truncated
bit-width, one batched verify scan replays the feeds at full fidelity, and
the greedy exact-match acceptance rule makes the emitted stream bitwise
identical to ``spec_k = 1`` — asserted here across cache families, bit
widths, granularities, pool pressure, faults, and prefix sharing.

Also covers the kernel-level ``draft_bits`` truncated-read contract
(kernels/bitdecode, kernels/paged_bitdecode) and the speculative counter
conservation the invariant auditor enforces.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.kernels.bitdecode import ops as bd_ops
from repro.kernels.kv_quant import ref as kq_ref
from repro.models.zoo import build_model
from repro.serve import FaultPlan, Request, ServeEngine
from repro.serve.audit import audit_engine

BLOCK = 32


def _model(arch, **cfg_kw):
    kw = {"kv_bits": 4, "kv_block": BLOCK}
    kw.update(cfg_kw)
    cfg = smoke_config(arch).with_(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def attn_model():
    return _model("llama3-8b")


@pytest.fixture(scope="module")
def mla_model():
    return _model("deepseek-v3-671b")


@pytest.fixture(scope="module")
def hybrid_model():
    return _model("zamba2-7b")


@pytest.fixture(scope="module")
def xlstm_model():
    return _model("xlstm-1.3b")


def _workload(cfg, n=4, seed=42, max_new=(12, 20)):
    """Block-crossing prompts so draft/verify cycles straddle residual
    flushes (the interesting part of the hierarchy)."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(34, 48)))
            .astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for i in range(n)
    ]


def _run(model, params, reqs, **kw):
    engine = ServeEngine(model, params, slots=2, max_seq=128, **kw)
    for r in reqs:
        assert engine.submit(r)
    engine.run()
    return engine


def _assert_spec_matches_sequential(cfg, model, params, *, spec_k,
                                    spec_bits=None, n=4,
                                    max_new=(12, 20), **spec_kw):
    base_reqs = _workload(cfg, n, max_new=max_new)
    _run(model, params, base_reqs)
    seq = {r.uid: list(r.out_tokens) for r in base_reqs}
    reqs = _workload(cfg, n, max_new=max_new)
    engine = _run(model, params, reqs, spec_k=spec_k, spec_bits=spec_bits,
                  audit_every=1, **spec_kw)
    for r in reqs:
        assert r.done, (r.uid, r.phase, r.error)
        assert list(r.out_tokens) == seq[r.uid], (
            f"request {r.uid} diverged under spec_k={spec_k}"
        )
    assert engine.stats["spec_cycles"] > 0
    assert audit_engine(engine).ok
    return engine


# --------------------------------------------------------------------------
# Bitwise parity: every cache family, bits x granularity
# --------------------------------------------------------------------------

def test_spec_matches_sequential_attn_4bit_channel(attn_model):
    cfg, model, params = attn_model
    engine = _assert_spec_matches_sequential(cfg, model, params, spec_k=3)
    assert engine.spec_bits == 2  # default min(2, kv_bits)


def test_spec_matches_sequential_attn_2bit_tensor():
    """kv_bits=2 + tensor granularity: spec_bits floors at the cache width,
    so the draft read is full fidelity (draft_bits >= bits no-op path)."""
    cfg, model, params = _model("llama3-8b", kv_bits=2, kv_gran="tensor")
    engine = _assert_spec_matches_sequential(cfg, model, params, spec_k=2)
    assert engine.spec_bits == 2


def test_spec_matches_sequential_mla(mla_model):
    cfg, model, params = mla_model
    _assert_spec_matches_sequential(cfg, model, params, spec_k=2, n=3)


def test_spec_matches_sequential_hybrid(hybrid_model):
    """Hybrid per-layer states: the verify scan must freeze dead lanes'
    SSM recurrent side-state, not just the paged KV."""
    cfg, model, params = hybrid_model
    _assert_spec_matches_sequential(cfg, model, params, spec_k=2, n=3)


def test_spec_xlstm_full_acceptance(xlstm_model):
    """The recurrent shim has no quantized cache: draft and verify run the
    same full-precision math, so every draft token must be accepted."""
    cfg, model, params = xlstm_model
    engine = _assert_spec_matches_sequential(cfg, model, params, spec_k=2,
                                             n=3)
    assert engine.stats["spec_draft_tokens"] > 0
    assert engine.stats["spec_rejected_tokens"] == 0
    assert engine.summary()["spec_accept_rate"] == 1.0


# --------------------------------------------------------------------------
# Pressure, faults, prefix sharing
# --------------------------------------------------------------------------

def test_spec_under_oversubscription_and_faults(attn_model):
    """Oversubscribed pool + expected reservations + alloc-fail faults:
    preemption-by-rematerialization (teacher-forced replay lanes in the
    verify scan) must still reconstruct the sequential stream bitwise."""
    cfg, model, params = attn_model
    plan = FaultPlan(seed=5, alloc_fail=0.3)
    engine = _assert_spec_matches_sequential(
        cfg, model, params, spec_k=3, n=5, max_new=(24, 32),
        n_pages=2 + 3, reserve_policy="expected", expected_quantile=0.0,
        faults=plan,
    )
    assert engine.stats["preempted"] > 0, "no pressure exercised"
    assert engine.stats["preempt_remat_tokens"] > 0
    assert engine.pool.n_free == engine.pool.capacity
    assert engine.pool.reserved == 0


def test_spec_with_prefix_sharing(attn_model):
    """Requests sharing a long prompt prefix: shared pages + suffix prefill
    interleave with speculative cycles without breaking parity.  The
    baseline is an identically-staggered *sequential* engine: a sharer's
    suffix prefill reads dequantized committed blocks, so its stream
    legitimately differs from an unshared run — what speculation must
    preserve is the sharing run itself, bit for bit."""
    cfg, model, params = attn_model
    rng = np.random.default_rng(9)
    stem = rng.integers(0, cfg.vocab, 2 * BLOCK + 7).astype(np.int32)
    mk = lambda uid: Request(uid=uid, prompt=stem.copy(), max_new_tokens=10)

    def staggered(**kw):
        reqs = [mk(0), mk(1), mk(2)]
        engine = ServeEngine(model, params, slots=2, max_seq=128, **kw)
        engine.submit(reqs[0])
        engine.step()  # donor adopted + its prefix registered
        engine.submit(reqs[1])
        engine.submit(reqs[2])
        engine.run()
        assert engine.stats["prefill_tokens_saved"] > 0, "sharing never fired"
        return engine, reqs

    _, base_reqs = staggered()
    seq = {r.uid: list(r.out_tokens) for r in base_reqs}
    engine, reqs = staggered(spec_k=3, audit_every=1)
    for r in reqs:
        assert list(r.out_tokens) == seq[r.uid]
    assert audit_engine(engine).ok


def test_spec_poisoned_row_isolated(attn_model):
    """A poisoned cycle retires only its own request (ERRORED) mid-spec;
    unaffected requests keep sequential parity."""
    cfg, model, params = attn_model
    base_reqs = _workload(cfg)
    _run(model, params, base_reqs)
    seq = {r.uid: list(r.out_tokens) for r in base_reqs}
    plan = FaultPlan(seed=1, fire_at={"poison_logits": (3,)},
                     max_fires={"poison_logits": 1})
    reqs = _workload(cfg)
    engine = _run(model, params, reqs, spec_k=3, faults=plan, audit_every=2)
    errored = [r for r in reqs if not r.done]
    assert len(errored) == 1
    assert "non-finite logits" in errored[0].error
    assert engine.stats["errored"] == 1
    for r in reqs:
        if r is errored[0]:
            continue
        assert r.done and list(r.out_tokens) == seq[r.uid]
    assert audit_engine(engine).ok


# --------------------------------------------------------------------------
# Counters and configuration
# --------------------------------------------------------------------------

def test_spec_counters_conserved(attn_model):
    cfg, model, params = attn_model
    reqs = _workload(cfg)
    engine = _run(model, params, reqs, spec_k=3, audit_every=1)
    s = engine.stats
    assert s["spec_cycles"] > 0
    assert s["spec_draft_tokens"] == (
        s["spec_accepted_tokens"] + s["spec_rejected_tokens"]
    )
    # per-request counters sum to the engine totals (replay lanes draft
    # nothing, so retired requests account for every drafted token)
    assert sum(r.spec_accepted for r in reqs) == s["spec_accepted_tokens"]
    assert sum(r.spec_rejected for r in reqs) == s["spec_rejected_tokens"]
    assert 0.0 <= engine.summary()["spec_accept_rate"] <= 1.0
    # the decoded stream itself is fully accounted: every emitted token
    # came from exactly one applied verify feed
    assert s["decoded_tokens"] == sum(len(r.out_tokens) for r in reqs)


def test_spec_config_validation(attn_model):
    cfg, model, params = attn_model
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(model, params, spec_k=0)
    with pytest.raises(ValueError, match="spec_bits"):
        ServeEngine(model, params, spec_k=2, spec_bits=0)
    with pytest.raises(ValueError, match="spec_bits"):
        ServeEngine(model, params, spec_k=2, spec_bits=8)  # > kv_bits=4
    # spec_k=1 is plain sequential decode: no draft/verify built
    engine = ServeEngine(model, params)
    assert engine._draft is None and engine._verify is None
    assert "spec_accept_rate" not in engine.summary()


# --------------------------------------------------------------------------
# Kernel-level draft_bits contract (truncated committed-pool read)
# --------------------------------------------------------------------------

def _bd_case(key, *, bits, res_len, pack_blocks, k_gran="channel"):
    b, h, g, d, nb, block_n = 1, 2, 4, 128, 2, 128
    ks = jax.random.split(key, 5)
    k_full = jax.random.normal(ks[0], (b, h, nb * block_n, d), jnp.float32)
    v_full = jax.random.normal(ks[1], (b, h, nb * block_n, d), jnp.float32)
    q = (jax.random.normal(ks[2], (b, h, g, d), jnp.float32) / d**0.25
         ).astype(jnp.bfloat16)
    k_res = jax.random.normal(ks[3], (b, h, block_n, d),
                              jnp.float32).astype(jnp.bfloat16)
    v_res = jax.random.normal(ks[4], (b, h, block_n, d),
                              jnp.float32).astype(jnp.bfloat16)
    kw, ksc, kzp = kq_ref.quantize_kv_ref(k_full.astype(jnp.bfloat16), bits,
                                          k_gran, block_n=block_n)
    vw, vsc, vzp = kq_ref.quantize_kv_ref(v_full.astype(jnp.bfloat16), bits,
                                          "tensor", block_n=block_n)
    return dict(q=q, kw=kw, k_scale=ksc, k_zero=kzp, vw=vw, v_scale=vsc,
                v_zero=vzp, k_res=k_res, v_res=v_res,
                pack_blocks=jnp.asarray(pack_blocks, jnp.int32),
                res_len=jnp.asarray(res_len, jnp.int32)), block_n


def test_draft_bits_noop_when_not_truncating():
    """draft_bits >= bits reads full fidelity: bitwise the normal path."""
    case, block_n = _bd_case(jax.random.PRNGKey(0), bits=4,
                             pack_blocks=[2], res_len=[17])
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4,
                           block_n=block_n, impl="xla")
    full = np.asarray(fn(**case))
    for db in (4, 8):
        np.testing.assert_array_equal(np.asarray(fn(**case, draft_bits=db)),
                                      full)


def test_draft_bits_truncates_committed_read_only():
    """The truncated read touches only the packed pools: with everything in
    the residual window the draft output is bitwise the full output, and
    with committed blocks present it must actually differ."""
    res_only, block_n = _bd_case(jax.random.PRNGKey(1), bits=4,
                                 pack_blocks=[0], res_len=[33])
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4,
                           block_n=block_n, impl="xla")
    np.testing.assert_array_equal(
        np.asarray(fn(**res_only, draft_bits=2)),
        np.asarray(fn(**res_only)),
    )
    packed, _ = _bd_case(jax.random.PRNGKey(1), bits=4,
                         pack_blocks=[2], res_len=[33])
    full = np.asarray(fn(**packed))
    draft = np.asarray(fn(**packed, draft_bits=2))
    assert draft.shape == full.shape and np.isfinite(draft).all()
    assert not np.array_equal(draft, full)
    # coarser, not broken: still an attention output in the same range
    assert float(np.abs(draft - full).max()) < 1.0


def test_draft_bits_validation():
    case, block_n = _bd_case(jax.random.PRNGKey(2), bits=4,
                             pack_blocks=[1], res_len=[5])
    fn = functools.partial(bd_ops.bitdecode_attention, bits=4,
                           block_n=block_n)
    with pytest.raises(ValueError):
        fn(**case, impl="xla", draft_bits=0)
    with pytest.raises(ValueError, match="Pallas"):
        fn(**case, impl="pallas", draft_bits=2)
